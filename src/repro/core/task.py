"""Solver-agnostic `TunableTask` API.

The paper claims the contextual-bandit autotuner "can be extended to
general algorithms"; this module is that claim as an interface. A task
packages everything algorithm-specific — its instances, per-instance
features, the precision `ActionSpace`, a batched solver, and a reward
hook — behind a small protocol, so one `AutotuneEngine`
(`core.engine`) and one `AutotuneServer` (`service.server`) can train
and serve any algorithm: GMRES-IR, CG-IR (`repro.tasks`), or anything
a user plugs in.

This module is deliberately dependency-light: numpy only, no solver
imports. Concrete tasks live in `repro.tasks` and bind the solver
substrate (`repro.solvers`) to this interface.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

# Outcome status codes — every solver in repro.solvers follows this
# convention, so tasks can translate stats to Outcomes without mapping.
CONVERGED, STAGNATED, MAXITER, FAILED = 0, 1, 2, 3


def bucket_of(n: int, step: int = 128, minimum: int = 128) -> int:
    """Smallest multiple of `step` (floored at `minimum`) that holds n."""
    return max(minimum, ((n + step - 1) // step) * step)


@dataclasses.dataclass
class Outcome:
    """Host-side result of applying one action to one instance.

    Generalizes the GMRES-IR `SolveRecord`: `status` uses the shared
    status codes above, `cost` is the task's scalar work measure (e.g.
    total inner solver iterations), and `metrics` carries every
    task-specific scalar (ferr, nbe, iteration counts, ...). Metrics
    are also readable as attributes (``outcome.ferr``), which keeps
    `SolveRecord`-era call sites working unchanged.
    """
    status: int
    cost: float
    metrics: Dict[str, float]

    def __getattr__(self, name: str):
        # Guard dunders and `metrics` itself: during unpickling/copy the
        # instance exists before `metrics` is set, and falling through to
        # `self.metrics` would recurse into this method forever.
        if name.startswith("__") or name == "metrics":
            raise AttributeError(name)
        try:
            return self.metrics[name]
        except KeyError:
            raise AttributeError(
                f"Outcome has no field or metric {name!r}") from None

    @property
    def ok(self) -> bool:
        return int(self.status) != FAILED


@runtime_checkable
class TunableTask(Protocol):
    """What the autotuning engine and server need from an algorithm.

    Attributes
    ----------
    name : str
        Stable identifier (telemetry, registries, benchmark rows).
    action_space : ActionSpace
        The joint precision action space the bandit selects from.
    instances : Sequence
        Training/evaluation instances (may be empty for serving-only
        tasks — the online server streams instances through
        `feature_of`/`prepare`/`solve_rows` without an instance set).
    features : np.ndarray
        (len(instances), d) context-feature matrix.
    """

    name: str
    action_space: Any
    instances: Sequence[Any]

    @property
    def features(self) -> np.ndarray: ...

    def feature_of(self, instance) -> np.ndarray:
        """Context-feature vector for one instance."""
        ...

    def bucket_key(self, instance) -> int:
        """Shape-bucket key: instances sharing a key may share one
        compiled fixed-shape executable."""
        ...

    def prepare(self, instance):
        """Device-ready padded row(s) for one instance (cacheable)."""
        ...

    def solve_rows(self, rows: Sequence, action_rows: Sequence,
                   chunk: int) -> List[Outcome]:
        """Batch-apply `action_rows[i]` to prepared `rows[i]`.

        All rows share one bucket. Implementations pad the batch
        dimension to exactly `chunk` (fixed compiled shape) and return
        one `Outcome` per *input* row.
        """
        ...

    def reward(self, outcome: Outcome, action_idx: int, instance,
               cfg) -> float:
        """Scalar reward for `outcome` under reward config `cfg`."""
        ...


def is_tunable_task(obj) -> bool:
    """Structural check (protocol isinstance is unreliable for
    non-method members)."""
    return all(callable(getattr(obj, m, None)) for m in
               ("feature_of", "bucket_key", "prepare", "solve_rows",
                "reward"))


def coerce_task(obj, *, action_space=None, bucket_step=None,
                min_bucket=None):
    """Return `obj` if it already implements `TunableTask`; otherwise
    adapt a legacy solver-config object (e.g. an `IRConfig`, or None
    for the historical default) via `repro.tasks.adapt_legacy`.

    Executor overrides are NOT plumbed here: callers that want one set
    `task.executor` on the result (the server and engine both do),
    which covers adapted and real tasks with one mechanism.

    The import is deferred so this module — and everything built only
    on the protocol, like `core.engine` and `service.server` — stays
    free of solver dependencies.
    """
    if obj is not None and is_tunable_task(obj):
        return obj
    from repro import tasks  # deferred: binds solver-specific adapters
    return tasks.adapt_legacy(obj, action_space=action_space,
                              bucket_step=bucket_step,
                              min_bucket=min_bucket)
