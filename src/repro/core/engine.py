"""The single autotuning engine shared by offline training and online
serving.

`AutotuneEngine` owns the three things every bandit-autotuning loop
needs, for any `TunableTask`:

  * the **solve cache** — deterministic tasks make (instance, action)
    outcomes reusable; cache misses are batched per shape bucket into
    fixed-`chunk` calls to `task.solve_rows` (one compile per bucket),
  * **epsilon-greedy selection** — by discretized state (offline Alg. 3,
    with pre-drawn coins for predictive prefetching) or by raw features
    (online serving, with the nearest-visited-bin greedy fallback),
  * **Q-updates** — the Eq. 6 incremental update against the attached
    policy's Q-table, returning the reward-prediction error.

The engine never imports a solver: everything algorithm-specific flows
through the task's `solve_rows` / `reward` hooks. `core.autotune`
(offline) and `service.server` (online) are both thin drivers over this
class, so the learning loop exists exactly once.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import faults
from repro.core.bandit import QTable
from repro.core.discretize import Discretizer
from repro.core.executor import resolve_executor
from repro.core.policy import PrecisionPolicy
from repro.core.task import Outcome, TunableTask


def _count(name: str, help: str, amount: float = 1.0, **labels) -> None:
    """Fail-open counter against the process-default metrics registry
    (repro.obs). The engine predates any server's obs bundle, and the
    solve-cache stats are process-global anyway — like the executor's
    wrapped-callable memo they describe compiled state, not one server."""
    try:
        from repro.obs.metrics import default_registry
        fam = default_registry().counter(name, help,
                                         tuple(sorted(labels)))
        (fam.labels(**labels) if labels else fam).inc(amount)
    except Exception:
        pass


class AutotuneEngine:
    def __init__(self, task: TunableTask, reward_cfg=None,
                 chunk: int = 32, seed: int = 0,
                 policy: Optional[PrecisionPolicy] = None,
                 executor=None):
        self.task = task
        self.reward_cfg = reward_cfg
        self.chunk = chunk
        self.policy = policy
        # The executor rides through the solve cache (DESIGN.md §7):
        # chunks are rounded to its dispatch granularity, so padded-row
        # accounting below reflects what actually ran on the devices.
        # An explicit `executor` is pushed onto the task (same move the
        # server makes) — the task's solve_rows is where dispatch
        # happens, so engine-side chunk policy and task-side placement
        # must agree. Default: the task's own executor.
        if executor is not None:
            self.task.executor = resolve_executor(executor)
        self.executor = resolve_executor(
            getattr(self.task, "executor", None))
        self._rng = np.random.default_rng(seed)
        self._prepared: Dict[int, object] = {}   # instance idx -> rows
        self._cache: Dict[Tuple[int, int], Outcome] = {}
        # Ad-hoc solve cache (trajectory replay, eval.replay): keyed by
        # (id(instance), action) with the instance pinned alongside the
        # outcome so the id can never be recycled while the entry lives.
        self._adhoc: Dict[Tuple[int, int], Tuple[object, Outcome]] = {}
        self.n_solves = 0       # real solver rows (satellite: no pad rows)
        self.n_pad_solves = 0   # wasted rows from fixed-chunk padding
        self.n_requests = 0     # reward lookups

    # -- task facade -------------------------------------------------------
    @property
    def instances(self):
        return self.task.instances

    @property
    def features(self) -> np.ndarray:
        return self.task.features

    @property
    def action_space(self):
        return self.task.action_space

    @property
    def kappas(self):
        """Condition estimates when the task provides them (linear-system
        tasks do); None otherwise."""
        return getattr(self.task, "kappas", None)

    # -- solve cache -------------------------------------------------------
    def _prep(self, i: int):
        if i not in self._prepared:
            self._prepared[i] = self.task.prepare(self.task.instances[i])
        return self._prepared[i]

    def solve_pairs(self, pairs: Iterable[Tuple[int, int]]) -> None:
        """Batch-solve all uncached (instance, action) pairs."""
        miss = sorted({(int(i), int(a)) for i, a in pairs
                       if (int(i), int(a)) not in self._cache})
        if not miss:
            return
        pad_before = self.n_pad_solves
        by_bucket: Dict[int, List[Tuple[int, int]]] = {}
        for p in miss:
            key = self.task.bucket_key(self.task.instances[p[0]])
            by_bucket.setdefault(key, []).append(p)
        task_name = getattr(self.task, "name", "unknown")
        for bucket, plist in sorted(by_bucket.items()):
            # Executor granularity: a mesh executor rounds the chunk up
            # to a multiple of its data-axis width, and the pad-row
            # stats must count those extra rows — they run on devices.
            chunk = self.executor.preferred_chunk(self.chunk, bucket)
            _count("repro_engine_cache_misses_total",
                   "Uncached (instance, action) pairs solved by the "
                   "engine's solve cache.", len(plist),
                   task=task_name, bucket=bucket)
            for c0 in range(0, len(plist), chunk):
                chunk_pairs = plist[c0:c0 + chunk]
                faults.maybe_raise("engine.solve", bucket=bucket)
                outs = self.task.solve_rows(
                    [self._prep(i) for i, _ in chunk_pairs],
                    [self.action_space.actions[a] for _, a in chunk_pairs],
                    chunk)
                self.n_solves += len(chunk_pairs)
                self.n_pad_solves += chunk - len(chunk_pairs)
                for p, out in zip(chunk_pairs, outs):
                    self._cache[p] = faults.corrupt_outcome(
                        "solver.outcome", out, bucket=bucket,
                        action_row=self.action_space.actions[p[1]])
        _count("repro_engine_solve_rows_total",
               "Real rows solved through the engine cache.", len(miss),
               task=task_name)
        _count("repro_engine_pad_rows_total",
               "Padding rows burned by fixed-chunk engine solves.",
               self.n_pad_solves - pad_before, task=task_name)

    def outcome(self, i: int, a: int) -> Outcome:
        if (i, a) not in self._cache:
            self.solve_pairs([(i, a)])
        return self._cache[(i, a)]

    def solve_adhoc(self, pairs: Sequence[Tuple[object, int]]
                    ) -> List[Outcome]:
        """Batch-solve (instance, action) pairs for instances *outside*
        ``task.instances`` — the trajectory-replay path (`eval.replay`)
        and any serving-style one-off. Same bucketed fixed-chunk route
        as `solve_pairs` (one compiled executable per bucket; pad rows
        counted), outcomes returned in input order and cached."""
        miss: Dict[Tuple[int, int], Tuple[object, int]] = {}
        for inst, a in pairs:
            key = (id(inst), int(a))
            if key not in self._adhoc and key not in miss:
                miss[key] = (inst, int(a))
        by_bucket: Dict[int, List[Tuple[Tuple[int, int],
                                        Tuple[object, int]]]] = {}
        for key, (inst, a) in miss.items():
            bucket = self.task.bucket_key(inst)
            by_bucket.setdefault(bucket, []).append((key, (inst, a)))
        task_name = getattr(self.task, "name", "unknown")
        for bucket, plist in sorted(by_bucket.items()):
            chunk = self.executor.preferred_chunk(self.chunk, bucket)
            _count("repro_engine_cache_misses_total",
                   "Uncached (instance, action) pairs solved by the "
                   "engine's solve cache.", len(plist),
                   task=task_name, bucket=bucket)
            for c0 in range(0, len(plist), chunk):
                part = plist[c0:c0 + chunk]
                faults.maybe_raise("engine.solve", bucket=bucket)
                outs = self.task.solve_rows(
                    [self.task.prepare(inst) for _, (inst, _) in part],
                    [self.action_space.actions[a] for _, (_, a) in part],
                    chunk)
                self.n_solves += len(part)
                self.n_pad_solves += chunk - len(part)
                for (key, (inst, a)), out in zip(part, outs):
                    self._adhoc[key] = (inst, faults.corrupt_outcome(
                        "solver.outcome", out, bucket=bucket,
                        action_row=self.action_space.actions[a]))
        return [self._adhoc[(id(inst), int(a))][1] for inst, a in pairs]

    def outcome_for_instance(self, instance, action_idx: int) -> Outcome:
        """Outcome of one ad-hoc (instance, action) solve (cached)."""
        return self.solve_adhoc([(instance, int(action_idx))])[0]

    def reward_for(self, outcome: Outcome, action_idx: int, instance,
                   cfg=None) -> float:
        """Task reward for an already-observed outcome (online path)."""
        cfg = cfg if cfg is not None else self.reward_cfg
        return self.task.reward(outcome, int(action_idx), instance, cfg)

    def reward(self, i: int, a: int, cfg=None) -> float:
        """Reward for applying action `a` to instance `i` (offline path)."""
        self.n_requests += 1
        return self.reward_for(self.outcome(i, a), a,
                               self.task.instances[i], cfg)

    def prefill_all(self) -> None:
        """Exhaustive (instance x action) sweep — the multi-pod work grid."""
        self.solve_pairs([(i, a) for i in range(len(self.task.instances))
                          for a in range(self.action_space.n_actions)])

    def precompile(self, buckets: Optional[Sequence[int]] = None
                   ) -> List[Tuple[int, bool]]:
        """AOT-warm the solve cache's executable grid (DESIGN.md §12):
        for each bucket, build the executable a `solve_pairs` chunk
        would otherwise compile on first miss — same chunk policy, same
        computation key, so this is a no-op on an already-warm engine.
        Buckets default to the task's instance buckets. Returns
        (bucket, warmed) pairs; warmed=False means the task has no AOT
        form and that bucket compiles lazily as before."""
        fn = getattr(self.task, "precompile_bucket", None)
        if fn is None:
            return []
        if buckets is None:
            buckets = sorted({self.task.bucket_key(s)
                              for s in self.task.instances})
        return [(int(b), bool(fn(int(b), self.chunk))) for b in buckets]

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def summarize(self) -> Dict[str, float]:
        """Solver-work accounting: real rows vs fixed-shape padding
        waste, plus the per-device view (rows are spread evenly over the
        executor's mesh, so per-device counts are totals / devices)."""
        d = max(1, self.executor.device_count())
        total = self.n_solves + self.n_pad_solves
        return {"n_solves": self.n_solves,
                "n_pad_solves": self.n_pad_solves,
                "n_requests": self.n_requests,
                "cache_size": self.cache_size,
                "n_devices": d,
                "rows_per_device": total // d,
                "n_solves_per_device": self.n_solves / d,
                "n_pad_solves_per_device": self.n_pad_solves / d}

    # -- selection + learning ---------------------------------------------
    def fit_policy(self, n_bins, alpha=0.5, seed: int = 0
                   ) -> PrecisionPolicy:
        """Fresh policy: discretizer fit on the task's feature matrix plus
        an all-zero Q-table. Attached as this engine's live policy."""
        disc = Discretizer.fit(self.features, n_bins)
        qt = QTable(disc.n_states, self.action_space.n_actions, alpha, seed)
        self.policy = PrecisionPolicy(self.action_space, disc, qt)
        return self.policy

    @property
    def qtable(self) -> QTable:
        return self.policy.qtable

    def greedy(self, state: int) -> int:
        return self.policy.qtable.greedy(int(state))

    def select(self, state: int, eps: float, *, explore: Optional[bool]
               = None, rand_action: Optional[int] = None
               ) -> Tuple[int, bool]:
        """Epsilon-greedy by discretized state.

        `explore`/`rand_action` may be pre-drawn by the caller (the
        offline trainer draws them at episode start so greedy picks can
        be prefetched in one batched solve); left None, the engine's own
        rng draws them.
        """
        if explore is None:
            explore = bool(self._rng.random() < eps)
        if explore:
            action = (int(rand_action) if rand_action is not None else
                      int(self._rng.integers(self.action_space.n_actions)))
        else:
            action = self.greedy(state)
        return action, bool(explore)

    def select_for_features(self, features: np.ndarray, eps: float
                            ) -> Tuple[int, int, bool]:
        """(state, action, explore) from raw features: the online path.
        Greedy picks go through `PrecisionPolicy.predict`, i.e. the
        nearest-visited-bin fallback (Prop. 1)."""
        state = self.policy.state_of(features)
        explore = bool(self._rng.random() < eps)
        if explore:
            action = int(self._rng.integers(self.action_space.n_actions))
        else:
            action, _ = self.policy.predict(features)
        return state, int(action), explore

    def update(self, state: int, action: int, r: float) -> float:
        """Eq. 6 Q-update; returns the pre-update reward-prediction
        error."""
        return self.policy.qtable.update(int(state), int(action), float(r))
