"""High-level training / evaluation API (paper Alg. 3), task-agnostic.

`train_policy` is an *exact* implementation of Algorithm 3 — sequential
per-instance epsilon-greedy selection and Q-updates — with a predictive
batching trick: at each episode start the epsilon coins and random actions
are pre-drawn and the greedy actions under the episode-start Q are
pre-solved, so nearly every reward lookup hits the solve cache while the
update order/semantics stay exactly the paper's. Intra-episode Q changes
that flip an argmax fall back to an on-demand solve (rare).

All entry points accept any `TunableTask` (GMRES-IR, CG-IR, ...) or an
already-built `AutotuneEngine`; the legacy `GMRESIREnv` is an engine
subclass, so existing call sites work unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.bandit import epsilon_schedule
from repro.core.engine import AutotuneEngine
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig
from repro.core.task import coerce_task
from repro.solvers.metrics import summarize


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    episodes: int = 100
    alpha: Optional[float] = 0.5    # None => 1/N(s,a)
    eps_min: float = 0.02
    n_bins: Sequence[int] = (10, 10)
    seed: int = 0
    prefill: bool = False           # exhaustive (i,a) sweep before training


@dataclasses.dataclass
class TrainHistory:
    episode_reward: List[float] = dataclasses.field(default_factory=list)
    episode_rpe: List[float] = dataclasses.field(default_factory=list)
    epsilon: List[float] = dataclasses.field(default_factory=list)
    unique_solves: List[int] = dataclasses.field(default_factory=list)
    wall_time_s: float = 0.0
    n_solves: int = 0        # real solver rows executed
    n_pad_solves: int = 0    # fixed-chunk padding waste


def as_engine(task_or_engine) -> AutotuneEngine:
    """Coerce a TunableTask (or legacy config object) into an engine;
    pass engines (incl. the `GMRESIREnv` shim) through untouched."""
    if isinstance(task_or_engine, AutotuneEngine):
        return task_or_engine
    return AutotuneEngine(coerce_task(task_or_engine))


def train_policy(task, reward_cfg: RewardConfig,
                 cfg: TrainConfig = TrainConfig()) -> tuple:
    """Algorithm 3 on the task's training instances."""
    t0 = time.time()
    engine = as_engine(task)
    n_sys = len(engine.instances)
    policy = engine.fit_policy(cfg.n_bins, cfg.alpha, cfg.seed)
    states = np.asarray(policy.discretizer(engine.features))
    rng = np.random.default_rng(cfg.seed + 1)
    hist = TrainHistory()

    if cfg.prefill:
        engine.prefill_all()

    for t in range(cfg.episodes):
        eps = epsilon_schedule(t, cfg.episodes, cfg.eps_min)
        coins = rng.random(n_sys) < eps
        rand_a = rng.integers(engine.action_space.n_actions, size=n_sys)
        # Predictive prefetch: random picks + episode-start greedy picks.
        prefetch = [(i, int(rand_a[i])) for i in range(n_sys) if coins[i]]
        prefetch += [(i, engine.greedy(int(states[i])))
                     for i in range(n_sys) if not coins[i]]
        engine.solve_pairs(prefetch)

        ep_rewards, ep_rpes = [], []
        for i in range(n_sys):                      # Alg. 3 lines 6-21
            s = int(states[i])
            a, _ = engine.select(s, eps, explore=bool(coins[i]),
                                 rand_action=int(rand_a[i]))
            r = engine.reward(i, a, reward_cfg)
            rpe = engine.update(s, a, r)
            ep_rewards.append(r)
            ep_rpes.append(abs(rpe))
        hist.episode_reward.append(float(np.mean(ep_rewards)))
        hist.episode_rpe.append(float(np.mean(ep_rpes)))
        hist.epsilon.append(eps)
        hist.unique_solves.append(engine.cache_size)

    hist.wall_time_s = time.time() - t0
    hist.n_solves = engine.n_solves
    hist.n_pad_solves = engine.n_pad_solves
    return policy, hist


def _collect(engine: AutotuneEngine, picks):
    """Metric arrays for a list of (instance, action) picks.

    The evaluation drivers (unlike training) summarize per condition
    range, so they require linear-system-style tasks: outcomes carrying
    "ferr"/"nbe"/"n_outer" (+ the task's `inner_iter_metric`) and a
    `kappas` attribute on the task. Custom tasks without these should
    summarize their own outcomes via `engine.outcome`.
    """
    if getattr(engine.task, "kappas", None) is None:
        raise TypeError(
            f"task {getattr(engine.task, 'name', type(engine.task).__name__)!r}"
            " has no `kappas`; evaluate_policy/evaluate_fixed_action only "
            "summarize linear-system tasks — collect outcomes via "
            "AutotuneEngine.outcome for custom tasks")
    outs = [engine.outcome(i, a) for i, a in picks]
    inner_key = getattr(engine.task, "inner_iter_metric", "n_gmres")
    ferr = np.array([o.metrics["ferr"] for o in outs])
    nbe = np.array([o.metrics["nbe"] for o in outs])
    n_outer = np.array([o.metrics["n_outer"] for o in outs])
    n_inner = np.array([o.metrics[inner_key] for o in outs])
    return ferr, nbe, n_outer, n_inner


def evaluate_policy(policy: PrecisionPolicy, task, tau_base: float) -> Dict:
    """Greedy inference (Alg. 3 line 23) over the task's instances,
    summarized per condition range (paper table columns)."""
    engine = as_engine(task)
    n_sys = len(engine.instances)
    picks = []
    for i in range(n_sys):
        a, _ = policy.predict(engine.features[i])
        picks.append((i, a))
    engine.solve_pairs(picks)
    ferr, nbe, n_outer, n_inner = _collect(engine, picks)
    kappa = engine.kappas
    table = summarize(ferr, nbe, n_outer, n_inner, kappa, tau_base)
    # Per-step precision usage frequencies (paper Fig. 2 / Table 5).
    usage = np.zeros((len(policy.action_space.ladder),))
    per_range_usage = {}
    names = list(policy.action_space.ladder)
    lad = policy.action_space.ladder_idx
    for rng_name, (lo, hi) in {
            "low": (1e0, 1e3), "medium": (1e3, 1e6),
            "high": (1e6, 1e9), "vhigh": (1e9, 1e12)}.items():
        sel = [(i, a) for (i, a) in picks if lo <= kappa[i] < hi]
        if not sel:
            continue
        counts = np.zeros(len(names))
        for _, a in sel:
            for step in lad[a]:
                counts[step] += 1
        per_range_usage[rng_name] = dict(
            zip(names, (counts / len(sel)).round(3).tolist()))
    for _, a in picks:
        for step in lad[a]:
            usage[step] += 1
    return {
        "table": table,
        "actions": picks,
        "ferr": ferr, "nbe": nbe,
        "n_outer": n_outer, "n_inner": n_inner,
        # legacy alias (pre-TunableTask callers read the GMRES name)
        "n_gmres": n_inner,
        "usage_per_solve": dict(zip(names, (usage / n_sys).round(3).tolist())),
        "usage_per_range": per_range_usage,
    }


def evaluate_fixed_action(task, action_idx: int, tau_base: float) -> Dict:
    """Baseline evaluation (e.g. the all-FP64 action)."""
    engine = as_engine(task)
    picks = [(i, action_idx) for i in range(len(engine.instances))]
    engine.solve_pairs(picks)
    ferr, nbe, n_outer, n_inner = _collect(engine, picks)
    return {"table": summarize(ferr, nbe, n_outer, n_inner, engine.kappas,
                               tau_base),
            "ferr": ferr, "nbe": nbe, "n_outer": n_outer,
            "n_inner": n_inner, "n_gmres": n_inner}
