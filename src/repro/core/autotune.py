"""High-level training / evaluation API (paper Alg. 3).

`train_policy` is an *exact* implementation of Algorithm 3 — sequential
per-instance epsilon-greedy selection and Q-updates — with a predictive
batching trick: at each episode start the epsilon coins and random actions
are pre-drawn and the greedy actions under the episode-start Q are
pre-solved, so nearly every reward lookup hits the solve cache while the
update order/semantics stay exactly the paper's. Intra-episode Q changes
that flip an argmax fall back to an on-demand solve (rare).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.bandit import QTable, epsilon_schedule
from repro.core.discretize import Discretizer
from repro.core.env import GMRESIREnv
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig
from repro.solvers.metrics import summarize


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    episodes: int = 100
    alpha: Optional[float] = 0.5    # None => 1/N(s,a)
    eps_min: float = 0.02
    n_bins: Sequence[int] = (10, 10)
    seed: int = 0
    prefill: bool = False           # exhaustive (i,a) sweep before training


@dataclasses.dataclass
class TrainHistory:
    episode_reward: List[float] = dataclasses.field(default_factory=list)
    episode_rpe: List[float] = dataclasses.field(default_factory=list)
    epsilon: List[float] = dataclasses.field(default_factory=list)
    unique_solves: List[int] = dataclasses.field(default_factory=list)
    wall_time_s: float = 0.0


def train_policy(env: GMRESIREnv, reward_cfg: RewardConfig,
                 cfg: TrainConfig = TrainConfig()) -> tuple:
    """Algorithm 3 on the environment's training systems."""
    t0 = time.time()
    n_sys = len(env.systems)
    disc = Discretizer.fit(env.features, cfg.n_bins)
    states = np.asarray(disc(env.features))
    qt = QTable(disc.n_states, env.action_space.n_actions, cfg.alpha,
                cfg.seed)
    rng = np.random.default_rng(cfg.seed + 1)
    hist = TrainHistory()

    if cfg.prefill:
        env.prefill_all()

    for t in range(cfg.episodes):
        eps = epsilon_schedule(t, cfg.episodes, cfg.eps_min)
        coins = rng.random(n_sys) < eps
        rand_a = rng.integers(env.action_space.n_actions, size=n_sys)
        # Predictive prefetch: random picks + episode-start greedy picks.
        prefetch = [(i, int(rand_a[i])) for i in range(n_sys) if coins[i]]
        prefetch += [(i, qt.greedy(int(states[i]))) for i in range(n_sys)
                     if not coins[i]]
        env.solve_pairs(prefetch)

        ep_rewards, ep_rpes = [], []
        for i in range(n_sys):                      # Alg. 3 lines 6-21
            s = int(states[i])
            a = int(rand_a[i]) if coins[i] else qt.greedy(s)
            r = env.reward(i, a, reward_cfg)
            rpe = qt.update(s, a, r)
            ep_rewards.append(r)
            ep_rpes.append(abs(rpe))
        hist.episode_reward.append(float(np.mean(ep_rewards)))
        hist.episode_rpe.append(float(np.mean(ep_rpes)))
        hist.epsilon.append(eps)
        hist.unique_solves.append(env.cache_size)

    hist.wall_time_s = time.time() - t0
    policy = PrecisionPolicy(env.action_space, disc, qt)
    return policy, hist


def evaluate_policy(policy: PrecisionPolicy, env: GMRESIREnv,
                    tau_base: float) -> Dict:
    """Greedy inference (Alg. 3 line 23) over the env's systems, summarized
    per condition range (paper table columns)."""
    n_sys = len(env.systems)
    picks = []
    for i in range(n_sys):
        a, _ = policy.predict(env.features[i])
        picks.append((i, a))
    env.solve_pairs(picks)
    recs = [env.record(i, a) for i, a in picks]
    ferr = np.array([r.ferr for r in recs])
    nbe = np.array([r.nbe for r in recs])
    n_outer = np.array([r.n_outer for r in recs])
    n_gmres = np.array([r.n_gmres for r in recs])
    kappa = env.kappas
    table = summarize(ferr, nbe, n_outer, n_gmres, kappa, tau_base)
    # Per-step precision usage frequencies (paper Fig. 2 / Table 5).
    usage = np.zeros((len(policy.action_space.ladder),))
    per_range_usage = {}
    names = list(policy.action_space.ladder)
    lad = policy.action_space.ladder_idx
    for rng_name, (lo, hi) in {
            "low": (1e0, 1e3), "medium": (1e3, 1e6),
            "high": (1e6, 1e9), "vhigh": (1e9, 1e12)}.items():
        sel = [(i, a) for (i, a) in picks if lo <= kappa[i] < hi]
        if not sel:
            continue
        counts = np.zeros(len(names))
        for _, a in sel:
            for step in lad[a]:
                counts[step] += 1
        per_range_usage[rng_name] = dict(
            zip(names, (counts / len(sel)).round(3).tolist()))
    for _, a in picks:
        for step in lad[a]:
            usage[step] += 1
    return {
        "table": table,
        "actions": picks,
        "ferr": ferr, "nbe": nbe,
        "n_outer": n_outer, "n_gmres": n_gmres,
        "usage_per_solve": dict(zip(names, (usage / n_sys).round(3).tolist())),
        "usage_per_range": per_range_usage,
    }


def evaluate_fixed_action(env: GMRESIREnv, action_idx: int,
                          tau_base: float) -> Dict:
    """Baseline evaluation (e.g. the all-FP64 action)."""
    picks = [(i, action_idx) for i in range(len(env.systems))]
    env.solve_pairs(picks)
    recs = [env.record(i, a) for i, a in picks]
    ferr = np.array([r.ferr for r in recs])
    nbe = np.array([r.nbe for r in recs])
    n_outer = np.array([r.n_outer for r in recs])
    n_gmres = np.array([r.n_gmres for r in recs])
    return {"table": summarize(ferr, nbe, n_outer, n_gmres, env.kappas,
                               tau_base),
            "ferr": ferr, "nbe": nbe, "n_outer": n_outer,
            "n_gmres": n_gmres}
