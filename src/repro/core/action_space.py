"""Joint precision action space + the paper's monotone reduction (Eq. 11-12).

An action is a k-tuple of precisions (one per computational step), ordered so
that u_1' <= u_2' <= ... <= u_k' by significand bits (for GMRES-IR:
u_f <= u <= u_g <= u_r). The reduced space has C(m+k-1, k) elements
(Eq. 12): 35 for m=4, k=4, an ~86% cut of the 256-action product space.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.precision import (FORMAT_ID, FORMATS, SOLVER_LADDER,
                             SOLVER_LADDER_FP8)


@dataclasses.dataclass(frozen=True)
class ActionSpace:
    ladder: Tuple[str, ...]      # precision names, increasing significand
    k: int                       # number of precision-controlled steps
    actions: np.ndarray          # (n_actions, k) global format ids
    ladder_idx: np.ndarray       # (n_actions, k) indices into `ladder`

    @property
    def n_actions(self) -> int:
        return self.actions.shape[0]

    def names(self, a: int) -> Tuple[str, ...]:
        return tuple(self.ladder[i] for i in self.ladder_idx[a])

    def significand_bits(self, a: int) -> Tuple[int, ...]:
        return tuple(FORMATS[n].t for n in self.names(a))


def reduced_size(m: int, k: int) -> int:
    """Eq. 12: C(m+k-1, k)."""
    return math.comb(m + k - 1, k)


def reduced_action_space(ladder: Sequence[str] = tuple(SOLVER_LADDER),
                         k: int = 4,
                         subsample: Optional[int] = None,
                         seed: int = 0) -> ActionSpace:
    """All non-decreasing k-tuples over the ladder (Eq. 11).

    `subsample`: optionally keep only this many actions (the paper further
    prunes to ~1/4 of the valid combinations); the full/best (all-lowest,
    all-highest) extremes are always retained so the agent can reach both the
    cheapest and the reference configuration.
    """
    m = len(ladder)
    combos = list(itertools.combinations_with_replacement(range(m), k))
    assert len(combos) == reduced_size(m, k)
    idx = np.asarray(combos, dtype=np.int32)
    if subsample is not None and subsample < len(combos):
        rng = np.random.default_rng(seed)
        keep = {0, len(combos) - 1}
        rest = [i for i in range(len(combos)) if i not in keep]
        keep |= set(rng.choice(rest, size=subsample - len(keep),
                               replace=False).tolist())
        idx = idx[sorted(keep)]
    actions = np.asarray([[FORMAT_ID[ladder[i]] for i in row] for row in idx],
                         dtype=np.int32)
    return ActionSpace(tuple(ladder), k, actions, idx)


def fp8_reduced_action_space(k: int = 4,
                             subsample: Optional[int] = None,
                             seed: int = 0) -> ActionSpace:
    """The fp8-extended reduced space: the `SOLVER_LADDER`-derived Eq. 11
    construction over `SOLVER_LADDER_FP8` (e5m2/e4m3 prepended as the
    cheapest rungs). m=6, k=4 gives C(9, 4) = 126 monotone actions —
    `subsample` prunes as in the paper while always keeping the
    all-e5m2 and all-fp64 extremes. The fp8 formats saturate on
    overflow, so u_f = fp8 arms fail soft (clamped factors -> more
    refinement) instead of hard (inf-poisoned LU)."""
    return reduced_action_space(tuple(SOLVER_LADDER_FP8), k,
                                subsample=subsample, seed=seed)


def full_action_space(ladder: Sequence[str] = tuple(SOLVER_LADDER),
                      k: int = 4) -> ActionSpace:
    """Unreduced m^k product space (for ablations)."""
    m = len(ladder)
    combos = list(itertools.product(range(m), repeat=k))
    idx = np.asarray(combos, dtype=np.int32)
    actions = np.asarray([[FORMAT_ID[ladder[i]] for i in row] for row in idx],
                         dtype=np.int32)
    return ActionSpace(tuple(ladder), k, actions, idx)


def is_monotone(action_ladder_idx: Sequence[int]) -> bool:
    return all(a <= b for a, b in zip(action_ladder_idx,
                                      action_ladder_idx[1:]))
