"""Solve executors: device placement + dispatch of fixed-shape batches
(DESIGN.md §7).

Every solve the engine or the serving micro-batcher runs is a
fixed-shape stacked batch — `(chunk, n_pad, n_pad)` matrices plus their
`(chunk, n_pad)` vectors and `(chunk, k)` action rows. A `SolveExecutor`
is the one object that owns where those arrays live and how the batched
solver executable is dispatched over them:

  * `LocalExecutor` — the historical single-device vmapped path
    (extracted from `core.batching.solve_fixed_batch`): arrays go to the
    default device, one executable per size bucket.
  * `ShardedExecutor` — a `("data", "model")` `jax.sharding.Mesh`:
    batch rows are laid over the "data" axis via `NamedSharding` on the
    stacked arrays, so one engine sweep spans every device of the mesh;
    for systems of `model_min_n` and above the system (row) dimension is
    additionally laid over "model" with the same divisibility-checked
    `_fit` rule the LM substrate uses (`distributed/sharding`). The
    chunk is auto-rounded up to a multiple of the data-axis size
    (`preferred_chunk`), so the compiled shape stays bucket-stable no
    matter how many rows a flush happens to carry.

The data-axis layout dispatches through `shard_map`: every device runs
the *unpartitioned* per-shard program on its slice of the batch. This
is what makes cross-executor bit-equality constructive — the per-row
program is byte-for-byte the local one (batched == single row results
are already pinned by the backend suite), whereas letting GSPMD
partition the solver body changes reduction lowering with the program
context (measured: a mesh shard holding one row compiles a batch-1 dot
that accumulates differently). The "model"-axis layout for huge systems
IS GSPMD-partitioned (collectives inside the row are the point there)
and sits outside the bit-parity contract — see DESIGN.md §7.2.

Executors are tiny frozen dataclasses, hashing by value like
`BlockingPolicy` and the precision backends: wrapped batch callables
are memoized per (executor, caller key) — `batch_callable` — so
switching executors costs exactly one extra executable per bucket while
the format ids stay runtime data (the §3.4 invariant is untouched), and
equal-valued executors share executables. Cross-executor SolveRecord
bit-equality is asserted by `tests/test_executor.py` on a forced
8-device host mesh.

This module is solver-free (the engine and serving stack import it);
selection mirrors the precision backends: explicit argument >
`set_default_executor` > ``REPRO_SOLVE_EXECUTOR`` env var > ``"local"``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ENV_VAR = "REPRO_SOLVE_EXECUTOR"


class SolveExecutor:
    """Interface shared by all solve executors (duck-typed; this base
    class documents the contract and hosts shared helpers)."""

    name: str = "abstract"

    # -- chunk policy ------------------------------------------------------
    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        """Dispatch granularity: the smallest batch size >= `chunk` this
        executor can lay out without a ragged device dimension. The
        engine sizes its fixed-shape chunks and the micro-batcher its
        flush target with this, so compiled shapes stay bucket-stable."""
        raise NotImplementedError

    # -- placement + dispatch ----------------------------------------------
    def shard(self, arrays: Sequence, n_pad: int) -> Tuple:
        """Place stacked batch arrays (leading dim = chunk) on this
        executor's devices."""
        raise NotImplementedError

    def wrap(self, solve_fn: Callable) -> Callable:
        """`(arrays, n_pad) -> result` callable dispatching `solve_fn`
        on this executor. May build jitted machinery — callers should
        reuse the wrapper (or go through `batch_callable`, which
        memoizes it) rather than re-wrapping per call."""
        def run(arrays, n_pad: int):
            return solve_fn(*self.shard(arrays, n_pad))
        return run

    def dispatch(self, solve_fn: Callable, arrays: Sequence, n_pad: int,
                 key=None):
        """Run a batched solver entry point over placed arrays.

        `key` (any hashable; defaults to `solve_fn` itself) memoizes the
        wrapped callable: callers that pass fresh lambdas MUST provide a
        stable key describing the computation — (entry point, config,
        backend) — or a sharded executor would rebuild (and recompile)
        its dispatch wrapper on every call."""
        from repro import faults
        faults.maybe_raise("executor.dispatch", executor=self.name,
                           n_pad=n_pad)
        return batch_callable(self, solve_fn if key is None else key,
                              solve_fn)(arrays, n_pad)

    # -- accounting --------------------------------------------------------
    def device_count(self) -> int:
        raise NotImplementedError

    def mesh_shape(self) -> Optional[Dict[str, int]]:
        """Axis-name -> size of the execution mesh (None when local)."""
        return None


@dataclasses.dataclass(frozen=True)
class LocalExecutor(SolveExecutor):
    """Single-device vmapped dispatch — the historical
    `solve_fixed_batch` behavior, now behind the executor contract."""

    name: str = dataclasses.field(default="local", init=False)

    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        return int(chunk)

    def shard(self, arrays, n_pad: int):
        return tuple(arrays)

    def device_count(self) -> int:
        return 1


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions (kwarg renamed check_rep ->
    check_vma when it moved to the jax namespace)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# One Mesh per (data, model) shape per process: Mesh construction is
# cheap but identity matters for jit cache reuse across executor
# instances that hash equal.
_MESH_CACHE: Dict[Tuple[int, int], Mesh] = {}


def _mesh_for(data: int, model: int) -> Mesh:
    key = (int(data), int(model))
    if key not in _MESH_CACHE:
        devs = jax.devices()
        need = key[0] * key[1]
        if need > len(devs):
            raise ValueError(
                f"ShardedExecutor mesh ({key[0]} data x {key[1]} model) "
                f"needs {need} devices but the host exposes {len(devs)} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "for a host-device mesh)")
        _MESH_CACHE[key] = Mesh(
            np.asarray(devs[:need]).reshape(key), ("data", "model"))
    return _MESH_CACHE[key]


@dataclasses.dataclass(frozen=True)
class ShardedExecutor(SolveExecutor):
    """Mesh dispatch: batch rows over "data", big systems over "model".

    `data=None` sizes the data axis to every device the host exposes
    (divided by `model`); an explicit `data` pins the mesh width (the
    scaling benchmark sweeps it). The data-axis layout dispatches
    through `shard_map` — each device runs the unpartitioned per-shard
    program, which is what makes it bit-identical to `LocalExecutor`
    (DESIGN.md §7.3).

    The system dimension only joins the "model" axis at `n_pad >=
    model_min_n`: below that, row-dimension collectives cost more than
    they parallelize. That path IS GSPMD-partitioned (the partitioner
    inserts the row-dimension collectives), so it sits outside the
    bit-parity contract — partitioning within a row changes reduction
    structure (DESIGN.md §7.2).
    """

    name: str = dataclasses.field(default="sharded", init=False)
    data: Optional[int] = None
    model: int = 1
    model_min_n: int = 1024

    # -- mesh --------------------------------------------------------------
    def data_size(self) -> int:
        if self.data is not None:
            return int(self.data)
        return max(1, jax.device_count() // int(self.model))

    def mesh(self) -> Mesh:
        return _mesh_for(self.data_size(), self.model)

    def device_count(self) -> int:
        return self.data_size() * int(self.model)

    def mesh_shape(self) -> Dict[str, int]:
        return {"data": self.data_size(), "model": int(self.model)}

    # -- chunk policy ------------------------------------------------------
    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        """Round up to a multiple of the data-axis size, so every
        device carries the same number of rows and the compiled shape
        is stable per bucket."""
        d = self.data_size()
        return max(d, -(-int(chunk) // d) * d)

    def _model_engaged(self, n_pad: int, mesh: Mesh) -> bool:
        from repro.distributed.sharding import _fit
        return (n_pad >= self.model_min_n
                and _fit(n_pad, "model", mesh) is not None)

    # -- placement ---------------------------------------------------------
    def _spec(self, shape: Tuple[int, ...], n_pad: int, mesh: Mesh) -> P:
        # Divisibility-checked axis fitting, shared with the LM
        # substrate's batch_spec rules (drop the axis rather than pad).
        from repro.distributed.sharding import _fit
        entries = [_fit(shape[0], "data", mesh)]
        entries += [None] * (len(shape) - 1)
        if len(shape) == 3 and shape[1] == n_pad \
                and self._model_engaged(n_pad, mesh):
            entries[1] = _fit(n_pad, "model", mesh)
        return P(*entries)

    def shard(self, arrays, n_pad: int):
        mesh = self.mesh()
        return tuple(
            jax.device_put(a, NamedSharding(
                mesh, self._spec(np.shape(a), n_pad, mesh)))
            for a in arrays)

    # -- dispatch ----------------------------------------------------------
    def wrap(self, solve_fn: Callable) -> Callable:
        mesh = self.mesh()
        d = self.data_size()

        @jax.jit
        def data_sharded(*arrays):
            in_specs = tuple(P("data", *([None] * (a.ndim - 1)))
                             for a in arrays)
            return _shard_map(solve_fn, mesh, in_specs, P("data"))(*arrays)

        def run(arrays, n_pad: int):
            chunk = np.shape(arrays[0])[0]
            if chunk % d:
                raise ValueError(
                    f"batch of {chunk} rows does not divide over the "
                    f"{d}-wide data axis; size batches with "
                    "preferred_chunk()")
            placed = self.shard(arrays, n_pad)
            if self._model_engaged(n_pad, mesh):
                # Huge systems: GSPMD lays rows over "model" and
                # partitions the solver body (collectives inside the
                # row). Outside the bit-parity contract by design.
                return solve_fn(*placed)
            return data_sharded(*placed)

        run._jit = data_sharded   # compile-accounting hook for tests
        return run


# ---------------------------------------------------------------------------
# Wrapped-callable memo
# ---------------------------------------------------------------------------

# (executor, key) -> wrapped batch callable. Executors are frozen
# value-hashed dataclasses, so equal executors share wrappers (and
# therefore compiled executables). Keys must uniquely describe the
# computation — callers use (entry point, solver config, backend).
_WRAPPED: Dict[tuple, Callable] = {}


def batch_callable(executor: "SolveExecutor", key,
                   solve_fn: Callable) -> Callable:
    """Memoized `executor.wrap(solve_fn)`.

    The first `solve_fn` registered for (executor, key) wins; callers
    passing fresh lambdas must ensure equal keys imply identical
    computations."""
    k = (executor, key)
    if k not in _WRAPPED:
        _WRAPPED[k] = executor.wrap(solve_fn)
        # A memo miss is the compile-cache-miss signal: each wrapper is
        # one new executable per (executor, computation key). Fail-open
        # against the process-default metrics registry (DESIGN.md §8).
        try:
            from repro.obs.metrics import default_registry
            default_registry().counter(
                "repro_executor_wrap_builds_total",
                "Wrapped batch callables built — one new compiled "
                "executable per (executor, computation key).",
                ("executor",)).labels(executor=executor.name).inc()
        except Exception:
            pass
    return _WRAPPED[k]


# ---------------------------------------------------------------------------
# Registry + selection (mirrors precision.backend)
# ---------------------------------------------------------------------------

ExecutorLike = Union[None, str, SolveExecutor]

_REGISTRY: Dict[str, Callable[[], SolveExecutor]] = {
    "local": LocalExecutor,
    "sharded": ShardedExecutor,
}
_DEFAULT: Optional[SolveExecutor] = None


def register_executor(name: str,
                      factory: Callable[[], SolveExecutor]) -> None:
    """Register an executor factory under `name` (overwrites allowed)."""
    _REGISTRY[name] = factory


def available_executors():
    return sorted(_REGISTRY)


def _from_name(name: str) -> SolveExecutor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown solve executor {name!r}; "
                       f"available: {available_executors()}")
    return _REGISTRY[name]()


def set_default_executor(executor: ExecutorLike) -> Optional[SolveExecutor]:
    """Set the process-wide default executor (None restores env/'local'
    resolution). Returns the previous override, for save/restore."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = (resolve_executor(executor)
                if executor is not None else None)
    return prev


def default_executor() -> SolveExecutor:
    if _DEFAULT is not None:
        return _DEFAULT
    return _from_name(os.environ.get(ENV_VAR, "local"))


def resolve_executor(executor: ExecutorLike = None) -> SolveExecutor:
    """Coerce an executor spec (instance | name | None=default) into an
    executor instance. Pure Python — safe to call before tracing."""
    if executor is None:
        return default_executor()
    if isinstance(executor, str):
        return _from_name(executor)
    return executor
