"""Solve executors: device placement + dispatch of fixed-shape batches
(DESIGN.md §7).

Every solve the engine or the serving micro-batcher runs is a
fixed-shape stacked batch — `(chunk, n_pad, n_pad)` matrices plus their
`(chunk, n_pad)` vectors and `(chunk, k)` action rows. A `SolveExecutor`
is the one object that owns where those arrays live and how the batched
solver executable is dispatched over them:

  * `LocalExecutor` — the historical single-device vmapped path
    (extracted from `core.batching.solve_fixed_batch`): arrays go to the
    default device, one executable per size bucket.
  * `ShardedExecutor` — a `("data", "model")` `jax.sharding.Mesh`:
    batch rows are laid over the "data" axis via `NamedSharding` on the
    stacked arrays, so one engine sweep spans every device of the mesh;
    for systems of `model_min_n` and above the system (row) dimension is
    additionally laid over "model" with the same divisibility-checked
    `_fit` rule the LM substrate uses (`distributed/sharding`). The
    chunk is auto-rounded up to a multiple of the data-axis size
    (`preferred_chunk`), so the compiled shape stays bucket-stable no
    matter how many rows a flush happens to carry.

The data-axis layout dispatches through `shard_map`: every device runs
the *unpartitioned* per-shard program on its slice of the batch. This
is what makes cross-executor bit-equality constructive — the per-row
program is byte-for-byte the local one (batched == single row results
are already pinned by the backend suite), whereas letting GSPMD
partition the solver body changes reduction lowering with the program
context (measured: a mesh shard holding one row compiles a batch-1 dot
that accumulates differently). The "model"-axis layout for huge systems
IS GSPMD-partitioned (collectives inside the row are the point there)
and sits outside the bit-parity contract — see DESIGN.md §7.2.

Executors are tiny frozen dataclasses, hashing by value like
`BlockingPolicy` and the precision backends: wrapped batch callables
are memoized per (executor, computation key) — `batch_callable` — so
switching executors costs exactly one extra executable per bucket while
the format ids stay runtime data (the §3.4 invariant is untouched), and
equal-valued executors share executables. Cross-executor SolveRecord
bit-equality is asserted by `tests/test_executor.py` on a forced
8-device host mesh.

Compile-cliff control (DESIGN.md §12): solver entry points arrive as
`LowerableCall`s — the module-level jitted function plus its hashable
static kwargs, with the eager carrier coercion split out — so the
dispatchers hold a per-shape cache of AOT-compiled executables
(`lower().compile()`). Every call, cold or warmed, routes through the
same `Compiled` object for its shape; `precompile()` merely builds it
early, which is what makes warm-vs-cold bit-identity hold by
construction. The computation key is derived from the `LowerableCall`
value, so two tasks running the identical program share one dispatcher
and one executable per shape.

This module is solver-free (the engine and serving stack import it);
selection mirrors the precision backends: explicit argument >
`set_default_executor` > ``REPRO_SOLVE_EXECUTOR`` env var > ``"local"``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ENV_VAR = "REPRO_SOLVE_EXECUTOR"


@dataclasses.dataclass(frozen=True)
class LowerableCall:
    """A batched solver entry point in AOT-compilable form (DESIGN.md §12).

    `jitted` is the module-level `jax.jit`-wrapped function and
    `statics` its hashable static kwargs — together they are the
    computation identity (`computation_key`): two tasks built over the
    same solver config and backend produce equal `LowerableCall`s and
    therefore share one wrapped dispatcher and one executable per
    shape, across tasks.

    `prepare` is the eager per-call coercion the plain entry point runs
    outside the jit boundary (device transfer + carrier-dtype cast). It
    must be fully determined by (jitted, statics) — it is excluded from
    equality/hash on purpose, so closure identity cannot split the
    memo.
    """
    jitted: Any
    statics: Tuple[Tuple[str, Any], ...] = ()
    prepare: Optional[Callable] = dataclasses.field(
        default=None, compare=False)

    def bind(self, arrays: Sequence) -> Tuple:
        """Apply the eager coercion: the arrays actually traced/run."""
        if self.prepare is None:
            return tuple(arrays)
        return tuple(self.prepare(*arrays))

    def __call__(self, *arrays):
        return self.jitted(*self.bind(arrays), **dict(self.statics))

    def lower(self, args: Sequence):
        """Lower against already-bound arrays (or ShapeDtypeStructs)."""
        return self.jitted.lower(*args, **dict(self.statics))


def computation_key(solve_fn: Callable, key=None):
    """Canonical memo key for a batched computation.

    An explicit `key` wins (legacy call sites). A `LowerableCall` keys
    by (jitted entry point, static kwargs) — its computation identity —
    so distinct task objects running the same program collapse onto one
    dispatcher and one executable per shape. Anything else keys by the
    callable itself."""
    if key is not None:
        return key
    if isinstance(solve_fn, LowerableCall):
        return (solve_fn.jitted, solve_fn.statics)
    return solve_fn


# Process-wide executable-build accounting (DESIGN.md §12): every
# `lower().compile()` a dispatcher runs is appended here, whether it
# came from AOT warmup or a lazy first hit. The persistent compilation
# cache can serve the *XLA* work from disk — that still counts as one
# in-process build; `repro.core.aot.cache_stats()` tracks disk
# hits/misses separately (those are what "zero fresh compiles on warm
# restart" is asserted on).
_COMPILE_LOG: List[dict] = []
_COMPILE_LOCK = threading.Lock()

_COMPILE_SECONDS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
                            30.0, 60.0, 120.0)


def executor_compile_count() -> int:
    """Executables built in-process so far (all executors)."""
    return len(_COMPILE_LOG)


def executor_compile_log() -> List[dict]:
    """Copies of the per-build records: executor, bucket, rows,
    backend, seconds."""
    with _COMPILE_LOCK:
        return [dict(r) for r in _COMPILE_LOG]


def _backend_label(solve_fn) -> str:
    if isinstance(solve_fn, LowerableCall):
        for k, v in solve_fn.statics:
            if k == "backend":
                return str(getattr(v, "name", v))
    return "unknown"


def _record_compile(executor_name: str, solve_fn, n_pad: int, rows: int,
                    seconds: float) -> None:
    with _COMPILE_LOCK:
        _COMPILE_LOG.append({"executor": executor_name,
                             "bucket": int(n_pad), "rows": int(rows),
                             "backend": _backend_label(solve_fn),
                             "seconds": float(seconds)})
    # Fail-open against the process-default metrics registry
    # (DESIGN.md §8) — compile accounting must never break a solve.
    try:
        from repro.obs.metrics import default_registry
        reg = default_registry()
        reg.histogram(
            "repro_compile_seconds",
            "Wall seconds building one XLA executable (lower+compile) "
            "per size bucket and precision backend.",
            ("bucket", "backend"),
            buckets=_COMPILE_SECONDS_BUCKETS).labels(
                bucket=n_pad,
                backend=_backend_label(solve_fn)).observe(seconds)
        reg.counter(
            "repro_executor_compiles_total",
            "XLA executables built in-process by the per-shape compile "
            "cache (AOT warmup and lazy first hits both count).",
            ("executor",)).labels(executor=executor_name).inc()
    except Exception:
        pass


class SolveExecutor:
    """Interface shared by all solve executors (duck-typed; this base
    class documents the contract and hosts shared helpers)."""

    name: str = "abstract"

    # -- chunk policy ------------------------------------------------------
    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        """Dispatch granularity: the smallest batch size >= `chunk` this
        executor can lay out without a ragged device dimension. The
        engine sizes its fixed-shape chunks and the micro-batcher its
        flush target with this, so compiled shapes stay bucket-stable."""
        raise NotImplementedError

    # -- placement + dispatch ----------------------------------------------
    def shard(self, arrays: Sequence, n_pad: int) -> Tuple:
        """Place stacked batch arrays (leading dim = chunk) on this
        executor's devices."""
        raise NotImplementedError

    def wrap(self, solve_fn: Callable) -> Callable:
        """`(arrays, n_pad) -> result` dispatcher for `solve_fn` on this
        executor — a `_DirectDispatch` holding the per-shape compiled
        executable cache. May build jitted machinery; callers should
        reuse the wrapper (or go through `batch_callable`, which
        memoizes it) rather than re-wrapping per call."""
        return _DirectDispatch(self, solve_fn)

    def dispatch(self, solve_fn: Callable, arrays: Sequence, n_pad: int,
                 key=None):
        """Run a batched solver entry point over placed arrays.

        The wrapped dispatcher is memoized per (executor, computation
        key); `LowerableCall`s key themselves by value. Callers passing
        plain fresh lambdas MUST provide a stable `key` describing the
        computation — (entry point, config, backend) — or a sharded
        executor would rebuild (and recompile) its dispatch wrapper on
        every call."""
        from repro import faults
        faults.maybe_raise("executor.dispatch", executor=self.name,
                           n_pad=n_pad)
        return batch_callable(self, key, solve_fn)(arrays, n_pad)

    def precompile(self, solve_fn: Callable, arrays: Sequence,
                   n_pad: int, key=None) -> bool:
        """AOT-build the executable the first `dispatch` of these shapes
        would otherwise compile lazily (DESIGN.md §12). Goes through the
        same `batch_callable` memo, so a later live call finds both the
        wrapper and the per-shape executable warm. Returns True when an
        executable now exists for the shapes (False: no AOT form, the
        shape compiles on first hit exactly as before)."""
        wrapped = batch_callable(self, key, solve_fn)
        pre = getattr(wrapped, "precompile", None)
        if pre is None:          # custom executor with a plain closure
            return False
        return bool(pre(arrays, n_pad))

    # -- accounting --------------------------------------------------------
    def device_count(self) -> int:
        raise NotImplementedError

    def mesh_shape(self) -> Optional[Dict[str, int]]:
        """Axis-name -> size of the execution mesh (None when local)."""
        return None


@dataclasses.dataclass(frozen=True)
class LocalExecutor(SolveExecutor):
    """Single-device vmapped dispatch — the historical
    `solve_fixed_batch` behavior, now behind the executor contract."""

    name: str = dataclasses.field(default="local", init=False)

    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        return int(chunk)

    def shard(self, arrays, n_pad: int):
        return tuple(arrays)

    def device_count(self) -> int:
        return 1


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions (kwarg renamed check_rep ->
    check_vma when it moved to the jax namespace)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# One Mesh per (data, model) shape per process: Mesh construction is
# cheap but identity matters for jit cache reuse across executor
# instances that hash equal.
_MESH_CACHE: Dict[Tuple[int, int], Mesh] = {}


def _mesh_for(data: int, model: int) -> Mesh:
    key = (int(data), int(model))
    if key not in _MESH_CACHE:
        devs = jax.devices()
        need = key[0] * key[1]
        if need > len(devs):
            raise ValueError(
                f"ShardedExecutor mesh ({key[0]} data x {key[1]} model) "
                f"needs {need} devices but the host exposes {len(devs)} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "for a host-device mesh)")
        _MESH_CACHE[key] = Mesh(
            np.asarray(devs[:need]).reshape(key), ("data", "model"))
    return _MESH_CACHE[key]


@dataclasses.dataclass(frozen=True)
class ShardedExecutor(SolveExecutor):
    """Mesh dispatch: batch rows over "data", big systems over "model".

    `data=None` sizes the data axis to every device the host exposes
    (divided by `model`); an explicit `data` pins the mesh width (the
    scaling benchmark sweeps it). The data-axis layout dispatches
    through `shard_map` — each device runs the unpartitioned per-shard
    program, which is what makes it bit-identical to `LocalExecutor`
    (DESIGN.md §7.3).

    The system dimension only joins the "model" axis at `n_pad >=
    model_min_n`: below that, row-dimension collectives cost more than
    they parallelize. That path IS GSPMD-partitioned (the partitioner
    inserts the row-dimension collectives), so it sits outside the
    bit-parity contract — partitioning within a row changes reduction
    structure (DESIGN.md §7.2).
    """

    name: str = dataclasses.field(default="sharded", init=False)
    data: Optional[int] = None
    model: int = 1
    model_min_n: int = 1024

    # -- mesh --------------------------------------------------------------
    def data_size(self) -> int:
        if self.data is not None:
            return int(self.data)
        return max(1, jax.device_count() // int(self.model))

    def mesh(self) -> Mesh:
        return _mesh_for(self.data_size(), self.model)

    def device_count(self) -> int:
        return self.data_size() * int(self.model)

    def mesh_shape(self) -> Dict[str, int]:
        return {"data": self.data_size(), "model": int(self.model)}

    # -- chunk policy ------------------------------------------------------
    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        """Round up to a multiple of the data-axis size, so every
        device carries the same number of rows and the compiled shape
        is stable per bucket."""
        d = self.data_size()
        return max(d, -(-int(chunk) // d) * d)

    def _model_engaged(self, n_pad: int, mesh: Mesh) -> bool:
        from repro.distributed.sharding import _fit
        return (n_pad >= self.model_min_n
                and _fit(n_pad, "model", mesh) is not None)

    # -- placement ---------------------------------------------------------
    def _spec(self, shape: Tuple[int, ...], n_pad: int, mesh: Mesh) -> P:
        # Divisibility-checked axis fitting, shared with the LM
        # substrate's batch_spec rules (drop the axis rather than pad).
        from repro.distributed.sharding import _fit
        entries = [_fit(shape[0], "data", mesh)]
        entries += [None] * (len(shape) - 1)
        if len(shape) == 3 and shape[1] == n_pad \
                and self._model_engaged(n_pad, mesh):
            entries[1] = _fit(n_pad, "model", mesh)
        return P(*entries)

    def shard(self, arrays, n_pad: int):
        mesh = self.mesh()
        return tuple(
            jax.device_put(a, NamedSharding(
                mesh, self._spec(np.shape(a), n_pad, mesh)))
            for a in arrays)

    # -- dispatch ----------------------------------------------------------
    def wrap(self, solve_fn: Callable) -> Callable:
        return _MeshDispatch(self, solve_fn)


# ---------------------------------------------------------------------------
# Dispatchers: per-shape compiled-executable caches (DESIGN.md §12)
# ---------------------------------------------------------------------------


class _BatchDispatch:
    """Memoized `(arrays, n_pad) -> result` dispatcher with a per-shape
    cache of AOT-compiled executables.

    Every call — cold first hit or AOT-warmed — routes through the same
    `Compiled` object for its shapes, so warmup cannot change numerics:
    there is exactly one executable per (computation key, shapes), and
    `precompile()` merely builds it early. The lock makes the build
    safe against a background warmup thread racing a live solve."""

    def __init__(self, executor: "SolveExecutor", solve_fn: Callable):
        self.executor = executor
        self.solve_fn = solve_fn
        self.executables: Dict[tuple, Any] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _shape_key(args) -> tuple:
        return tuple(
            (tuple(int(d) for d in np.shape(a)),
             str(getattr(a, "dtype", None) or np.asarray(a).dtype))
            for a in args)

    def _lowered(self, args):
        raise NotImplementedError

    def _executable(self, args, n_pad: int):
        key = self._shape_key(args)
        exe = self.executables.get(key)
        if exe is not None:
            return exe
        with self._lock:
            exe = self.executables.get(key)
            if exe is None:
                t0 = time.perf_counter()
                exe = self._lowered(args).compile()
                rows = int(np.shape(args[0])[0]) if np.ndim(args[0]) else 0
                _record_compile(self.executor.name, self.solve_fn,
                                n_pad, rows, time.perf_counter() - t0)
                self.executables[key] = exe
        return exe

    def precompile(self, arrays: Sequence, n_pad: int) -> bool:
        raise NotImplementedError


class _DirectDispatch(_BatchDispatch):
    """Placement + direct dispatch (LocalExecutor, custom executors).
    `LowerableCall` solve_fns route through the per-shape compiled
    cache; plain callables keep the historical direct-call path (their
    own jit owns compilation, nothing to AOT)."""

    def _args(self, arrays, n_pad: int):
        return self.solve_fn.bind(self.executor.shard(arrays, n_pad))

    def _lowered(self, args):
        return self.solve_fn.lower(args)

    def __call__(self, arrays, n_pad: int):
        if not isinstance(self.solve_fn, LowerableCall):
            return self.solve_fn(*self.executor.shard(arrays, n_pad))
        args = self._args(arrays, n_pad)
        return self._executable(args, n_pad)(*args)

    def precompile(self, arrays, n_pad: int) -> bool:
        if not isinstance(self.solve_fn, LowerableCall):
            return False
        self._executable(self._args(arrays, n_pad), n_pad)
        return True


class _MeshDispatch(_BatchDispatch):
    """Mesh dispatch (ShardedExecutor): the data-axis shard_map program
    is jitted once per dispatcher and AOT-compiled per shape. Any
    solve_fn works — shard_map traces it — so the sharded grid
    precompiles even for plain callables. A `LowerableCall`'s eager
    coercion is traced *inside* the per-shard program, exactly where
    the plain entry point ran it before, keeping the per-shard jaxpr
    (and therefore the §7.3 bit-parity contract) unchanged. The GSPMD
    "model" path keeps the direct call: it is outside the bit-parity
    contract by design (DESIGN.md §7.2)."""

    def __init__(self, executor: "ShardedExecutor", solve_fn: Callable):
        super().__init__(executor, solve_fn)
        self._mesh = executor.mesh()
        self._d = executor.data_size()
        if isinstance(solve_fn, LowerableCall):
            jitted, prep = solve_fn.jitted, solve_fn.prepare
            statics = dict(solve_fn.statics)

            def fn(*arrays):
                bound = prep(*arrays) if prep is not None else arrays
                return jitted(*bound, **statics)
        else:
            fn = solve_fn
        self._fn = fn
        mesh = self._mesh

        @jax.jit
        def data_sharded(*arrays):
            in_specs = tuple(P("data", *([None] * (a.ndim - 1)))
                             for a in arrays)
            return _shard_map(fn, mesh, in_specs, P("data"))(*arrays)

        self._jit = data_sharded   # compile-accounting hook for tests

    def _lowered(self, args):
        return self._jit.lower(*args)

    def _placed(self, arrays, n_pad: int):
        chunk = np.shape(arrays[0])[0]
        if chunk % self._d:
            raise ValueError(
                f"batch of {chunk} rows does not divide over the "
                f"{self._d}-wide data axis; size batches with "
                "preferred_chunk()")
        return self.executor.shard(arrays, n_pad)

    def __call__(self, arrays, n_pad: int):
        placed = self._placed(arrays, n_pad)
        if self.executor._model_engaged(n_pad, self._mesh):
            # Huge systems: GSPMD lays rows over "model" and partitions
            # the solver body (collectives inside the row). Outside the
            # bit-parity contract by design.
            return self._fn(*placed)
        return self._executable(placed, n_pad)(*placed)

    def precompile(self, arrays, n_pad: int) -> bool:
        placed = self._placed(arrays, n_pad)
        if self.executor._model_engaged(n_pad, self._mesh):
            return False       # the model path compiles via its own jit
        self._executable(placed, n_pad)
        return True


# ---------------------------------------------------------------------------
# Wrapped-callable memo
# ---------------------------------------------------------------------------

# (executor, computation key) -> wrapped batch dispatcher. Executors
# are frozen value-hashed dataclasses, so equal executors share
# dispatchers (and therefore compiled executables). `LowerableCall`s
# key by value — (jitted entry point, statics) — which is what dedupes
# executable builds across tasks running the same program; plain
# callers must pass a stable explicit key.
_WRAPPED: Dict[tuple, Callable] = {}
_WRAPPED_LOCK = threading.RLock()


def batch_callable(executor: "SolveExecutor", key,
                   solve_fn: Callable) -> Callable:
    """Memoized `executor.wrap(solve_fn)`, keyed by `computation_key`.

    The first `solve_fn` registered for (executor, key) wins; callers
    passing fresh lambdas must ensure equal keys imply identical
    computations. Thread-safe: a background AOT warmup sweep and a live
    solve may race to build the same wrapper (DESIGN.md §12)."""
    k = (executor, computation_key(solve_fn, key))
    with _WRAPPED_LOCK:
        if k not in _WRAPPED:
            _WRAPPED[k] = executor.wrap(solve_fn)
            # A memo miss means a new dispatcher: at least one new
            # executable per (executor, computation key). Fail-open
            # against the process-default registry (DESIGN.md §8).
            try:
                from repro.obs.metrics import default_registry
                default_registry().counter(
                    "repro_executor_wrap_builds_total",
                    "Wrapped batch dispatchers built — one per "
                    "(executor, computation key).",
                    ("executor",)).labels(executor=executor.name).inc()
            except Exception:
                pass
        return _WRAPPED[k]


# ---------------------------------------------------------------------------
# Registry + selection (mirrors precision.backend)
# ---------------------------------------------------------------------------

ExecutorLike = Union[None, str, SolveExecutor]

_REGISTRY: Dict[str, Callable[[], SolveExecutor]] = {
    "local": LocalExecutor,
    "sharded": ShardedExecutor,
}
_DEFAULT: Optional[SolveExecutor] = None


def register_executor(name: str,
                      factory: Callable[[], SolveExecutor]) -> None:
    """Register an executor factory under `name` (overwrites allowed)."""
    _REGISTRY[name] = factory


def available_executors():
    return sorted(_REGISTRY)


def _from_name(name: str) -> SolveExecutor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown solve executor {name!r}; "
                       f"available: {available_executors()}")
    return _REGISTRY[name]()


def set_default_executor(executor: ExecutorLike) -> Optional[SolveExecutor]:
    """Set the process-wide default executor (None restores env/'local'
    resolution). Returns the previous override, for save/restore."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = (resolve_executor(executor)
                if executor is not None else None)
    return prev


def default_executor() -> SolveExecutor:
    if _DEFAULT is not None:
        return _DEFAULT
    return _from_name(os.environ.get(ENV_VAR, "local"))


def resolve_executor(executor: ExecutorLike = None) -> SolveExecutor:
    """Coerce an executor spec (instance | name | None=default) into an
    executor instance. Pure Python — safe to call before tracing."""
    if executor is None:
        return default_executor()
    if isinstance(executor, str):
        return _from_name(executor)
    return executor
