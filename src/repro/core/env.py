"""Deprecated GMRES-IR environment — thin shim over the TunableTask API.

`GMRESIREnv` predates the solver-agnostic redesign: it was a GMRES-only
fusion of what is now `tasks.gmres_ir.GMRESIRTask` (the algorithm) and
`core.engine.AutotuneEngine` (the cache + learning loop). It survives as
an engine subclass so historical call sites — `GMRESIREnv(systems,
space, ir_cfg)` into `train_policy` / `PolicyRegistry.warm_start` — keep
working bit-for-bit. New code should build a task directly:

    task = GMRESIRTask(systems, space, ir_cfg)       # repro.tasks
    policy, hist = train_policy(task, reward_cfg)    # same trainer
"""
from __future__ import annotations

from typing import Sequence

from repro.core.action_space import ActionSpace
from repro.core.engine import AutotuneEngine
from repro.core.rewards import RewardConfig
from repro.core.task import Outcome
from repro.data.matrices import LinearSystem


class GMRESIREnv(AutotuneEngine):
    def __init__(self, systems: Sequence[LinearSystem],
                 action_space: ActionSpace, ir_cfg,
                 chunk: int = 32, bucket_step: int = 128):
        # Deferred import keeps `repro.core` importable before
        # `repro.tasks` finishes initializing (and vice versa).
        from repro.tasks.gmres_ir import GMRESIRTask
        task = GMRESIRTask(systems, action_space, ir_cfg,
                           bucket_step=bucket_step)
        super().__init__(task, chunk=chunk)
        self.ir_cfg = ir_cfg

    # -- legacy accessors --------------------------------------------------
    @property
    def systems(self):
        return self.task.instances

    def record(self, i: int, a: int) -> Outcome:
        """Legacy name for `outcome` (the Outcome's metrics are readable
        as attributes, matching the old SolveRecord fields)."""
        return self.outcome(i, a)

    def reward(self, i: int, a: int, cfg: RewardConfig) -> float:
        return super().reward(i, a, cfg)
