"""Deterministic GMRES-IR environment with batched, memoized solves.

The environment is a pure function of (system, action): rewards carry no
noise beyond the solver itself, so every solve is cached and each episode
sweep batches its cache misses into fixed-shape vmapped `gmres_ir_batch`
calls (one compile per size bucket). This is the framework-scale reading of
the paper: the env evaluation is the compute-heavy, embarrassingly-parallel
part — it batches over instances on one host and shards over the (instance x
action) grid across pods — while the bandit update itself is trivial.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.batching import (SolveRecord, bucket_of, solve_fixed_batch)
from repro.core.features import feature_vector
from repro.core.rewards import RewardConfig, reward as reward_fn
from repro.data.matrices import LinearSystem, pad_system
from repro.solvers.ir import IRConfig


class GMRESIREnv:
    def __init__(self, systems: Sequence[LinearSystem],
                 action_space: ActionSpace, ir_cfg: IRConfig,
                 chunk: int = 32, bucket_step: int = 128):
        self.systems = list(systems)
        self.action_space = action_space
        self.ir_cfg = ir_cfg
        self.chunk = chunk
        self.kappas = np.array([s.features["kappa_est"] for s in systems])
        self.features = np.stack([feature_vector(s.features)
                                  for s in systems])
        self._buckets = [bucket_of(s.n, bucket_step) for s in systems]
        self._padded = {}      # sys_idx -> (A, b, x) padded numpy
        self._cache: Dict[Tuple[int, int], SolveRecord] = {}
        self.n_solves = 0      # actual solver invocations (incl. chunk pad)
        self.n_requests = 0    # reward lookups

    # ------------------------------------------------------------------ --
    def _get_padded(self, i: int):
        if i not in self._padded:
            self._padded[i] = pad_system(self.systems[i], self._buckets[i])
        return self._padded[i]

    def solve_pairs(self, pairs: Iterable[Tuple[int, int]]) -> None:
        """Batch-solve all uncached (system, action) pairs."""
        miss = sorted({p for p in pairs if p not in self._cache})
        if not miss:
            return
        by_bucket: Dict[int, List[Tuple[int, int]]] = {}
        for p in miss:
            by_bucket.setdefault(self._buckets[p[0]], []).append(p)
        for bucket, plist in by_bucket.items():
            for c0 in range(0, len(plist), self.chunk):
                chunk_pairs = plist[c0:c0 + self.chunk]
                recs = solve_fixed_batch(
                    [self._get_padded(i)[0] for i, _ in chunk_pairs],
                    [self._get_padded(i)[1] for i, _ in chunk_pairs],
                    [self._get_padded(i)[2] for i, _ in chunk_pairs],
                    [self.action_space.actions[a] for _, a in chunk_pairs],
                    self.ir_cfg, self.chunk)
                self.n_solves += self.chunk
                for p, rec in zip(chunk_pairs, recs):
                    self._cache[p] = rec

    def record(self, i: int, a: int) -> SolveRecord:
        if (i, a) not in self._cache:
            self.solve_pairs([(i, a)])
        return self._cache[(i, a)]

    def reward(self, i: int, a: int, cfg: RewardConfig) -> float:
        """Eq. 21 reward for applying action a to system i."""
        self.n_requests += 1
        rec = self.record(i, a)
        return reward_fn(rec.ferr, rec.nbe, rec.n_gmres, rec.status,
                         self.action_space.actions[a], self.kappas[i], cfg)

    def prefill_all(self) -> None:
        """Exhaustive (instance x action) sweep — the multi-pod work grid."""
        pairs = [(i, a) for i in range(len(self.systems))
                 for a in range(self.action_space.n_actions)]
        self.solve_pairs(pairs)

    @property
    def cache_size(self) -> int:
        return len(self._cache)
