"""Shared size-bucketing / padding / fixed-shape batch-solve layer.

The GMRES-IR task (`tasks.gmres_ir.GMRESIRTask`, and through it both the
offline `AutotuneEngine` and the online serving micro-batcher) funnels
solves through this module: systems are identity-padded to a size bucket
(solution preserving, see `data.matrices.pad_system`), stacked into
fixed-shape (chunk, n_pad, n_pad) batches — short batches are padded by
repeating row 0 — and executed with one `gmres_ir_batch` call. Because
every batch for a given (bucket, chunk) pair has the same shape, XLA
compiles each bucket exactly once per process, no matter how many
batches flow through it. That single-executable property extends to
the blocked factorization/substitution path: `ir_cfg.blocking`
(DESIGN.md §6.4) is part of the static config, so buckets at or above
its threshold compile the blocked LU + trisolve variant — once, with
the format ids still runtime data — and smaller buckets the strict
row-loop variant, on either precision backend.

`bucket_of` itself lives in the solver-free `core.task` module (the
engine buckets work without knowing any solver) and is re-exported here
for backward compatibility. Device placement and dispatch moved to
`core.executor` (DESIGN.md §7): `solve_fixed_batch` is now a thin shim
that stacks rows and hands the fixed-shape batch to a `SolveExecutor`
(single-device vmapped by default, mesh-sharded on request), kept for
the pre-executor call sites.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import numpy as np

from repro.core.executor import resolve_executor
from repro.core.task import bucket_of
from repro.data.matrices import LinearSystem, pad_system
from repro.solvers.ir import IRConfig, gmres_ir_batch_lowerable

__all__ = ["SolveRecord", "bucket_of", "pad_to_bucket",
           "records_from_stats", "solve_fixed_batch"]


@dataclasses.dataclass
class SolveRecord:
    """Host-side scalar outcome of one (system, action) GMRES-IR solve."""
    ferr: float
    nbe: float
    n_outer: int
    n_gmres: int
    status: int
    res_norm: float


def pad_to_bucket(system: LinearSystem, bucket_step: int = 128,
                  minimum: int = 128):
    """(A, b, x) identity-padded to the system's size bucket."""
    return pad_system(system, bucket_of(system.n, bucket_step, minimum))


def records_from_stats(stats, count: int) -> List[SolveRecord]:
    """First `count` rows of a batched SolveStats as host SolveRecords.

    The whole stats tuple comes to the host in ONE `jax.device_get`
    (six per-field transfers would mean six device->host round trips —
    and six cross-device gathers once the stats live on a mesh)."""
    ferr, nbe, n_outer, n_gmres, status, res = (
        np.asarray(f) for f in jax.device_get(tuple(stats)))
    return [SolveRecord(float(ferr[j]), float(nbe[j]), int(n_outer[j]),
                        int(n_gmres[j]), int(status[j]), float(res[j]))
            for j in range(count)]


def solve_fixed_batch(A_rows: Sequence[np.ndarray],
                      b_rows: Sequence[np.ndarray],
                      x_rows: Sequence[np.ndarray],
                      action_rows: Sequence[np.ndarray],
                      ir_cfg: IRConfig, chunk: int,
                      backend=None, executor=None) -> List[SolveRecord]:
    """One fixed-shape `gmres_ir_batch` dispatch over already-padded rows.

    All rows must share one padded size n_pad; the batch dimension is
    padded to exactly the executor's `preferred_chunk(chunk)` rows by
    repeating row 0, keeping the compiled shape constant. Returns one
    SolveRecord per *input* row (pad rows dropped). `backend` selects
    the precision backend (DESIGN.md §6); the solver entry point coerces
    rows to the backend's carrier dtype. `executor` selects device
    placement (DESIGN.md §7): None/"local" is the historical
    single-device vmapped path, "sharded" lays the batch over a device
    mesh. Buckets at or above `ir_cfg.blocking.min_n` run the blocked
    LU + trisolve hot path (DESIGN.md §6.4) inside the same vmapped
    executable.
    """
    from repro.precision import resolve_backend
    from repro.tasks.base import stack_fixed
    ex = resolve_executor(executor)
    bk = resolve_backend(backend)
    A, b, x, acts, k = stack_fixed(list(zip(A_rows, b_rows, x_rows)),
                                   action_rows, ex.preferred_chunk(chunk))
    # The solver rides as a `LowerableCall`, which both keys the
    # dispatcher memo by computation value — every call site with equal
    # (cfg, backend) shares one executable per shape, across tasks —
    # and lets AOT warmup precompile the very executable this dispatch
    # will run (DESIGN.md §12).
    stats = ex.dispatch(gmres_ir_batch_lowerable(ir_cfg, bk),
                        (A, b, x, acts), A.shape[-1])
    return records_from_stats(stats, k)
