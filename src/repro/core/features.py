"""Context features for problem instances (paper §4.2 / Eq. 18).

The paper's state is s = [log10(max(kappa(A), d_c)), log10(max(||A||_inf,
d_n))], with kappa obtained "via an efficient algorithm (e.g. Hager-Higham)".
We implement the Hager–Higham 1-norm condition estimator honestly: a few
LU-backed solves with A and A^T, never an SVD. Extra features (sparsity,
diagonal dominance) are provided for the feature-saliency studies the paper
proposes (§6) and for the LM-integration context.

These run at data-ingest time on the host (numpy/scipy), matching the
paper's "cheap features before solving" deployment model; a jnp variant of
the norm features is exposed for in-graph use.
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import scipy.linalg as sla

DELTA_C = 1.0   # paper's delta_c (floor inside the log for kappa)
DELTA_N = 1e-30  # paper's delta_n (floor inside the log for the norm)


def condest_hager(A: np.ndarray, lu_piv=None, maxiter: int = 5) -> float:
    """Hager–Higham estimate of ||A^{-1}||_1 * ||A||_1 (1-norm condition).

    Uses LU solves only — O(n^2) per iteration after one O(n^3)
    factorization, the classical condest cost model.
    """
    n = A.shape[0]
    if lu_piv is None:
        lu_piv = sla.lu_factor(A)
    solve = lambda v: sla.lu_solve(lu_piv, v, trans=0)
    solve_t = lambda v: sla.lu_solve(lu_piv, v, trans=1)

    x = np.full(n, 1.0 / n)
    est = 0.0
    for _ in range(maxiter):
        y = solve(x)
        est_new = np.sum(np.abs(y))
        xi = np.sign(y)
        xi[xi == 0] = 1.0
        z = solve_t(xi)
        j = int(np.argmax(np.abs(z)))
        if np.abs(z[j]) <= z @ x and est_new >= est:
            est = max(est, est_new)
            break
        est = max(est, est_new)
        x = np.zeros(n)
        x[j] = 1.0
    norm1 = np.max(np.sum(np.abs(A), axis=0))
    return float(est * norm1)


def inf_norm(A: np.ndarray) -> float:
    return float(np.max(np.sum(np.abs(A), axis=1)))


def sparsity(A: np.ndarray, tol: float = 0.0) -> float:
    """Fraction of (near-)zero entries."""
    return float(np.mean(np.abs(A) <= tol))


def diag_dominance(A: np.ndarray) -> float:
    """min_i |a_ii| / sum_{j != i} |a_ij| (clipped to [0, 10])."""
    d = np.abs(np.diag(A))
    off = np.sum(np.abs(A), axis=1) - d
    ratio = d / np.where(off == 0, 1.0, off)
    return float(np.clip(np.min(ratio), 0.0, 10.0))


def system_features(A: np.ndarray, lu_piv=None) -> Dict[str, float]:
    """All features for one system. The two paper features come first."""
    kappa = condest_hager(A, lu_piv)
    return {
        "log_kappa": float(np.log10(max(kappa, DELTA_C))),
        "log_norm": float(np.log10(max(inf_norm(A), DELTA_N))),
        "kappa_est": kappa,
        "norm_inf": inf_norm(A),
        "sparsity": sparsity(A),
        "diag_dominance": diag_dominance(A),
    }


PAPER_FEATURES = ("log_kappa", "log_norm")


def feature_vector(feats: Dict[str, float],
                   names=PAPER_FEATURES) -> np.ndarray:
    return np.array([feats[n] for n in names], dtype=np.float64)
