"""Multi-objective reward (paper Eq. 21-25).

  R(s, a) = w2 * f_precision + w1 * f_accuracy - w3 * f_penalty

f_precision (Eq. 22): rewards fewer significand bits, damped by log10(kappa)
— at high condition numbers the incentive to go low-precision shrinks.
f_accuracy (Eq. 24): -C1 (min(log10 max(ferr, eps), theta)
                          + min(log10 max(nbe, eps), theta)).
f_penalty (Eq. 25): log2(max(T_iter, 1)) with T_iter = total inner GMRES
iterations; `use_penalty=False` reproduces the Table 6 ablation.

Failure (LU overflow / non-finite solve) maps to a flat `fail_reward` — the
paper folds failures into the penalty; a flat floor keeps the Q-update
bounded.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.precision import FORMAT_LIST, FORMATS
from repro.solvers.ir import FAILED

_T_BITS = np.array([f.t for f in FORMAT_LIST], dtype=np.float64)
_T_FP64 = float(FORMATS["fp64"].t)


@dataclasses.dataclass(frozen=True)
class RewardConfig:
    w1: float = 1.0           # accuracy weight
    w2: float = 0.1           # precision weight
    w3: float = 1.0           # iteration-penalty weight
    C1: float = 1.0
    theta: float = 2.5
    eps: float = 1e-10
    use_penalty: bool = True
    fail_reward: float = -30.0


# The paper's two weight settings (§5.1).
W1 = RewardConfig(w1=1.0, w2=0.1)
W2 = RewardConfig(w1=1.0, w2=1.0)


def precision_term(action_fmt_ids: np.ndarray, kappa: float) -> float:
    """Eq. 22: sum over steps of t_FP64 / (t_p (1 + log10 max(kappa, 1)))."""
    t_p = _T_BITS[np.asarray(action_fmt_ids)]
    damp = 1.0 + np.log10(max(float(kappa), 1.0))
    return float(np.sum(_T_FP64 / (t_p * damp)))


def accuracy_term(ferr: float, nbe: float, cfg: RewardConfig) -> float:
    """Eq. 24 (inf-safe: log10(inf) caps at theta)."""
    def capped_log(v):
        v = max(float(v), cfg.eps)
        lg = np.log10(v) if np.isfinite(v) else np.inf
        return min(lg, cfg.theta)
    return -cfg.C1 * (capped_log(ferr) + capped_log(nbe))


def penalty_term(n_gmres_total: int) -> float:
    """Eq. 25 on total inner GMRES iterations."""
    return float(np.log2(max(int(n_gmres_total), 1)))


def reward(ferr: float, nbe: float, n_gmres: int, status: int,
           action_fmt_ids: np.ndarray, kappa: float,
           cfg: RewardConfig) -> float:
    """Eq. 21 for one (system, action) outcome.

    NaN measurements (a poisoned solve: fault injection, accelerator
    NaN-propagation) yield a NaN reward rather than raising — the
    serving path quarantines non-finite rewards away from the Q-table
    (DESIGN.md §11.2), and `int(nan)` in the penalty would otherwise
    crash the completion loop. Infs stay on the existing inf-safe path
    (capped logs). FAILED outcomes keep the flat floor.
    """
    if int(status) == FAILED:
        return cfg.fail_reward
    if any(math.isnan(float(v)) for v in (ferr, nbe, n_gmres)):
        return float("nan")
    r = (cfg.w2 * precision_term(action_fmt_ids, kappa)
         + cfg.w1 * accuracy_term(ferr, nbe, cfg))
    if cfg.use_penalty:
        r -= cfg.w3 * penalty_term(n_gmres)
    return float(r)


def reward_batch(ferr, nbe, n_gmres, status, actions_fmt_ids, kappas,
                 cfg: RewardConfig) -> np.ndarray:
    return np.array([
        reward(f, b, g, s, a, k, cfg)
        for f, b, g, s, a, k in zip(np.asarray(ferr), np.asarray(nbe),
                                    np.asarray(n_gmres), np.asarray(status),
                                    np.asarray(actions_fmt_ids),
                                    np.asarray(kappas))
    ])
