"""The paper's primary contribution: contextual-bandit precision autotuning.

Exports the general framework (action space, discretizer, rewards, tabular
bandit, policy) and the GMRES-IR instantiation (env + train/evaluate)."""
from .action_space import (ActionSpace, full_action_space, is_monotone,
                           reduced_action_space, reduced_size)
from .autotune import (TrainConfig, TrainHistory, evaluate_fixed_action,
                       evaluate_policy, train_policy)
from .bandit import QTable, epsilon_schedule
from .batching import (SolveRecord, bucket_of, pad_to_bucket,
                       records_from_stats, solve_fixed_batch)
from .discretize import Discretizer
from .env import GMRESIREnv
from .policy import PrecisionPolicy
from .rewards import (RewardConfig, W1, W2, accuracy_term, penalty_term,
                      precision_term, reward, reward_batch)

__all__ = [
    "ActionSpace", "full_action_space", "is_monotone",
    "reduced_action_space", "reduced_size", "TrainConfig", "TrainHistory",
    "evaluate_fixed_action", "evaluate_policy", "train_policy", "QTable",
    "epsilon_schedule", "Discretizer", "GMRESIREnv", "SolveRecord",
    "bucket_of", "pad_to_bucket", "records_from_stats", "solve_fixed_batch",
    "PrecisionPolicy", "RewardConfig", "W1", "W2", "accuracy_term",
    "penalty_term", "precision_term", "reward", "reward_batch",
]
