"""The paper's primary contribution: contextual-bandit precision autotuning.

Layered solver-agnostically around the `TunableTask` API:

  * `task.py` — the `TunableTask` protocol + `Outcome` (what an
    algorithm must expose to be autotuned); concrete tasks live in
    `repro.tasks` (GMRES-IR, CG-IR).
  * `engine.py` — `AutotuneEngine`: the single learning loop (solve
    cache, epsilon-greedy selection, Q-updates) shared by offline
    training and the online service.
  * `autotune.py` — Alg. 3 `train_policy` / `evaluate_policy` drivers
    over any task or engine.
  * Framework pieces: action space (Eq. 11-12), discretizer (Eq. 19-20),
    rewards (Eq. 21-25), tabular bandit (Eq. 5-6), policy persistence,
    and the fixed-shape batching layer.
  * `env.py` — the deprecated `GMRESIREnv` shim (engine + GMRES-IR task
    fused, kept for pre-TunableTask call sites).
"""
from . import aot
from .action_space import (ActionSpace, fp8_reduced_action_space,
                           full_action_space, is_monotone,
                           reduced_action_space, reduced_size)
from .autotune import (TrainConfig, TrainHistory, as_engine,
                       evaluate_fixed_action, evaluate_policy, train_policy)
from .bandit import QTable, epsilon_schedule
from .batching import (SolveRecord, bucket_of, pad_to_bucket,
                       records_from_stats, solve_fixed_batch)
from .discretize import Discretizer
from .engine import AutotuneEngine
from .env import GMRESIREnv
from .executor import (LocalExecutor, LowerableCall, ShardedExecutor,
                       SolveExecutor, available_executors,
                       computation_key, default_executor,
                       executor_compile_count, executor_compile_log,
                       register_executor, resolve_executor,
                       set_default_executor)
from .policy import PrecisionPolicy
from .rewards import (RewardConfig, W1, W2, accuracy_term, penalty_term,
                      precision_term, reward, reward_batch)
from .task import (CONVERGED, FAILED, MAXITER, STAGNATED, Outcome,
                   TunableTask, coerce_task, is_tunable_task)

__all__ = [
    "ActionSpace", "fp8_reduced_action_space", "full_action_space",
    "is_monotone", "reduced_action_space", "reduced_size",
    "SolveExecutor", "LocalExecutor", "LowerableCall", "ShardedExecutor",
    "resolve_executor", "default_executor", "set_default_executor",
    "register_executor", "available_executors", "aot",
    "computation_key", "executor_compile_count", "executor_compile_log",
    "TrainConfig", "TrainHistory",
    "as_engine", "evaluate_fixed_action", "evaluate_policy", "train_policy",
    "QTable", "epsilon_schedule", "Discretizer", "AutotuneEngine",
    "GMRESIREnv", "SolveRecord", "bucket_of", "pad_to_bucket",
    "records_from_stats", "solve_fixed_batch", "PrecisionPolicy",
    "RewardConfig", "W1", "W2", "accuracy_term", "penalty_term",
    "precision_term", "reward", "reward_batch", "Outcome", "TunableTask",
    "coerce_task", "is_tunable_task", "CONVERGED", "STAGNATED", "MAXITER",
    "FAILED",
]
