"""Context-space discretization (Eq. 3-4, 19-20).

Features arrive already in log10 space (log kappa, log norm), so linear bins
here realize the paper's "logarithmic bins". Bin ranges are fit on the
training set; out-of-range test features clip to the boundary bins (Eq. 19).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Discretizer:
    mins: np.ndarray     # (d,)
    maxs: np.ndarray     # (d,)
    n_bins: Tuple[int, ...]

    @classmethod
    def fit(cls, features: np.ndarray,
            n_bins: Sequence[int]) -> "Discretizer":
        """features: (N, d) training feature matrix."""
        features = np.asarray(features, dtype=np.float64)
        assert features.ndim == 2 and features.shape[1] == len(n_bins)
        return cls(features.min(axis=0), features.max(axis=0),
                   tuple(int(b) for b in n_bins))

    @property
    def d(self) -> int:
        return len(self.n_bins)

    @property
    def n_states(self) -> int:
        return int(np.prod(self.n_bins))

    def bin_indices(self, s: np.ndarray) -> np.ndarray:
        """Per-feature bin index, clipped to [0, n_j - 1].

        Degenerate features (mins == maxs: a single training instance, or
        a constant feature column) get a well-defined single-bin mapping —
        every query value lands in bin 0, rather than the arbitrary bin
        that floor((v - min) / 1.0 * n) would pick for off-point queries.
        """
        s = np.atleast_2d(np.asarray(s, dtype=np.float64))
        degenerate = self.maxs <= self.mins
        width = np.where(degenerate, 1.0, self.maxs - self.mins)
        frac = (s - self.mins) / width
        nb = np.asarray(self.n_bins)
        idx = np.floor(frac * nb).astype(np.int64)
        idx = np.where(degenerate[None, :], 0, idx)
        return np.clip(idx, 0, nb - 1)

    def __call__(self, s: np.ndarray) -> np.ndarray:
        """Flat state index (Eq. 20: row-major over features)."""
        idx = self.bin_indices(s)
        flat = np.zeros(idx.shape[0], dtype=np.int64)
        for j in range(self.d):
            flat = flat * self.n_bins[j] + idx[:, j]
        return flat if np.asarray(s).ndim > 1 else flat[0]

    def bin_diameter(self) -> float:
        """Euclidean diameter of one cell (the Delta of Prop. 1)."""
        widths = (self.maxs - self.mins) / np.asarray(self.n_bins)
        return float(np.linalg.norm(widths))

    def to_dict(self) -> dict:
        return {"mins": self.mins.tolist(), "maxs": self.maxs.tolist(),
                "n_bins": list(self.n_bins)}

    @classmethod
    def from_dict(cls, d: dict) -> "Discretizer":
        return cls(np.asarray(d["mins"]), np.asarray(d["maxs"]),
                   tuple(d["n_bins"]))
