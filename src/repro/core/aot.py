"""Ahead-of-time executable-grid warmup + persistent compile cache
(DESIGN.md §12).

The serving stack compiles one XLA executable per (computation key,
bucket, chunk, backend, executor) grid cell. Left lazy, every cell is
paid as a first-hit latency cliff — minutes of cold start before the
first solve, and a p99 outlier on every new shape, which poisons
exactly the time signal the bandit's reward is built on. This module
kills the cliff three ways:

  * `plan()` + `precompile()` — enumerate the grid for a set of tasks
    and AOT-build it through the exact per-shape compile caches the
    live path dispatches from (`core.executor`). Warm hits are
    bit-identical to cold ones by construction: both run the same
    `Compiled` object.
  * `BackgroundWarmup` — the same sweep on a daemon thread, priority
    ordered (most-traffic bucket first, smallest first among ties;
    traffic read from a trajectory log when one exists), so the
    likeliest buckets go warm first and the server's `/readyz`
    warm-bucket gate flips per bucket as each cell lands.
  * `enable_persistent_cache()` — `jax.experimental.compilation_cache`
    wiring (``REPRO_COMPILE_CACHE_DIR``): restarts reuse compiles from
    disk, with hit/miss events mirrored into `repro.obs` counters so
    "the warm restart did zero fresh XLA compiles" is a counter
    assertion, not a timing guess. This also makes the §11 crash
    recovery path fast, not just correct.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

ENV_CACHE_DIR = "REPRO_COMPILE_CACHE_DIR"

_cache_dir: Optional[str] = None
_cache_events = {"hits": 0, "misses": 0}
_listener_installed = False


def _count(name: str, help: str, amount: float = 1.0, **labels) -> None:
    """Fail-open counter against the process-default metrics registry
    (DESIGN.md §8) — warmup accounting must never take a server down."""
    try:
        from repro.obs.metrics import default_registry
        fam = default_registry().counter(name, help,
                                         tuple(sorted(labels)))
        (fam.labels(**labels) if labels else fam).inc(amount)
    except Exception:
        pass


# ---------------------------------------------------------------------------
# Persistent compilation cache (cross-process compile reuse)
# ---------------------------------------------------------------------------


def enable_persistent_cache(cache_dir: Optional[str] = None
                            ) -> Optional[str]:
    """Point jax's persistent compilation cache at `cache_dir` (or
    ``$REPRO_COMPILE_CACHE_DIR``); returns the directory in force, or
    None when neither is set (no-op). Idempotent.

    The size/time thresholds are dropped to zero: the repro's grid is
    many small CPU executables — exactly the entries jax's defaults
    decline to persist — and the whole point is that a restarted server
    rebuilds its grid from disk instead of re-running XLA."""
    global _cache_dir
    d = cache_dir if cache_dir is not None else os.environ.get(ENV_CACHE_DIR)
    if not d:
        return _cache_dir
    d = os.path.abspath(d)
    if _cache_dir == d:
        return d
    os.makedirs(d, exist_ok=True)
    import jax
    jax.config.update("jax_compilation_cache_dir", d)
    for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                      ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, val)
        except Exception:      # knob renamed/absent on this jax version
            pass
    _install_listener()
    _cache_dir = d
    return d


def _install_listener() -> None:
    """Mirror jax's compilation-cache hit/miss monitoring events into
    counters. This is the counter-based warm-restart signal: a restart
    whose grid is fully served from disk records zero misses."""
    global _listener_installed
    if _listener_installed:
        return
    try:
        from jax import monitoring

        def _on_event(event, *args, **kwargs):
            if event.endswith("/cache_hits"):
                _cache_events["hits"] += 1
                _count("repro_compile_cache_hits_total",
                       "Persistent-compilation-cache hits (XLA compile "
                       "served from REPRO_COMPILE_CACHE_DIR).")
            elif event.endswith("/cache_misses"):
                _cache_events["misses"] += 1
                _count("repro_compile_cache_misses_total",
                       "Persistent-compilation-cache misses (fresh XLA "
                       "compilation, result written to disk).")

        monitoring.register_event_listener(_on_event)
        _listener_installed = True
    except Exception:
        pass


def cache_stats() -> dict:
    """Persistent-cache state: directory in force (None = disabled) and
    hit/miss event counts since process start."""
    return {"dir": _cache_dir, "hits": int(_cache_events["hits"]),
            "misses": int(_cache_events["misses"])}


# ---------------------------------------------------------------------------
# Grid enumeration + priority order
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GridEntry:
    """One cell of the executable grid: (task, bucket) at the serving
    chunk. Precision backend and executor ride on the task; identical
    programs across tasks collapse onto one executable inside
    `core.executor` (`computation_key`), so over-enumerating is safe."""
    task: object
    bucket: int
    chunk: int

    def labels(self) -> dict:
        return {"task": getattr(self.task, "name", "unknown"),
                "bucket": int(self.bucket),
                "backend": str(getattr(
                    getattr(self.task, "backend", None), "name",
                    "unknown")),
                "executor": str(getattr(
                    getattr(self.task, "executor", None), "name",
                    "unknown"))}


def bucket_traffic(trajectory_path: Optional[str]) -> Dict[int, int]:
    """Per-bucket request counts from a JSONL trajectory log
    (`obs.trajlog` format; fail-open — unreadable path or rows yield
    {}). This is what makes warmup priority follow production traffic
    across restarts: the log survives the process, the jit caches
    don't."""
    counts: Dict[int, int] = {}
    if not trajectory_path:
        return counts
    try:
        with open(trajectory_path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    b = json.loads(line).get("bucket")
                except Exception:
                    continue
                if b is not None:
                    counts[int(b)] = counts.get(int(b), 0) + 1
    except OSError:
        return counts
    return counts


def order_buckets(buckets: Sequence[int],
                  traffic: Optional[Dict[int, int]] = None,
                  trajectory_path: Optional[str] = None) -> List[int]:
    """Warmup priority: most-seen bucket first (explicit `traffic`
    counts plus trajectory-log counts), smallest first among ties —
    small buckets compile fastest, so the grid starts flipping the
    `/readyz` gate as early as possible."""
    counts: Dict[int, int] = {int(b): int(c)
                              for b, c in (traffic or {}).items()}
    for b, c in bucket_traffic(trajectory_path).items():
        counts[b] = counts.get(b, 0) + c
    return sorted({int(b) for b in buckets},
                  key=lambda b: (-counts.get(b, 0), b))


def plan(tasks: Sequence, buckets: Sequence[int], chunk: int,
         traffic: Optional[Dict[int, int]] = None,
         trajectory_path: Optional[str] = None) -> List[GridEntry]:
    """Enumerate the executable grid in warmup-priority order: every
    task for the hottest bucket, then the next bucket, and so on."""
    ordered = order_buckets(buckets, traffic, trajectory_path)
    return [GridEntry(task, int(b), int(chunk))
            for b in ordered for task in tasks]


# ---------------------------------------------------------------------------
# Warmup sweeps
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WarmupReport:
    """Outcome of one warmup sweep. `warmed`/`skipped` hold bucket keys
    in completion order (skipped = the task had no AOT form for the
    cell; it will compile on first hit exactly as before)."""
    entries: int = 0
    warmed: List[int] = dataclasses.field(default_factory=list)
    skipped: List[int] = dataclasses.field(default_factory=list)
    errors: List[str] = dataclasses.field(default_factory=list)
    seconds: float = 0.0
    done: bool = False


def _sweep(entries: Sequence[GridEntry], report: WarmupReport,
           on_entry: Optional[Callable], pace: Optional[Callable]
           ) -> WarmupReport:
    t0 = time.perf_counter()
    for e in entries:
        if pace is not None:
            pace(e)
        try:
            ok = bool(e.task.precompile_bucket(e.bucket, e.chunk))
        except Exception as err:
            # Fail-open by contract: warmup must never take a server
            # down — the cell just compiles lazily on first hit.
            ok = False
            report.errors.append(f"bucket {e.bucket}: {err!r}")
        (report.warmed if ok else report.skipped).append(int(e.bucket))
        _count("repro_warmup_buckets_total",
               "Executable-grid cells processed by AOT warmup.",
               task=e.labels()["task"],
               status="warmed" if ok else "skipped")
        report.seconds = time.perf_counter() - t0
        if on_entry is not None:
            try:
                on_entry(e, ok)
            except Exception:
                pass
    report.done = True
    return report


def precompile(entries: Sequence[GridEntry],
               on_entry: Optional[Callable] = None) -> WarmupReport:
    """Run the grid eagerly (the server's ``warmup="sync"`` path).
    `on_entry(entry, warmed)` fires after each cell — the server flips
    its per-bucket `/readyz` warm gate there."""
    return _sweep(entries, WarmupReport(entries=len(entries)),
                  on_entry, None)


class BackgroundWarmup:
    """`precompile()` on a daemon thread (``warmup="background"``):
    priority-ordered cells land one by one, flipping per-bucket state
    through `on_entry` while the server is already accepting traffic.

    `pace` (optional) is called with each entry *before* it compiles —
    a rate-limiting / sequencing hook: production can yield the CPU to
    serving threads between cells, and tests step the sweep
    deterministically. The per-shape locks in `core.executor` make a
    live solve racing the warmup of the same cell safe: one of them
    builds, both use the same executable."""

    def __init__(self, entries: Sequence[GridEntry],
                 on_entry: Optional[Callable] = None,
                 pace: Optional[Callable] = None):
        self.entries = list(entries)
        self.report = WarmupReport(entries=len(self.entries))
        self._on_entry = on_entry
        self._pace = pace
        self._thread = threading.Thread(
            target=self._run, name="repro-aot-warmup", daemon=True)

    def start(self) -> "BackgroundWarmup":
        self._thread.start()
        return self

    def _run(self) -> None:
        _sweep(self.entries, self.report, self._on_entry, self._pace)

    @property
    def done(self) -> bool:
        return self.report.done

    def wait(self, timeout: Optional[float] = None) -> WarmupReport:
        self._thread.join(timeout)
        return self.report
