"""Trained precision-selection policy: Q-table + discretizer + action space."""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.action_space import ActionSpace, reduced_action_space
from repro.core.bandit import QTable
from repro.core.discretize import Discretizer


@dataclasses.dataclass
class PrecisionPolicy:
    action_space: ActionSpace
    discretizer: Discretizer
    qtable: QTable

    def state_of(self, features: np.ndarray) -> int:
        return int(self.discretizer(np.asarray(features)))

    @property
    def safe_action(self) -> int:
        """The known-safe all-fp64 arm: the highest action index. Action
        spaces order arms lowest→highest precision, and `QTable.greedy`
        breaks ties toward the highest index, so this is exactly the arm
        a zeroed (never-trained) Q-row resolves to — the breaker's
        degradation target (DESIGN.md §11.2) coincides with the
        untrained-policy default by construction."""
        return self.action_space.n_actions - 1

    def _nearest_visited(self, s: int) -> int:
        """Nearest visited state in bin coordinates (L2).

        Prop. 1 justifies nearest-bin generalization: the expected-reward
        Lipschitz bound degrades linearly with the bin distance, so the
        closest visited cell is the minimum-regret surrogate for a cell the
        training set never reached. Falls back to `s` itself (whose all-zero
        Q row resolves to the highest-precision action) when nothing was
        visited at all.
        """
        visited = np.where(self.qtable.N.sum(axis=1) > 0)[0]
        if len(visited) == 0 or s in visited:
            return s
        nb = np.asarray(self.discretizer.n_bins)
        def coords(flat):
            out = []
            for b in nb[::-1]:
                out.append(flat % b)
                flat = flat // b
            return np.stack(out[::-1], axis=-1)
        d = np.linalg.norm(coords(visited) - coords(np.asarray([s])), axis=1)
        return int(visited[int(np.argmin(d))])

    def predict(self, features: np.ndarray) -> Tuple[int, np.ndarray]:
        """Greedy inference (Eq. 7), with nearest-visited-bin fallback."""
        s = self.state_of(features)
        if not self.qtable.visited(s):
            s = self._nearest_visited(s)
        a = self.qtable.greedy(s)
        return a, self.action_space.actions[a]

    def predict_names(self, features: np.ndarray) -> Tuple[str, ...]:
        a, _ = self.predict(features)
        return self.action_space.names(a)

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        self.qtable.save(os.path.join(path, "qtable.npz"))
        meta = {
            "discretizer": self.discretizer.to_dict(),
            "ladder": list(self.action_space.ladder),
            "k": self.action_space.k,
            "ladder_idx": self.action_space.ladder_idx.tolist(),
        }
        with open(os.path.join(path, "policy.json"), "w") as f:
            json.dump(meta, f)

    @classmethod
    def load(cls, path: str) -> "PrecisionPolicy":
        qt = QTable.load(os.path.join(path, "qtable.npz"))
        with open(os.path.join(path, "policy.json")) as f:
            meta = json.load(f)
        space = reduced_action_space(tuple(meta["ladder"]), meta["k"])
        # Restore any subsampling by matching ladder_idx rows.
        want = np.asarray(meta["ladder_idx"], dtype=np.int32)
        if want.shape != space.ladder_idx.shape or \
                not np.array_equal(want, space.ladder_idx):
            keep = [i for i, row in enumerate(space.ladder_idx.tolist())
                    if row in want.tolist()]
            space = ActionSpace(space.ladder, space.k,
                                space.actions[keep], space.ladder_idx[keep])
        disc = Discretizer.from_dict(meta["discretizer"])
        return cls(space, disc, qt)
