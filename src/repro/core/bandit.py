"""Tabular contextual-bandit learner (paper Alg. 1 / §3.2).

Q: S_d x A -> R with the incremental estimator Q += alpha (R - Q) (Eq. 6),
epsilon-greedy action selection (Eq. 5) with linear decay (Eq. 13), and
optional 1/N(s,a) learning-rate schedule (Alg. 1 line 13).

The Q-table is tiny (|S_d| * |A| floats) and replicated at fleet scale —
checkpointing and elastic resize are trivial (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np


def epsilon_schedule(episode: int, total: int, eps_min: float) -> float:
    """Eq. 13/26: linear decay from 1.0, floored at eps_min."""
    return max(eps_min, 1.0 - episode / total)


@dataclasses.dataclass
class QTable:
    n_states: int
    n_actions: int
    alpha: Optional[float] = 0.5   # None => 1/N(s,a) schedule
    seed: int = 0

    def __post_init__(self):
        self.Q = np.zeros((self.n_states, self.n_actions))
        self.N = np.zeros((self.n_states, self.n_actions), dtype=np.int64)
        self.rng = np.random.default_rng(self.seed)

    # -- policy ------------------------------------------------------------
    def greedy(self, s: int) -> int:
        """argmax_a Q(s, a), ties broken toward the HIGHEST action index.

        Actions are ordered by increasing precision (Eq. 11 reduction), so an
        unvisited state (all-zero Q row) resolves to the all-highest-
        precision action — the numerically safe fallback the paper observes
        its agent learning on ill-conditioned data (§5.3).
        """
        q = self.Q[s]
        return int(len(q) - 1 - np.argmax(q[::-1]))

    def select(self, s: int, eps: float) -> int:
        """Eq. 5 epsilon-greedy."""
        if self.rng.random() < eps:
            return int(self.rng.integers(self.n_actions))
        return self.greedy(s)

    def visited(self, s: int) -> bool:
        return bool(self.N[s].sum() > 0)

    # -- learning ----------------------------------------------------------
    def update(self, s: int, a: int, r: float) -> float:
        """Eq. 6/27. Returns the reward-prediction error before the update."""
        rpe = r - self.Q[s, a]
        self.N[s, a] += 1
        alpha = self.alpha if self.alpha is not None else 1.0 / self.N[s, a]
        self.Q[s, a] += alpha * rpe
        return float(rpe)

    # -- persistence -------------------------------------------------------
    @staticmethod
    def _npz_path(path: str) -> str:
        # np.savez appends ".npz" when the suffix is absent; normalize so
        # save(p) and load(p) always agree on the on-disk name.
        return path if path.endswith(".npz") else path + ".npz"

    def save(self, path: str) -> None:
        np.savez(self._npz_path(path), Q=self.Q, N=self.N,
                 meta=json.dumps({"n_states": self.n_states,
                                  "n_actions": self.n_actions,
                                  "alpha": self.alpha,
                                  "seed": self.seed}))

    @classmethod
    def load(cls, path: str) -> "QTable":
        z = np.load(cls._npz_path(path), allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        qt = cls(meta["n_states"], meta["n_actions"], meta["alpha"],
                 meta["seed"])
        qt.Q = z["Q"]
        qt.N = z["N"]
        return qt
