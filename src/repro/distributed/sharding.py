"""Sharding rules: parameter/optimizer/input/cache PartitionSpecs.

Uniform strategy (DESIGN.md §5): tensor-parallel on the "model" axis
(attention heads, FFN hidden, MoE experts, vocab) x ZeRO-3-style FSDP on
the data axes (("pod", "data") when multi-pod) on each parameter's
non-TP dimension; batch over the data axes; sequence-parallel residual
stream (S over "model") between scan groups.

Rules are name-based over the parameter tree paths and divisibility-checked:
an axis that does not divide a dimension is dropped (GSPMD could pad, but
predictable layouts beat padded ones at this scale).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(dim: int, axes, mesh: Mesh):
    """Return axes if they divide dim (and dim is nontrivial), else None."""
    if axes is None or dim <= 1:
        return None
    size = axes_size(mesh, axes)
    if size > 1 and dim % size == 0:
        return axes
    return None


# (regex on the leaf path, role per trailing dimension)
# roles: "fsdp", "model", None; applied to the LAST len(roles) dims.
_PARAM_RULES = [
    (r"embedding$", ("model", "fsdp")),
    (r"unembed$", ("fsdp", "model")),
    # MoE expert banks (E, d, f) / (E, f, d): expert-parallel on model.
    (r"ffn/(wi_gate|wi_up)$/3d", ("model", "fsdp", None)),
    (r"ffn/wo$/3d", ("model", None, "fsdp")),
    (r"router$", ("fsdp", None)),
    # Dense FFN (d, f) / (f, d).
    (r"(wi_gate|wi_up)$", ("fsdp", "model")),
    (r"ffn/wo$", ("model", "fsdp")),
    (r"shared/wo$", ("model", "fsdp")),
    # Attention.
    (r"(wq|wk|wv)$", ("fsdp", "model")),
    (r"mixer/wo$", ("model", "fsdp")),
    # MLA.
    (r"w_dkv$", ("fsdp", None)),
    (r"w_kr$", ("fsdp", None)),
    (r"w_dq$", ("fsdp", None)),
    (r"(w_uk|w_uv|w_uq)$", (None, "model", None)),
    # Mamba.
    (r"in_proj$", ("fsdp", "model")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"x_proj$", ("model", None)),
    (r"dt_proj$", (None, "model")),
    (r"dt_bias$", ("model",)),
    (r"A_log$", ("model", None)),
    (r"D$", ("model",)),
    (r"out_proj$", ("model", "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(f"[{k.idx}]")
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def spec_for_param(path_str: str, shape: Tuple[int, ...],
                   mesh: Mesh) -> P:
    fsdp = data_axes(mesh) or None
    ndim = len(shape)
    # QTensor leaves: codes share the param's shape; scales share its rank.
    core = re.sub(r"/(codes|scales)$", "", path_str)
    # Scan-stacked layer params carry a leading group dim (never sharded).
    stacked = core.startswith("layers") or "/layers/" in core
    base_ndim = ndim - (1 if stacked else 0)
    for pat, roles in _PARAM_RULES:
        want3d = pat.endswith("/3d")
        pat_core = pat[:-3] if want3d else pat
        if not re.search(pat_core, core):
            continue
        # 3d rules target MoE expert banks (E, d, f); dense FFN leaves with
        # the same names have base rank 2 and fall through to the 2d rule.
        if want3d and base_ndim != 3:
            continue
        nr = len(roles)
        if ndim < nr:
            continue
        entries = [None] * (ndim - nr)
        for dim, role in zip(shape[ndim - nr:], roles):
            ax = {"fsdp": fsdp, "model": "model", None: None}[role]
            entries.append(_fit(dim, ax, mesh))
        return P(*entries)
    return P()  # replicate (norms, scalars, step counters)


def param_specs(params_shapes, mesh: Mesh):
    """PartitionSpec tree mirroring a params/opt-state shape tree."""
    def leaf_spec(path, leaf):
        return spec_for_param(_path_str(path), leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def named(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch + cache specs
# ---------------------------------------------------------------------------

def batch_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """(B, S, ...) host batch: B over the data axes when divisible."""
    dp = data_axes(mesh) or None
    first = _fit(shape[0], dp, mesh)
    return P(first, *([None] * (len(shape) - 1)))


def batch_specs(batch_shapes, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda l: batch_spec(l.shape, mesh), batch_shapes)


def cache_spec(path_str: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    dp = data_axes(mesh) or None
    if path_str.endswith("length") or len(shape) <= 1:
        return P()
    b_ax = _fit(shape[0], dp, mesh)
    if re.search(r"/(k|v)$", path_str) and len(shape) == 4:
        b, s, h, d = shape
        h_ax = _fit(h, "model", mesh)
        s_ax = None
        if b_ax is None:                 # long-context: shard sequence
            s_ax = _fit(s, dp, mesh)
        if h_ax is None and s_ax is None:
            s_ax = _fit(s, "model", mesh)
        elif h_ax is None:
            h_ax = None
        return P(b_ax, s_ax, h_ax, None)
    if re.search(r"/ckv$|/k_rope$", path_str) and len(shape) == 3:
        b, s, r = shape
        s_ax = _fit(s, dp, mesh) if b_ax is None else None
        return P(b_ax, s_ax, None)
    if re.search(r"/h$", path_str) and len(shape) == 3:   # mamba state
        b, di, ds = shape
        return P(b_ax, _fit(di, "model", mesh), None)
    if re.search(r"/conv$", path_str) and len(shape) == 3:
        b, k, di = shape
        return P(b_ax, None, _fit(di, "model", mesh))
    # stacked (group, ...) cache entries: recurse on trailing dims
    if len(shape) >= 2:
        inner = cache_spec(path_str, shape[1:], mesh)
        return P(None, *inner)
    return P()


def cache_specs(cache_shapes, mesh: Mesh):
    def leaf(path, l):
        ps = _path_str(path)
        # Stacked scan caches carry a leading group dim.
        if ps.startswith("layers"):
            inner = cache_spec(ps, l.shape[1:], mesh)
            return P(None, *inner)
        return cache_spec(ps, l.shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def residual_spec(mesh: Mesh) -> P:
    """Sequence-parallel residual stream between scan groups (B, S, d)."""
    dp = data_axes(mesh) or None
    return P(dp, "model", None)
