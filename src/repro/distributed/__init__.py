from .sharding import (batch_spec, batch_specs, cache_spec, cache_specs,
                       data_axes, named, param_specs, residual_spec,
                       spec_for_param)

__all__ = ["batch_spec", "batch_specs", "cache_spec", "cache_specs",
           "data_axes", "named", "param_specs", "residual_spec",
           "spec_for_param"]
