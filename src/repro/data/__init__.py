from .matrices import (LinearSystem, generate_dense_set, generate_sparse_set,
                       pad_batch, pad_system, randsvd_dense, sparse_spd)

__all__ = ["LinearSystem", "generate_dense_set", "generate_sparse_set",
           "pad_batch", "pad_system", "randsvd_dense", "sparse_spd"]
