"""Problem-instance generation (paper §5.1-5.3).

Dense: MATLAB gallery('randsvd', ..., mode=2) — A = U diag(sigma) V^T with
sigma_1..n-1 = sigma_max, sigma_n = sigma_max/kappa (Eq. 31), U/V from QR of
standard-normal matrices.

Sparse: A0 with nnz = floor(lambda_s n^2) standard-normal entries at random
positions, symmetrized to SPD via A = A0 A0^T + beta I (following [17] as
cited by the paper). beta is calibrated from the spectrum so the measured
condition number lands in the paper's 1e8-1e10 band.

Systems are padded to a fixed bucket size with an identity block
(block-diag(A, I), b/x zero-extended) — exactly solution-preserving, so one
compiled batched solver serves every matrix size (DESIGN.md §3.5).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import scipy.linalg as sla

from repro.core.features import system_features


@dataclasses.dataclass
class LinearSystem:
    A: np.ndarray            # (n, n) float64, unpadded
    b: np.ndarray
    x_true: np.ndarray
    kappa: float             # generator-target (dense) / measured (sparse)
    features: dict           # from core.features.system_features
    kind: str                # "dense" | "sparse"

    @property
    def n(self) -> int:
        return self.A.shape[0]


def randsvd_dense(n: int, kappa: float, rng: np.random.Generator,
                  sigma_max: float = 1.0) -> LinearSystem:
    """gallery('randsvd') mode=2: one small singular value (Eq. 31)."""
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.full(n, sigma_max)
    s[-1] = sigma_max / kappa
    A = (u * s) @ v.T
    x = rng.standard_normal(n)
    b = A @ x
    return LinearSystem(A, b, x, float(kappa), system_features(A), "dense")


def sparse_spd(n: int, lambda_s: float, rng: np.random.Generator,
               kappa_target: float) -> LinearSystem:
    """A = A0 A0^T + beta I with nnz(A0) = floor(lambda_s n^2)."""
    nnz = max(int(lambda_s * n * n), n)
    A0 = np.zeros((n, n))
    idx = rng.choice(n * n, size=nnz, replace=False)
    A0.flat[idx] = rng.standard_normal(nnz)
    # Non-zero diagonal (paper: a_ii != 0, non-singular).
    diag_fill = rng.standard_normal(n) * 0.1
    G = A0 @ A0.T
    lam_max = float(sla.eigh(G, eigvals_only=True,
                             subset_by_index=(n - 1, n - 1))[0])
    lam_max = max(lam_max, 1e-12)
    beta = lam_max / kappa_target
    A = G + beta * np.eye(n) + np.diag(np.abs(diag_fill)) * beta
    x = rng.standard_normal(n)
    b = A @ x
    feats = system_features(A)
    return LinearSystem(A, b, x, feats["kappa_est"], feats, "sparse")


def generate_dense_set(n_systems: int, rng: np.random.Generator,
                       n_range=(100, 500),
                       log10_kappa_range=(1.0, 9.0)) -> List[LinearSystem]:
    out = []
    for _ in range(n_systems):
        n = int(rng.integers(n_range[0], n_range[1] + 1))
        kappa = 10.0 ** rng.uniform(*log10_kappa_range)
        out.append(randsvd_dense(n, kappa, rng))
    return out


def generate_sparse_set(n_systems: int, rng: np.random.Generator,
                        n_range=(100, 500), lambda_s: float = 0.01,
                        log10_kappa_range=(8.0, 10.0)) -> List[LinearSystem]:
    out = []
    for _ in range(n_systems):
        n = int(rng.integers(n_range[0], n_range[1] + 1))
        kt = 10.0 ** rng.uniform(*log10_kappa_range)
        out.append(sparse_spd(n, lambda_s, rng, kt))
    return out


def pad_system(sys: LinearSystem, n_pad: int):
    """Identity-extend to n_pad (solution-preserving)."""
    n = sys.n
    assert n <= n_pad
    A = np.eye(n_pad)
    A[:n, :n] = sys.A
    b = np.zeros(n_pad)
    b[:n] = sys.b
    x = np.zeros(n_pad)
    x[:n] = sys.x_true
    return A, b, x


def pad_batch(systems: List[LinearSystem], n_pad: Optional[int] = None):
    """Stack systems into padded (B, n_pad, n_pad) / (B, n_pad) arrays."""
    if n_pad is None:
        n_pad = max(s.n for s in systems)
    A = np.zeros((len(systems), n_pad, n_pad))
    b = np.zeros((len(systems), n_pad))
    x = np.zeros((len(systems), n_pad))
    for i, s in enumerate(systems):
        A[i], b[i], x[i] = pad_system(s, n_pad)
    return A, b, x
