"""Synthetic LM token pipeline: deterministic, host-sharded, resumable.

Generates Zipf-distributed token streams with injected n-gram structure so a
~100M model has signal to learn (loss decreases measurably within a few
hundred steps). Sharding: each data-parallel host slice draws a disjoint
counter range; the cursor is part of the checkpoint, so restart/elastic
resize re-shards deterministically (DESIGN.md §5 fault tolerance)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    shard: int = 0             # this host's shard index
    n_shards: int = 1
    cursor: int = 0            # resumable position (batches consumed)
    zipf_a: float = 1.2
    ngram_period: int = 8      # deterministic structure the model can learn

    def _batch_at(self, index: int) -> Dict[str, np.ndarray]:
        # Deterministic per (seed, shard, index): restart-safe.
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.shard) * 1_000_003 + index)
        z = rng.zipf(self.zipf_a, size=(self.batch_size, self.seq_len))
        tokens = (z - 1) % (self.vocab_size - 2) + 2
        # Inject learnable structure: every `ngram_period`-th token repeats
        # a function of its predecessor.
        prev = np.roll(tokens, 1, axis=1)
        mask = (np.arange(self.seq_len) % self.ngram_period) == 0
        tokens[:, mask] = (prev[:, mask] * 7 + 3) % (self.vocab_size - 2) + 2
        tokens[:, 0] = 1                          # BOS
        return {"tokens": tokens.astype(np.int32),
                "loss_mask": np.ones_like(tokens, np.float32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self._batch_at(self.cursor * self.n_shards + self.shard)
        self.cursor += 1
        return b

    # -- checkpointable state ----------------------------------------------
    def state_dict(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed,
                "shard": self.shard, "n_shards": self.n_shards}

    def load_state_dict(self, d: dict, *, new_shard: Optional[int] = None,
                        new_n_shards: Optional[int] = None):
        """Resume; on elastic resize the cursor is kept and the shard grid
        re-derived, so no sample is replayed within a shard."""
        self.cursor = int(d["cursor"])
        self.seed = int(d["seed"])
        self.shard = new_shard if new_shard is not None else int(d["shard"])
        self.n_shards = (new_n_shards if new_n_shards is not None
                         else int(d["n_shards"]))
