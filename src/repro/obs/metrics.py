"""Fail-open labeled metrics: Counter / Gauge / Histogram registry.

The one hard rule of this module (DESIGN.md §8.1): **instrumentation
must never break the solve path**. Every mutating call on a metric
(`inc` / `dec` / `set` / `observe`) swallows any exception raised inside
metric or sink code and counts it in the registry's self-metric
(exported as ``repro_obs_errors_total``), instead of propagating it into
`submit()`/`step()`. The same contract is available to instrumentation
facades via the `fail_open` decorator.

Conventions (linted by `obs.expo.lint_exposition`, scraped live in CI):

  * metric names: ``repro_<subsystem>_<what>[_unit]``, snake_case;
  * counters end in ``_total``; time histograms end in ``_seconds``;
  * label names are snake_case; label values are free-form strings
    (buckets and actions are stringified ints).

Stdlib-only and thread-safe: the HTTP exposition thread (`obs.expo`)
reads concurrently with the serving loop's writes. Metric families are
get-or-create, so repeated `registry.counter(name, ...)` calls from
several servers share one family — mirroring how the precision-backend
and executor registries are process-global. A module-level default
registry (`default_registry`) plays the role prometheus-client's
``REGISTRY`` does; isolated registries are for tests.
"""
from __future__ import annotations

import functools
import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

# Latency-shaped default buckets (seconds): micro-batched solves span
# ~100us (cached small bucket) to seconds (first-compile / huge n).
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# Ratio-shaped buckets for fractions in [0, 1] (pad waste).
RATIO_BUCKETS = (0.0, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.75, 0.9, 1.0)


class MetricsRegistry:
    """Holds metric families + the fail-open error count + sinks."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: Dict[str, "_Family"] = {}
        self._errors = 0
        self._sinks: List[Callable[[str, dict, float], None]] = []

    # -- fail-open accounting ----------------------------------------------
    def count_error(self) -> None:
        with self._lock:
            self._errors += 1

    @property
    def errors(self) -> int:
        """Instrumentation exceptions swallowed so far (self-metric)."""
        return self._errors

    # -- sinks -------------------------------------------------------------
    def add_sink(self, sink: Callable[[str, dict, float], None]) -> None:
        """Register a per-sample callback ``sink(name, labels, value)``.

        Sinks run inside the fail-open guard: a raising sink is counted
        in `errors` and never reaches the caller."""
        with self._lock:
            self._sinks.append(sink)

    def _notify(self, name: str, labels: dict, value: float) -> None:
        for sink in self._sinks:
            try:
                sink(name, labels, value)
            except Exception:
                self.count_error()

    # -- families (get-or-create) ------------------------------------------
    def _family(self, cls, name: str, help: str,
                labelnames: Tuple[str, ...], **kw) -> "_Family":
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(self, name, help, tuple(labelnames), **kw)
                self._families[name] = fam
            elif fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered with labels "
                    f"{tuple(labelnames)!r} != {fam.labelnames!r}")
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Tuple[str, ...] = ()) -> "Counter":
        return self._family(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Tuple[str, ...] = ()) -> "Gauge":
        return self._family(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Tuple[str, ...] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS
                  ) -> "Histogram":
        return self._family(Histogram, name, help, labelnames,
                            buckets=buckets)

    # -- collection --------------------------------------------------------
    def collect(self) -> List["_Family"]:
        with self._lock:
            return sorted(self._families.values(), key=lambda f: f.name)


class _Family:
    """One named metric with N labeled children."""

    type: str = "untyped"
    Child: type = None          # set by subclasses

    def __init__(self, registry: MetricsRegistry, name: str, help: str,
                 labelnames: Tuple[str, ...]):
        self.registry = registry
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._children: Dict[Tuple[str, ...], object] = {}

    def labels(self, **labelvalues):
        """Child for one label combination (get-or-create). Wrong label
        names raise here — facade code reaches this only through
        `fail_open`-guarded methods, so the solve path never sees it."""
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} expects labels {self.labelnames!r},"
                f" got {tuple(labelvalues)!r}")
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        with self.registry._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = type(self).Child(self, key)
            return child

    def _default_child(self):
        """The single unlabeled child (for labelless families)."""
        if self.labelnames:
            raise ValueError(
                f"metric {self.name!r} is labeled {self.labelnames!r}; "
                "use .labels(...)")
        return self.labels()

    def samples(self) -> List[Tuple[Tuple[str, ...], object]]:
        with self.registry._lock:
            return sorted(self._children.items())

    def _labels_dict(self, key: Tuple[str, ...]) -> dict:
        return dict(zip(self.labelnames, key))


class _Child:
    """Shared child plumbing: family backref + label dict."""

    def __init__(self, family: _Family, key: Tuple[str, ...]):
        self._family = family
        self._labels = family._labels_dict(key)

    def _registry(self) -> MetricsRegistry:
        return self._family.registry


class Counter(_Family):
    type = "counter"

    class Child(_Child):
        def __init__(self, family, key):
            super().__init__(family, key)
            self.value = 0.0

        def inc(self, amount: float = 1.0) -> None:
            reg = self._registry()
            try:
                amount = float(amount)
                if amount < 0 or not math.isfinite(amount):
                    raise ValueError(
                        f"counter increment must be finite >= 0, "
                        f"got {amount}")
                with reg._lock:
                    self.value += amount
                reg._notify(self._family.name, self._labels, self.value)
            except Exception:
                reg.count_error()

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)


class Gauge(_Family):
    type = "gauge"

    class Child(_Child):
        def __init__(self, family, key):
            super().__init__(family, key)
            self.value = 0.0

        def set(self, value: float) -> None:
            reg = self._registry()
            try:
                with reg._lock:
                    self.value = float(value)
                reg._notify(self._family.name, self._labels, self.value)
            except Exception:
                reg.count_error()

        def inc(self, amount: float = 1.0) -> None:
            reg = self._registry()
            try:
                with reg._lock:
                    self.value += float(amount)
                reg._notify(self._family.name, self._labels, self.value)
            except Exception:
                reg.count_error()

        def dec(self, amount: float = 1.0) -> None:
            self.inc(-amount)

    def set(self, value: float) -> None:
        self._default_child().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default_child().dec(amount)


class Histogram(_Family):
    type = "histogram"

    def __init__(self, registry, name, help, labelnames,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(registry, name, help, labelnames)
        bounds = sorted(float(b) for b in buckets)
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds: Tuple[float, ...] = tuple(bounds)

    class Child(_Child):
        def __init__(self, family, key):
            super().__init__(family, key)
            self.counts = [0] * (len(family.bounds) + 1)  # +Inf tail
            self.sum = 0.0
            self.count = 0

        def observe(self, value: float) -> None:
            reg = self._registry()
            try:
                value = float(value)
                with reg._lock:
                    for i, bound in enumerate(self._family.bounds):
                        if value <= bound:
                            break
                    else:
                        i = len(self._family.bounds)
                    self.counts[i] += 1
                    self.sum += value
                    self.count += 1
                reg._notify(self._family.name, self._labels, value)
            except Exception:
                reg.count_error()

        def cumulative(self) -> List[int]:
            """Cumulative per-`le` counts, +Inf last (Prometheus form)."""
            out, acc = [], 0
            for c in self.counts:
                acc += c
                out.append(acc)
            return out

    def observe(self, value: float) -> None:
        self._default_child().observe(value)


# ---------------------------------------------------------------------------
# Fail-open guard for instrumentation facades
# ---------------------------------------------------------------------------

def fail_open(method):
    """Decorator for instrumentation methods on objects exposing a
    `registry` attribute (a `MetricsRegistry`): any exception is counted
    in the registry's self-metric and never propagated. This is the
    boundary that keeps tracing/logging/exporter faults out of the
    solve path (DESIGN.md §8.1)."""
    @functools.wraps(method)
    def guarded(self, *args, **kwargs):
        try:
            return method(self, *args, **kwargs)
        except Exception:
            try:
                self.registry.count_error()
            except Exception:
                pass
            return None
    return guarded


# ---------------------------------------------------------------------------
# Process-default registry (mirrors prometheus-client's REGISTRY)
# ---------------------------------------------------------------------------

_DEFAULT_REGISTRY: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    global _DEFAULT_REGISTRY
    with _DEFAULT_LOCK:
        if _DEFAULT_REGISTRY is None:
            _DEFAULT_REGISTRY = MetricsRegistry()
        return _DEFAULT_REGISTRY
