"""Append-only JSONL trajectory log of every served decision.

One line per completed request, carrying everything off-policy
evaluation of a candidate policy needs later (ROADMAP "Beyond
ε-greedy"; Khodak et al. amortize over exactly such logged sequences of
related instances): the context features and discretized state, the
action taken, the epsilon in force and whether the epsilon coin fired
(the behavior-policy propensity is reconstructible from ``eps``,
``explore`` and the action-space size), the observed reward and outcome
metrics, and the policy version that made the decision.

The writer is line-buffered append-only — a crashed server loses at
most the final partial line, and `read()` skips partial/corrupt lines
rather than failing, so a log being written is safely readable. All
server-side writes go through the fail-open guard (DESIGN.md §8.1): a
full disk or closed file never breaks the solve path.

With ``max_bytes`` set, the log rotates: when the active file crosses
the limit it is renamed to ``<path>.1`` (older segments shift to
``.2`` … ``.N``; the oldest past ``max_segments`` is deleted) and a
fresh active file is opened. Readers span all live segments oldest
first, so rotation is invisible to `read()`/`iter_records()`. Rotation
failures are swallowed (fail-open): appends keep going to the current
file.

The ``sync`` knob sets fsync durability (DESIGN.md §11.1) — the log is
the learner's write-ahead record, so what survives a *host* crash is
what recovery can replay:

  * ``"none"``   (default) line-buffered only; a process crash loses at
    most the final partial line, a host crash may lose page-cache tail.
  * ``"rotate"`` fsync when a segment is sealed (rotation/close):
    rotated history is durable, the active segment is best-effort.
  * ``"always"`` fsync after every append: zero-loss, priced in
    benchmarks/service_bench.py (``--trajlog-sync``).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Iterator, List, Optional

from repro import faults

_SYNC_LEVELS = ("none", "rotate", "always")


def _jsonable(v):
    """Best-effort JSON coercion (numpy scalars -> float, else str)."""
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class TrajectoryLog:
    """Append-only JSONL writer + reader for served trajectories."""

    # The stable schema off-policy evaluation depends on; extra keys are
    # allowed, these are required of server-written records (pinned by
    # tests/test_obs.py).
    FIELDS = ("ts", "request_id", "task", "bucket", "features", "state",
              "action", "action_names", "eps", "explore", "reward",
              "outcome", "latency_s", "policy_version", "drift")

    def __init__(self, path: str, max_bytes: Optional[int] = None,
                 max_segments: int = 3, sync: str = "none"):
        if sync not in _SYNC_LEVELS:
            raise ValueError(f"sync must be one of {_SYNC_LEVELS}, "
                             f"got {sync!r}")
        self.path = str(path)
        self.max_bytes = max_bytes
        self.max_segments = int(max_segments)
        self.sync = sync
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", buffering=1)   # line-buffered
        self.written = 0
        self.rotations = 0

    def _fsync(self) -> None:
        """Flush+fsync the active file; OSError propagates to the
        caller's fail-open guard (a full disk surfaces as one counted
        obs error, not a wedged server)."""
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def append(self, record: dict) -> None:
        faults.maybe_raise("trajlog.write", path=self.path)
        line = json.dumps(record, default=_jsonable,
                          separators=(",", ":"))
        with self._lock:
            self._fh.write(line + "\n")
            self.written += 1
            if self.sync == "always":
                self._fsync()
            if (self.max_bytes is not None
                    and self._fh.tell() >= self.max_bytes):
                self._rotate()

    def _rotate(self) -> None:
        """Shift segments ``.k`` -> ``.k+1``, active -> ``.1``; open a
        fresh active file. Caller holds the lock. Never raises — a
        failed rename leaves the log appending to the current file."""
        try:
            if self.sync != "none":
                try:
                    self._fsync()       # seal the segment durably
                except OSError:
                    pass
            self._fh.close()
            for k in range(self.max_segments, 0, -1):
                src = f"{self.path}.{k}"
                if not os.path.exists(src):
                    continue
                if k == self.max_segments:
                    os.unlink(src)
                else:
                    os.replace(src, f"{self.path}.{k + 1}")
            if self.max_segments > 0:
                os.replace(self.path, f"{self.path}.1")
            self.rotations += 1
        except OSError:
            pass
        finally:
            self._fh = open(self.path, "a", buffering=1)

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                if self.sync != "none":
                    try:
                        self._fsync()
                    except OSError:
                        pass
                self._fh.close()

    def __enter__(self) -> "TrajectoryLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reading -----------------------------------------------------------
    @staticmethod
    def segments(path: str) -> List[str]:
        """Live segment files for `path`, oldest first (rotated ``.N`` …
        ``.1`` then the active file)."""
        out: List[str] = []
        k = 1
        while os.path.exists(f"{path}.{k}"):
            out.append(f"{path}.{k}")
            k += 1
        out.reverse()
        if os.path.exists(path):
            out.append(path)
        return out

    @staticmethod
    def iter_records(path: str) -> Iterator[dict]:
        """Yield records across all live segments (oldest first),
        skipping blank/partial trailing lines."""
        for seg in TrajectoryLog.segments(path):
            with open(seg) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        yield json.loads(line)
                    except json.JSONDecodeError:
                        continue      # torn tail write of a live log

    @classmethod
    def read(cls, path: str,
             task: Optional[str] = None) -> List[dict]:
        """All records (optionally filtered to one task name)."""
        recs = list(cls.iter_records(path))
        if task is not None:
            recs = [r for r in recs if r.get("task") == task]
        return recs

    @classmethod
    def read_complete(cls, path: str, task: Optional[str] = None,
                      fields: Optional[tuple] = None) -> List[dict]:
        """Records carrying every required field (default: `FIELDS`,
        the OPE schema). Foreign rows sharing a log file — decision-
        trail events, hand-written annotations — are skipped, so the
        off-policy evaluator can consume a mixed log safely."""
        need = cls.FIELDS if fields is None else tuple(fields)
        return [r for r in cls.read(path, task=task)
                if all(f in r for f in need)]
