"""Fail-open observability for the serving stack (DESIGN.md §8).

Four pieces, all stdlib-only and importable without jax:

  * `obs.metrics`  — labeled Counter/Gauge/Histogram registry where
    every instrumentation call is fail-open: exceptions in metric/sink
    code are swallowed and counted in ``repro_obs_errors_total``, never
    propagated into the solve path;
  * `obs.expo`     — Prometheus text + JSON exposition and the HTTP
    front door (``/metrics``, ``/healthz``, ``/readyz``) on a stdlib
    background thread;
  * `obs.trace`    — per-request spans (submit → queue wait → solve →
    reward → Q-update) in a bounded ring buffer, dumpable as Chrome
    trace-event JSON;
  * `obs.trajlog`  — append-only JSONL trajectory log (features, state,
    action, eps, explore, reward, outcome, policy version) that makes
    off-policy evaluation from logged service streams possible.

`Observability` bundles one of each for a server:
`AutotuneServer(..., obs=Observability(trajectory_path=...))`, then
``server.serve_obs()`` to open the HTTP surface. The `Telemetry` module
stays the computation layer; exporters here only *expose* it.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.expo import (ObsHTTPServer, lint_exposition, render_json,
                            render_prometheus)
from repro.obs.metrics import (DEFAULT_BUCKETS, RATIO_BUCKETS, Counter,
                               Gauge, Histogram, MetricsRegistry,
                               default_registry, fail_open)
from repro.obs.trace import Span, Tracer
from repro.obs.trajlog import TrajectoryLog


class Observability:
    """One server's observability bundle: metrics registry + tracer +
    optional trajectory log + the HTTP front door.

    ``registry=None`` joins the process-default registry (several
    servers share metric families, like prometheus-client's global
    REGISTRY); pass a fresh `MetricsRegistry` for isolation (tests,
    benchmarks)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 trajectory_path: Optional[str] = None,
                 trace_capacity: int = 4096,
                 trajectory_max_bytes: Optional[int] = None,
                 trajectory_max_segments: int = 3,
                 trajectory_sync: str = "none"):
        self.registry = registry if registry is not None \
            else default_registry()
        self.tracer = tracer if tracer is not None \
            else Tracer(capacity=trace_capacity)
        self.trajlog = (TrajectoryLog(
            trajectory_path, max_bytes=trajectory_max_bytes,
            max_segments=trajectory_max_segments, sync=trajectory_sync)
            if trajectory_path else None)
        self.http: Optional[ObsHTTPServer] = None

    def serve(self, host: str = "127.0.0.1", port: int = 0,
              ready_fn=None, telemetry_fn=None,
              rollout_fn=None, health_fn=None) -> ObsHTTPServer:
        """Start (or return the running) HTTP front door. ``health_fn``
        (when wired) contributes degradation state — open breakers,
        recovery metadata — to ``/healthz`` and ``/readyz``."""
        if self.http is None:
            self.http = ObsHTTPServer(
                self.registry, host=host, port=port, ready_fn=ready_fn,
                telemetry_fn=telemetry_fn,
                trace_fn=self.tracer.chrome_trace,
                rollout_fn=rollout_fn, health_fn=health_fn)
        return self.http

    def close(self) -> None:
        if self.http is not None:
            self.http.close()
            self.http = None
        if self.trajlog is not None:
            self.trajlog.close()


__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "MetricsRegistry",
    "ObsHTTPServer", "Observability", "RATIO_BUCKETS", "Span",
    "Tracer", "TrajectoryLog", "default_registry", "fail_open",
    "lint_exposition", "render_json", "render_prometheus",
]
