"""Per-request spans in a bounded ring buffer, Chrome-trace dumpable.

One solve request's lifecycle crosses several pump iterations (submit →
queue wait → flush/solve → reward → Q-update), so spans are recorded
with *explicit* timestamps from the server's injectable clock rather
than wall-clock context managers: the server knows `submitted_at`, the
batcher stamps solve start/end on each `FlushResult`, and `_complete`
emits the whole request tree at once. A `span()` context manager exists
for inline convenience instrumentation.

The buffer is a `deque(maxlen=capacity)` — a long-running server keeps
the most recent spans and never grows without bound (same policy as the
telemetry latency reservoir). `chrome_trace()` renders the standard
Chrome trace-event JSON (``chrome://tracing`` / Perfetto): complete
("ph": "X") events, microsecond timestamps, one `tid` per request id so
the viewer lays concurrent requests on separate rows.

Recording is cheap (one dataclass + deque append under a lock) and the
callers wrap it in the fail-open guard (DESIGN.md §8.1), so a broken
tracer can never break `submit()`/`step()`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Span:
    name: str                 # phase: submit / queue_wait / solve / ...
    t0: float                 # [seconds] start, in the recording clock
    t1: float                 # [seconds] end
    tid: int = 0              # request id (Chrome row)
    cat: str = "request"
    args: Optional[Dict[str, object]] = None

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class Tracer:
    def __init__(self, capacity: int = 4096,
                 clock=time.perf_counter):
        self.capacity = int(capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=self.capacity)

    # -- recording ---------------------------------------------------------
    def add_span(self, name: str, t0: float, t1: float, tid: int = 0,
                 cat: str = "request", **args) -> Span:
        """Record a completed span with caller-supplied timestamps."""
        span = Span(str(name), float(t0), float(t1), int(tid), str(cat),
                    dict(args) or None)
        with self._lock:
            self._spans.append(span)
        return span

    @contextlib.contextmanager
    def span(self, name: str, tid: int = 0, cat: str = "request", **args):
        """Inline span over a code block, timed by the tracer's clock."""
        t0 = self.clock()
        try:
            yield
        finally:
            self.add_span(name, t0, self.clock(), tid=tid, cat=cat,
                          **args)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    # -- reading -----------------------------------------------------------
    def spans(self, tid: Optional[int] = None) -> List[Span]:
        with self._lock:
            out = list(self._spans)
        if tid is not None:
            out = [s for s in out if s.tid == tid]
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    # -- export ------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object ({"traceEvents": [...]})."""
        events = []
        for s in self.spans():
            ev = {"name": s.name, "cat": s.cat, "ph": "X",
                  "ts": s.t0 * 1e6, "dur": max(s.duration, 0.0) * 1e6,
                  "pid": 0, "tid": s.tid}
            if s.args:
                ev["args"] = {k: _jsonable(v) for k, v in s.args.items()}
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        """Write `chrome_trace()` to `path` (open in chrome://tracing)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def _jsonable(v):
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)
