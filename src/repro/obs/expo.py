"""Metric exposition: Prometheus text format, JSON, and the HTTP front
door (`/metrics`, `/healthz`, `/readyz`) on a stdlib background thread.

This is the first externally visible surface of the serving stack
(DESIGN.md §8.3): a `ThreadingHTTPServer` bound to an ephemeral
loopback port by default, reading registry/telemetry/trace state that
the single-threaded serving loop writes (all reads go through the
registry lock). Handler exceptions answer 500 and never take the
server thread down; nothing here can propagate into the solve path.

Endpoints:

  * ``/metrics``       Prometheus text exposition 0.0.4
  * ``/metrics.json``  the same samples as JSON
  * ``/healthz``       liveness — 200 as long as the process serves HTTP;
    with a wired ``health_fn`` the body reports degradation state
    (``status: degraded``, open breakers per bucket, last-recovery
    metadata) while staying 200 — degraded-but-serving is by design
  * ``/readyz``        readiness — 200 iff the wired `ready_fn()` is
    truthy (for `AutotuneServer`: policy snapshot loaded + bucket grid
    warm), else 503 with a JSON reason; degradation state attached the
    same way
  * ``/telemetry``     the wired telemetry snapshot as JSON (optional;
    includes a ``rollout`` key when a rollout controller is wired)
  * ``/rollout``       canary rollout-controller state (optional)
  * ``/trace``         Chrome trace-event JSON of recent spans (optional)

`lint_exposition` enforces the repo's metric name/label conventions
(``repro_`` prefix, snake_case, ``_total`` counters, ``_seconds`` time
histograms); CI scrapes a live server and runs it (tests/test_obs.py).
"""
from __future__ import annotations

import json
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, List, Optional

from repro.obs.metrics import Histogram, MetricsRegistry

SELF_METRIC = "repro_obs_errors_total"
SELF_HELP = "Instrumentation exceptions swallowed by the fail-open guard."


def _fmt(v: float) -> str:
    """Prometheus sample value: integers bare, floats via repr."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _labelstr(labelnames, key, extra=()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(labelnames, key)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def render_prometheus(registry: MetricsRegistry) -> str:
    """Text exposition format 0.0.4 of every family + the self-metric."""
    lines: List[str] = []
    for fam in registry.collect():
        lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.type}")
        for key, child in fam.samples():
            if isinstance(fam, Histogram):
                for bound, cum in zip(
                        list(fam.bounds) + [float("inf")],
                        child.cumulative()):
                    le = _labelstr(fam.labelnames, key,
                                   extra=(("le", _fmt(bound)),))
                    lines.append(f"{fam.name}_bucket{le} {cum}")
                ls = _labelstr(fam.labelnames, key)
                lines.append(f"{fam.name}_sum{ls} {_fmt(child.sum)}")
                lines.append(f"{fam.name}_count{ls} {child.count}")
            else:
                ls = _labelstr(fam.labelnames, key)
                lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
    lines.append(f"# HELP {SELF_METRIC} {SELF_HELP}")
    lines.append(f"# TYPE {SELF_METRIC} counter")
    lines.append(f"{SELF_METRIC} {registry.errors}")
    return "\n".join(lines) + "\n"


def render_json(registry: MetricsRegistry) -> dict:
    """The same samples as a JSON-ready dict (one entry per family)."""
    out = {}
    for fam in registry.collect():
        samples = []
        for key, child in fam.samples():
            labels = dict(zip(fam.labelnames, key))
            if isinstance(fam, Histogram):
                samples.append({"labels": labels, "sum": child.sum,
                                "count": child.count,
                                "buckets": dict(zip(
                                    (_fmt(b) for b in fam.bounds),
                                    child.cumulative()))})
            else:
                samples.append({"labels": labels, "value": child.value})
        out[fam.name] = {"type": fam.type, "help": fam.help,
                         "samples": samples}
    out[SELF_METRIC] = {"type": "counter", "help": SELF_HELP,
                        "samples": [{"labels": {},
                                     "value": registry.errors}]}
    return out


# ---------------------------------------------------------------------------
# Name/label convention lint (CI scrapes a live /metrics through this)
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"^repro(_[a-z0-9]+)+$")
_LABEL_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[A-Za-z_:][A-Za-z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_PAIR_RE = re.compile(r'\s*(?P<k>[A-Za-z_][A-Za-z0-9_]*)='
                      r'"(?P<v>(?:[^"\\]|\\.)*)"\s*(?:,|$)')


def lint_exposition(text: str) -> List[str]:
    """Check a Prometheus exposition against the repo conventions;
    returns a list of violations (empty = clean)."""
    problems: List[str] = []
    types = {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, mtype = line.split(None, 3)
            types[name] = mtype
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"unparseable sample line: {line!r}")
            continue
        name = m.group("name")
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        family = base if base in types else name
        if not _NAME_RE.match(family):
            problems.append(
                f"{family}: name must be snake_case with 'repro_' prefix")
        mtype = types.get(family)
        if mtype == "counter" and not family.endswith("_total"):
            problems.append(f"{family}: counters must end in '_total'")
        if (mtype == "histogram"
                and ("second" in family or "latency" in family
                     or "duration" in family or "wait" in family)
                and not family.endswith("_seconds")):
            problems.append(
                f"{family}: time histograms must end in '_seconds'")
        for pm in _PAIR_RE.finditer(m.group("labels") or ""):
            label = pm.group("k")
            if label == "le":
                continue
            if not _LABEL_RE.match(label) or label != label.lower():
                problems.append(
                    f"{family}: label {label!r} must be snake_case")
    return problems


# ---------------------------------------------------------------------------
# HTTP front door
# ---------------------------------------------------------------------------

class ObsHTTPServer:
    """Background-thread HTTP server exposing observability state.

    Read-only and fail-open by construction: handlers only read, a
    raising handler answers 500 (and counts in the self-metric), and
    the daemon thread dies with the process. `port=0` binds an
    ephemeral port — read `.port`/`.url` after construction.
    """

    def __init__(self, registry: MetricsRegistry,
                 host: str = "127.0.0.1", port: int = 0,
                 ready_fn: Optional[Callable[[], object]] = None,
                 telemetry_fn: Optional[Callable[[], dict]] = None,
                 trace_fn: Optional[Callable[[], dict]] = None,
                 rollout_fn: Optional[Callable[[], dict]] = None,
                 health_fn: Optional[Callable[[], dict]] = None):
        self.registry = registry
        self.ready_fn = ready_fn
        self.telemetry_fn = telemetry_fn
        self.trace_fn = trace_fn
        self.rollout_fn = rollout_fn
        self.health_fn = health_fn
        obs = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):        # no stderr spam per scrape
                pass

            def do_GET(self):
                try:
                    obs._route(self)
                except BrokenPipeError:
                    pass
                except Exception:
                    obs.registry.count_error()
                    try:
                        self.send_error(500)
                    except Exception:
                        pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    # -- routing -----------------------------------------------------------
    def _route(self, handler: BaseHTTPRequestHandler) -> None:
        path = handler.path.split("?", 1)[0]
        scrapes = self.registry.counter(
            "repro_obs_scrapes_total",
            "HTTP requests served by the observability front door.",
            ("path",))
        if path == "/metrics":
            scrapes.labels(path=path).inc()
            self._respond(handler, 200, render_prometheus(self.registry),
                          "text/plain; version=0.0.4; charset=utf-8")
        elif path == "/metrics.json":
            scrapes.labels(path=path).inc()
            self._respond_json(handler, 200, render_json(self.registry))
        elif path == "/healthz":
            scrapes.labels(path=path).inc()
            # Liveness stays 200 while degraded — a breaker pinning to
            # the safe arm is the process *working as designed*, and
            # restarting it would only lose learner state. The payload
            # carries the degradation detail for operators/alerting.
            payload = {"status": "ok"}
            if self.health_fn is not None:
                state = dict(self.health_fn())
                if state.pop("degraded", False):
                    payload["status"] = "degraded"
                payload.update(state)
            self._respond_json(handler, 200, payload)
        elif path == "/readyz":
            scrapes.labels(path=path).inc()
            ready = bool(self.ready_fn()) if self.ready_fn else True
            payload = {"status": "ready" if ready else "unready"}
            if self.health_fn is not None:
                state = dict(self.health_fn())
                if state.pop("degraded", False):
                    payload["status"] = "degraded"
                payload.update(state)
            self._respond_json(handler, 200 if ready else 503, payload)
        elif path == "/telemetry" and self.telemetry_fn is not None:
            scrapes.labels(path=path).inc()
            snap = self.telemetry_fn()
            if self.rollout_fn is not None:
                snap = dict(snap, rollout=self.rollout_fn())
            self._respond_json(handler, 200, snap)
        elif path == "/rollout" and self.rollout_fn is not None:
            scrapes.labels(path=path).inc()
            self._respond_json(handler, 200, self.rollout_fn())
        elif path == "/trace" and self.trace_fn is not None:
            scrapes.labels(path=path).inc()
            self._respond_json(handler, 200, self.trace_fn())
        else:
            self._respond_json(handler, 404, {"error": "not found",
                                              "path": path})

    @staticmethod
    def _respond(handler, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        handler.send_response(code)
        handler.send_header("Content-Type", ctype)
        handler.send_header("Content-Length", str(len(data)))
        handler.end_headers()
        handler.wfile.write(data)

    @classmethod
    def _respond_json(cls, handler, code: int, obj) -> None:
        cls._respond(handler, code, json.dumps(obj, default=float),
                     "application/json")
