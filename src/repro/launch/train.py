"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Production behaviors demonstrated at host scale (the same code paths the
512-chip mesh uses — swap make_host_mesh for make_production_mesh):
  * sharded state via distributed.sharding rules,
  * fault tolerance: atomic checkpoints every --ckpt-every steps, automatic
    resume from LATEST (kill the process anywhere and relaunch),
  * straggler watchdog: per-step deadline alarms (on real fleets this
    triggers re-slicing; here it logs),
  * optional online precision autotuning (--autotune) via the paper's
    contextual bandit (train.TrainPrecisionController),
  * cross-pod compressed gradient sync (--grad-sync {fp32,bf16,int8}) when
    the mesh has a "pod" axis.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch, get_smoke
from repro.data.tokens import TokenPipeline
from repro.distributed.sharding import (batch_specs, named, param_specs,
                                        residual_spec)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import (AdamWConfig, TrainPrecisionController,
                         TrainStepConfig, global_norm, init_train_state,
                         make_train_step)
from jax.sharding import NamedSharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--grad-sync", default=None,
                    choices=[None, "fp32", "bf16", "int8"])
    ap.add_argument("--step-deadline-s", type=float, default=600.0)
    ap.add_argument("--quant-moments", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    tcfg = TrainStepConfig(
        peak_lr=args.lr, warmup=min(20, args.steps // 10 + 1),
        total_steps=args.steps,
        opt=AdamWConfig(quantize_moments=args.quant_moments),
        compute_dtype=jnp.float32 if not args.production_mesh
        else jnp.bfloat16)

    rs = NamedSharding(mesh, residual_spec(mesh))
    controller = (TrainPrecisionController(total_decisions=args.steps // 10)
                  if args.autotune else None)
    policy = None

    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)

    # Resume (fault tolerance): restore params/opt/step + pipeline cursor.
    if latest_step(args.ckpt_dir) is not None:
        state, meta = restore_checkpoint(args.ckpt_dir, state)
        pipe.load_state_dict(meta["pipeline"])
        print(f"[train] resumed from step {int(state.step)}")

    step_fn = make_train_step(cfg, tcfg, policy=policy,
                              residual_sharding=rs if
                              args.production_mesh else None)
    state_sh = named(param_specs(jax.eval_shape(lambda: state), mesh), mesh)
    with mesh:
        jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None))
        prev_loss = None
        while int(state.step) < args.steps:
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in
                     pipe.next_batch().items()}
            if controller is not None and int(state.step) % 10 == 0:
                gn = 1.0  # grad-norm ratio proxy before first step
                feats = controller.features(gn, 1e-3)
                policy = controller.act(feats)
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if dt > args.step_deadline_s:
                print(f"[watchdog] step {int(state.step)} took {dt:.1f}s "
                      f"(> {args.step_deadline_s}s) — straggler suspected; "
                      "a fleet controller would re-slice here")
            if controller is not None and prev_loss is not None and \
                    int(state.step) % 10 == 1:
                controller.observe(prev_loss, loss,
                                   diverged=not np.isfinite(loss))
            prev_loss = loss
            if int(state.step) % 10 == 0 or int(state.step) == args.steps:
                print(f"[train] step {int(state.step):5d} "
                      f"loss {loss:.4f} ({dt:.2f}s/step)")
            if int(state.step) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, int(state.step), state,
                                {"pipeline": pipe.state_dict()})
    save_checkpoint(args.ckpt_dir, int(state.step), state,
                    {"pipeline": pipe.state_dict()})
    print(f"[train] done at step {int(state.step)}; final loss {loss:.4f}")


if __name__ == "__main__":
    main()
