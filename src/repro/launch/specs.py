"""ShapeDtypeStruct stand-ins for every model input (dry-run §2).

Weak-type-correct, shardable, no device allocation. For train/prefill the
inputs are token batches (+ the modality-stub embeddings); decode shapes
carry a single new token plus the KV/latent/SSM caches at seq_len fill."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import init_caches


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32),
             "loss_mask": sds((b, s), jnp.float32)}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = sds((b, cfg.n_prefix_embeds, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                       cache_dtype=jnp.bfloat16):
    b, s_max = shape.global_batch, shape.seq_len
    token = sds((b, 1), jnp.int32)
    caches = jax.eval_shape(
        lambda: init_caches(cfg, b, s_max, cache_dtype))
    return token, caches


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Assignment-facing entry: all inputs for the cell's step function."""
    if shape.kind in ("train", "prefill"):
        return {"batch": train_batch_specs(cfg, shape)}
    token, caches = decode_input_specs(cfg, shape)
    return {"token": token, "caches": caches}
