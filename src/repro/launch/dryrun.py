import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step with AdamW for
train shapes; forward for prefill; cached decode_step for decode shapes),
shards it over the production mesh, lowers and compiles it, and records:

  * memory_analysis()  — per-device bytes (proves it fits)
  * cost_analysis()    — HLO FLOPs / bytes for the §Roofline terms
  * collective bytes   — parsed from the post-SPMD HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes; not in cost_analysis)

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_archs, get_arch, valid_cells
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (batch_specs, cache_specs, named,
                                        param_specs, residual_spec)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_input_specs, train_batch_specs
from repro.models import decode_step, forward, init_params
from repro.train import AdamWConfig, TrainStepConfig, make_train_step
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
                "s64": 8, "u64": 8, "pred": 1, "s16": 2, "u16": 2, "c64": 8}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum output sizes of collective ops in the (post-SPMD, per-device)
    HLO. Returns (total_bytes, by_type, counts)."""
    by_type, counts = {}, {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_blob, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes_blob):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        by_type[op] = by_type.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return sum(by_type.values()), by_type, counts


def _cost_dict(cost) -> dict:
    """Normalize compiled.cost_analysis() across JAX versions.

    Older JAX returns one dict; newer returns a list of per-module dicts.
    Use the main (post-SPMD) module only — its totals already include
    called computations, so summing across modules would double-count."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def _opt_cfg(cfg: ArchConfig) -> AdamWConfig:
    # int8 moments for the >=50B archs (fits HBM at 512 chips, DESIGN §5).
    quant = cfg.params_total() > 5e10
    return AdamWConfig(quantize_moments=quant)


# §Perf hillclimb switches (set by --qchunks / --cast-bf16; defaults are the
# paper-faithful-baseline execution).
OPT = {"cast_bf16": False}


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    rs = NamedSharding(mesh, residual_spec(mesh))
    if shape.kind == "train":
        tcfg = TrainStepConfig(opt=_opt_cfg(cfg),
                               compute_dtype=jnp.bfloat16,
                               cast_params_for_compute=OPT["cast_bf16"])
        step = make_train_step(cfg, tcfg, residual_sharding=rs)
        key = jax.random.PRNGKey(0)
        state_shapes = jax.eval_shape(
            lambda k: TrainState(
                init_params(cfg, k, jnp.float32),
                adamw_init(jax.eval_shape(
                    lambda kk: init_params(cfg, kk, jnp.float32), k),
                    tcfg.opt),
                jnp.zeros((), jnp.int32)), key)
        batch_shapes = train_batch_specs(cfg, shape)
        state_sh = named(param_specs(state_shapes, mesh), mesh)
        batch_sh = named(batch_specs(batch_shapes, mesh), mesh)
        return (step, (state_shapes, batch_shapes),
                (state_sh, batch_sh), (state_sh, None))
    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return forward(params, batch["tokens"], cfg, jnp.bfloat16,
                           prefix_embeds=batch.get("prefix_embeds"),
                           residual_sharding=rs)
        key = jax.random.PRNGKey(0)
        params_shapes = jax.eval_shape(
            lambda k: init_params(cfg, k, jnp.bfloat16), key)
        batch_shapes = train_batch_specs(cfg, shape)
        batch_shapes.pop("loss_mask")
        p_sh = named(param_specs(params_shapes, mesh), mesh)
        b_sh = named(batch_specs(batch_shapes, mesh), mesh)
        out_sh = NamedSharding(mesh, batch_specs(
            {"o": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.vocab_size),
                jnp.float32)}, mesh)["o"])
        return prefill_fn, (params_shapes, batch_shapes), (p_sh, b_sh), \
            out_sh
    # decode
    def serve_fn(params, token, caches):
        return decode_step(params, token, caches, cfg, jnp.bfloat16)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, jnp.bfloat16), key)
    token_shapes, caches_shapes = decode_input_specs(cfg, shape)
    p_sh = named(param_specs(params_shapes, mesh), mesh)
    t_sh = NamedSharding(mesh, batch_specs(
        {"t": token_shapes}, mesh)["t"])
    c_sh = named(cache_specs(caches_shapes, mesh), mesh)
    return (serve_fn, (params_shapes, token_shapes, caches_shapes),
            (p_sh, t_sh, c_sh), (None, c_sh))


def _truncated(cfg: ArchConfig, k_groups: int) -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        cfg, n_layers=cfg.first_dense + k_groups * cfg.pattern_len)


def _cost_numbers(cfg, shape, mesh):
    """flops / bytes / collective stats for one compile of `cfg`."""
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
        cost = _cost_dict(compiled.cost_analysis())
        total, by_type, counts = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(total),
        "coll_by_type": by_type,
    }


def calibrate_costs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """Exact per-device cost extrapolation.

    XLA's cost analysis counts a while-loop body ONCE (not trip-count
    times), so the scanned layer stack is undercounted. We compile the same
    cell at 1 and 2 layer-groups — depths at which the model UNROLLS the
    stack (models.transformer._scan_groups) — and extrapolate:
        per_group = cost(2) - cost(1);  total = cost(1) + (G-1)*per_group.
    This is exact for the layer stack (groups are identical) and keeps the
    non-layer parts (embedding, loss, optimizer) from the k=1 compile."""
    a1 = _cost_numbers(_truncated(cfg, 1), shape, mesh)
    a2 = _cost_numbers(_truncated(cfg, 2), shape, mesh)
    n_groups = (cfg.n_layers - cfg.first_dense) // cfg.pattern_len

    def extra(key):
        per = max(a2[key] - a1[key], 0.0)
        return a1[key] + (n_groups - 1) * per, per

    flops, flops_per_group = extra("flops")
    byts, _ = extra("bytes")
    coll, _ = extra("coll")
    by_type = {}
    for op in set(a1["coll_by_type"]) | set(a2["coll_by_type"]):
        v1 = a1["coll_by_type"].get(op, 0)
        v2 = a2["coll_by_type"].get(op, 0)
        by_type[op] = v1 + (n_groups - 1) * max(v2 - v1, 0)
    return {
        "flops": flops, "bytes_accessed": byts, "collective_bytes": coll,
        "collective_by_type": by_type, "n_groups": n_groups,
        "flops_per_group": flops_per_group,
        "calib_k1": a1, "calib_k2": a2,
    }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for train; 2*N_active per generated token for decode."""
    n = cfg.params_active()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence


def dryrun_cell(arch_name: str, shape_name: str, multi_pod: bool,
                out_dir: str = ART_DIR, verbose: bool = True,
                calibrate: bool = True) -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
    art = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "n_devices": int(np.prod([mesh.shape[a] for a in mesh.axis_names])),
        "kind": shape.kind,
        "params_total": cfg.params_total(),
        "params_active": cfg.params_active(),
        "model_flops": model_flops(cfg, shape),
    }
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        art["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        art["compile_s"] = round(time.time() - t1, 1)
        try:
            mem = compiled.memory_analysis()
            print(mem)
            art["memory"] = _mem_dict(mem)
        except Exception as e:                    # pragma: no cover
            art["memory"] = {"error": str(e)}
        try:
            cost = _cost_dict(compiled.cost_analysis())
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
            art["flops"] = float(cost.get("flops", 0.0))
            art["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
            art["cost_raw"] = {k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float))
                               and np.isfinite(v)}
        except Exception as e:                    # pragma: no cover
            art["cost_error"] = str(e)
        text = compiled.as_text()
        total, by_type, counts = collective_bytes(text)
        art["collective_bytes_raw"] = total       # loop bodies counted once
        art["collective_counts_raw"] = counts
        art["cost_is_per_device"] = True          # post-SPMD module
    # Exact extrapolated costs via truncated-depth calibration (single-pod
    # roofline table; the multi-pod pass proves compile/sharding only).
    if calibrate:
        t2 = time.time()
        calib = calibrate_costs(cfg, shape, mesh)
        art["calibrate_s"] = round(time.time() - t2, 1)
        art["flops_raw"] = art.get("flops", 0.0)
        art["bytes_accessed_raw"] = art.get("bytes_accessed", 0.0)
        art["flops"] = calib["flops"]
        art["bytes_accessed"] = calib["bytes_accessed"]
        art["collective_bytes"] = calib["collective_bytes"]
        art["collective_by_type"] = calib["collective_by_type"]
        art["n_groups"] = calib["n_groups"]
        art["calibration"] = {k: calib[k] for k in
                              ("calib_k1", "calib_k2", "flops_per_group")}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch_name}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    if verbose:
        coll = art.get("collective_bytes", art.get("collective_bytes_raw",
                                                   0))
        print(f"[dryrun] {arch_name} x {shape_name} x {mesh_name}: "
              f"lower {art['lower_s']}s compile {art['compile_s']}s "
              f"flops={art.get('flops', 0):.3e} coll={coll:.3e}B -> {path}")
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    ap.add_argument("--qchunks", type=int, default=0,
                    help="query-chunked attention (memory lever)")
    ap.add_argument("--cast-bf16", action="store_true",
                    help="bf16 param gathers (collective lever)")
    args = ap.parse_args()

    if args.qchunks:
        from repro.models import attention
        attention.QCHUNKS = args.qchunks
    OPT["cast_bf16"] = bool(args.cast_bf16)

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    cells = []
    if args.all:
        # Smallest-first: most cells land early on a 1-core host.
        for name, cfg in sorted(all_archs().items(),
                                key=lambda kv: kv[1].params_total()):
            for shp in valid_cells(cfg):
                cells.append((name, shp.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shp in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            path = os.path.join(args.out, f"{arch}__{shp}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip existing {path}")
                continue
            try:
                # Calibration only matters for the single-pod roofline.
                dryrun_cell(arch, shp, mp, args.out,
                            calibrate=not (args.no_calibrate or mp))
            except Exception:
                traceback.print_exc()
                failures.append((arch, shp, mesh_name))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete:", len(cells) * len(meshes), "cells")


if __name__ == "__main__":
    main()
