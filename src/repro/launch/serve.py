"""Serving launcher: batched generation with cached decode.

``python -m repro.launch.serve --arch gemma-2b --smoke --batch 4 --new 16``
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_smoke
from repro.models import init_params
from repro.precision import FORMAT_ID
from repro.serve import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-format", default=None,
                    help="emulated KV-cache format (e.g. e4m3, bf16)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    scfg = ServeConfig(max_new_tokens=args.new,
                       temperature=args.temperature,
                       compute_dtype=jnp.float32,
                       cache_fmt=FORMAT_ID[args.kv_format]
                       if args.kv_format else None)
    t0 = time.time()
    toks = generate(params, prompts, cfg, scfg, key)
    toks.block_until_ready()
    dt = time.time() - t0
    print(f"[serve] {args.batch} seqs x {args.new} new tokens in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print(toks[: min(2, args.batch)])


if __name__ == "__main__":
    main()
