"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A function, not a module constant: importing this module never touches jax
device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (tests/examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))
