"""Decoder stack: heterogeneous layer patterns under scan-over-layers.

Depth is organized as [prefix layers (unrolled)] + [n_groups x pattern
(lax.scan)]: the scanned body contains one full repetition of the arch's
layer pattern (attention flavors / mamba / MoE cycle), so HLO size and
compile time are O(pattern), not O(depth). Each scan body is rematerialized
(jax.checkpoint) — the standard memory/compute trade at 4k-512k context.

The same parameter tree serves train (forward), prefill, and single-token
decode (with per-layer caches stacked along the scan dimension).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from . import attention as attn
from . import mamba as ssm
from . import moe as moe_lib
from .layers import embed, ffn, init_embed, init_ffn, rms_norm, softcap, \
    unembed


def _use_rope_at(cfg: ArchConfig, layer: int) -> bool:
    if cfg.nope_every and (layer + 1) % cfg.nope_every == 0:
        return False
    return True


def _n_groups(layers_tree) -> int:
    return jax.tree_util.tree_leaves(layers_tree)[0].shape[0]


def _scan_groups(group_fn, x, layers_tree):
    """lax.scan over layer groups with remat; unrolled for <= 2 groups.

    The unrolled path keeps HLO flop/collective accounting exact for the
    dry-run's truncated-depth calibration (XLA's cost analysis counts a
    while-loop body once, not trip-count times — launch/dryrun.py diffs two
    unrolled depths to recover per-group costs)."""
    n_groups = _n_groups(layers_tree)
    body = jax.checkpoint(group_fn)
    if n_groups <= 2:
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda v: v[g], layers_tree)
            x, _ = body(x, gp)
        return x
    x, _ = jax.lax.scan(body, x, layers_tree)
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, layer: int, dtype) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    kind = cfg.layer_kind(layer)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg, dtype)
    elif cfg.use_mla:
        p["mixer"] = attn.init_mla(k1, cfg, dtype)
    else:
        p["mixer"] = attn.init_gqa(k1, cfg, dtype)
    if kind != "mamba" or cfg.d_ff or cfg.n_experts:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.is_moe_layer(layer):
            p["ffn"] = moe_lib.init_moe(k2, cfg, dtype)
        elif cfg.d_ff:
            p["ffn"] = init_ffn(k2, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype)
        if "ffn" in p:
            p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    p_len = cfg.pattern_len
    body = cfg.n_layers - cfg.first_dense
    assert body % p_len == 0, (cfg.name, body, p_len)
    n_groups = body // p_len
    keys = jax.random.split(key, 3 + cfg.first_dense + n_groups * p_len)
    params: Dict[str, Any] = {
        "embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype,
                            cfg.tie_embeddings),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    ki = 1
    prefix = []
    for l in range(cfg.first_dense):
        prefix.append(_init_block(keys[ki], cfg, l, dtype))
        ki += 1
    if prefix:
        params["prefix"] = prefix
    groups = []
    for g in range(n_groups):
        grp = {}
        for j in range(p_len):
            grp[f"l{j}"] = _init_block(keys[ki], cfg,
                                       cfg.first_dense + j, dtype)
            ki += 1
        groups.append(grp)
    params["layers"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *groups) if n_groups > 1 else \
        jax.tree_util.tree_map(lambda x: x[None], groups[0])
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block_forward(bp, x, cfg: ArchConfig, layer: int, positions,
                   policy=None):
    kind = cfg.layer_kind(layer)
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "mamba":
        a = ssm.mamba_forward(bp["mixer"], h, cfg, policy)
    elif cfg.use_mla:
        a = attn.mla_forward(bp["mixer"], h, cfg, positions, policy)
    else:
        a = attn.gqa_forward(bp["mixer"], h, cfg, kind, positions,
                             _use_rope_at(cfg, layer), policy)
    if cfg.post_norms:
        a = rms_norm(a, bp["ln1_post"], cfg.norm_eps)
    x = x + a
    if "ffn" in bp:
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe_layer(layer):
            f = moe_lib.moe_ffn(bp["ffn"], h, cfg, policy)
        else:
            f = ffn(bp["ffn"], h, cfg.act, policy)
        if cfg.post_norms:
            f = rms_norm(f, bp["ln2_post"], cfg.norm_eps)
        x = x + f
    return x


def forward(params, tokens: jnp.ndarray, cfg: ArchConfig,
            dtype=jnp.bfloat16, policy=None,
            prefix_embeds: Optional[jnp.ndarray] = None,
            residual_sharding=None) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, S, vocab) fp32.

    prefix_embeds: modality-stub injection (B, n_prefix, d) replacing the
    embeddings of the first n_prefix positions (DESIGN.md §4: audio/vlm
    frontends are stubs supplying precomputed frame/patch embeddings).
    residual_sharding: optional NamedSharding for the (B, S, d) residual
    stream at scan-group boundaries — sequence parallelism (DESIGN.md §5)."""
    x = hidden_states(params, tokens, cfg, dtype, policy, prefix_embeds,
                      residual_sharding)
    logits = unembed(params["embed"], x, cfg.tie_embeddings, policy)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits.astype(jnp.float32)


def hidden_states(params, tokens: jnp.ndarray, cfg: ArchConfig,
                  dtype=jnp.bfloat16, policy=None,
                  prefix_embeds: Optional[jnp.ndarray] = None,
                  residual_sharding=None) -> jnp.ndarray:
    """Final-norm hidden states (B, S, d) — forward() without the unembed."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens, dtype, cfg.embed_scale, cfg.d_model)
    if prefix_embeds is not None:
        n = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(dtype), x[:, n:]], axis=1)
    positions = jnp.arange(s, dtype=jnp.int32)

    def constrain(v):
        if residual_sharding is not None:
            return jax.lax.with_sharding_constraint(v, residual_sharding)
        return v

    x = constrain(x)
    for l, bp in enumerate(params.get("prefix", [])):
        x = constrain(_block_forward(bp, x, cfg, l, positions, policy))

    p_len = cfg.pattern_len

    def group_fn(x, gp):
        for j in range(p_len):
            x = _block_forward(gp[f"l{j}"], x, cfg, cfg.first_dense + j,
                               positions, policy)
        return constrain(x), None

    x = _scan_groups(group_fn, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


LOSS_CHUNKS = 8


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            dtype=jnp.bfloat16, policy=None, residual_sharding=None):
    """Next-token cross entropy, chunked over the sequence.

    The (B, S_chunk, vocab) logits of each chunk are materialized inside a
    jax.checkpoint region (recomputed in backward), bounding peak memory to
    one chunk of logits instead of the full (B, S, vocab) tensor — at 200k
    vocabs this is the difference between ~2 GB and ~20 GB of temps. Chunks
    are an unrolled python loop, so HLO flop accounting stays exact."""
    tokens = batch["tokens"]
    x = hidden_states(params, tokens, cfg, dtype, policy,
                      batch.get("prefix_embeds"), residual_sharding)
    targets = jnp.concatenate([tokens[:, 1:],
                               jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = batch.get("loss_mask",
                     jnp.ones_like(tokens, jnp.float32))
    mask = mask.astype(jnp.float32).at[:, -1].set(0.0)

    s = tokens.shape[1]
    n_chunks = LOSS_CHUNKS if s % LOSS_CHUNKS == 0 else 1

    @jax.checkpoint
    def chunk_nll(xc, tc, mc):
        logits = unembed(params["embed"], xc, cfg.tie_embeddings, policy)
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mc)

    csz = s // n_chunks
    total = 0.0
    for c in range(n_chunks):
        sl = slice(c * csz, (c + 1) * csz)
        total = total + chunk_nll(x[:, sl], targets[:, sl], mask[:, sl])
    loss = total / jnp.clip(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "ntokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Decode (single token, cached)
# ---------------------------------------------------------------------------

def _init_block_cache(cfg: ArchConfig, layer: int, batch: int, s_max: int,
                      dtype, kv_dtype=None):
    kind = cfg.layer_kind(layer)
    kv_dtype = kv_dtype or dtype
    if kind == "mamba":
        return ssm.init_mamba_cache(batch, cfg, dtype)
    if cfg.use_mla:
        return attn.init_mla_cache(batch, s_max, cfg, kv_dtype)
    window = cfg.window if kind == "local" and cfg.window else s_max
    chunk = cfg.attn_chunk if kind == "chunked" and cfg.attn_chunk else s_max
    s_eff = min(s_max, max(window, 1) if kind == "local" else s_max)
    # Windowed/chunked layers could use ring buffers of length window;
    # kept full-length here for correctness, ring-buffer is a §Perf lever.
    del s_eff, chunk
    return attn.init_kv_cache(batch, s_max, cfg, kv_dtype)


def init_caches(cfg: ArchConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16, kv_dtype=None):
    caches: Dict[str, Any] = {}
    if cfg.first_dense:
        caches["prefix"] = [
            _init_block_cache(cfg, l, batch, s_max, dtype, kv_dtype)
            for l in range(cfg.first_dense)]
    p_len = cfg.pattern_len
    n_groups = (cfg.n_layers - cfg.first_dense) // p_len
    grp = {f"l{j}": _init_block_cache(cfg, cfg.first_dense + j, batch,
                                      s_max, dtype, kv_dtype)
           for j in range(p_len)}
    caches["layers"] = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None],
                                   (n_groups,) + x.shape).copy(), grp)
    return caches


def _block_decode(bp, x, cache, cfg: ArchConfig, layer: int, policy=None,
                  cache_fmt=None):
    kind = cfg.layer_kind(layer)
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "mamba":
        a, cache = ssm.mamba_decode(bp["mixer"], h, cache, cfg, policy)
    elif cfg.use_mla:
        a, cache = attn.mla_decode(bp["mixer"], h, cache, cfg, policy)
    else:
        a, cache = attn.gqa_decode(bp["mixer"], h, cache, cfg, kind,
                                   _use_rope_at(cfg, layer), policy,
                                   cache_fmt)
    if cfg.post_norms:
        a = rms_norm(a, bp["ln1_post"], cfg.norm_eps)
    x = x + a
    if "ffn" in bp:
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe_layer(layer):
            f = moe_lib.moe_ffn(bp["ffn"], h, cfg, policy)
        else:
            f = ffn(bp["ffn"], h, cfg.act, policy)
        if cfg.post_norms:
            f = rms_norm(f, bp["ln2_post"], cfg.norm_eps)
        x = x + f
    return x, cache


def decode_step(params, token: jnp.ndarray, caches, cfg: ArchConfig,
                dtype=jnp.bfloat16, policy=None, cache_fmt=None):
    """token: (B, 1) int32 -> (logits (B, 1, vocab), new caches)."""
    x = embed(params["embed"], token, dtype, cfg.embed_scale, cfg.d_model)
    new_prefix = []
    for l, bp in enumerate(params.get("prefix", [])):
        x, c = _block_decode(bp, x, caches["prefix"][l], cfg, l, policy,
                             cache_fmt)
        new_prefix.append(c)

    p_len = cfg.pattern_len

    def group_fn(x, scans):
        gp, gc = scans
        new_c = {}
        for j in range(p_len):
            x, c = _block_decode(gp[f"l{j}"], x, gc[f"l{j}"], cfg,
                                 cfg.first_dense + j, policy, cache_fmt)
            new_c[f"l{j}"] = c
        return x, new_c

    x, new_layer_caches = jax.lax.scan(group_fn, x,
                                       (params["layers"],
                                        caches["layers"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings, policy)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    new_caches = {"layers": new_layer_caches}
    if new_prefix:
        new_caches["prefix"] = new_prefix
    return logits, new_caches
