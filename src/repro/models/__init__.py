from .transformer import (decode_step, forward, init_caches, init_params,
                          loss_fn)

__all__ = ["decode_step", "forward", "init_caches", "init_params", "loss_fn"]
