"""Mamba-1 (S6) block: in-proj, causal depthwise conv, selective SSM scan.

The scan is chunked: within-chunk `lax.associative_scan` (parallel,
MXU/VPU-friendly), cross-chunk `lax.scan` carrying the (B, d_inner, d_state)
boundary state — numerically identical to the full recurrence but with
bounded intermediates (DESIGN.md §5: this is the TPU-native re-think of the
CUDA selective-scan kernel; there is no warp-shuffle analogue, the chunk
boundary IS the parallelism unit). Decode keeps (conv window, ssm state) as
the cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .layers import dot, init_dense

CHUNK = 128


def init_mamba(key, cfg: ArchConfig, dtype):
    d, di, ds, dtr = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank)
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A.
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    return {
        "in_proj": init_dense(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di),
                                     dtype=jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_dense(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": init_dense(ks[3], dtr, di, dtype),
        "dt_bias": (jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1)))))
                    ).astype(jnp.float32),
        "A_log": jnp.log(a_init),                        # fp32 pinned
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[5], di, d, dtype),
    }


def _ssm_params(params, xc, cfg):
    """xc: (B, S, di) post-conv activations -> dt, B_t, C_t (fp32)."""
    dtr, ds = cfg.dt_rank, cfg.ssm_state
    proj = dot(xc, params["x_proj"]).astype(jnp.float32)
    dt_in, Bt, Ct = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_in,
                    params["dt_proj"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + params["dt_bias"])
    return dt, Bt, Ct


def _scan_chunked(dt, Bt, Ct, xf, A, h0):
    """Selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t; y = C_t.h_t,
    chunked with the (B, chunk, di, ds) discretized tensors built INSIDE the
    chunk body.

    Materializing dA/dBx for the full sequence costs B*S*di*ds floats
    (falcon-mamba train_4k: 34 TB/device — the §Perf worst-cell pathology);
    per-chunk construction bounds it to B*chunk*di*ds and lets XLA keep the
    state tensors fused/VMEM-resident. Returns (y (B,S,di) fp32, h_last).

    dt, xf: (B, S, di); Bt, Ct: (B, S, ds); A: (di, ds); h0: (B, di, ds).
    """
    b, s, di = dt.shape
    ds = Bt.shape[-1]
    chunk = CHUNK if s % CHUNK == 0 else s
    n_chunks = s // chunk

    def chunk_step(h, inputs):
        dt_c, b_c, c_c, x_c = inputs                     # (B, chunk, ...)
        dA = jnp.exp(dt_c[..., None] * A[None, None])    # (B,chunk,di,ds)
        dBx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        a_acc, bx_acc = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        h_all = bx_acc + a_acc * h[:, None]
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all, c_c,
                         preferred_element_type=jnp.float32)
        return h_all[:, -1], y_c

    def cs(v):
        return v.reshape(b, n_chunks, chunk, *v.shape[2:]).swapaxes(0, 1)

    h_last, y_chunks = jax.lax.scan(
        chunk_step, h0, (cs(dt), cs(Bt), cs(Ct), cs(xf)))
    y = y_chunks.swapaxes(0, 1).reshape(b, s, di)
    return y, h_last


def _causal_conv(x, w, b, state=None):
    """x: (B, S, di); w: (K, di) depthwise. state: (B, K-1, di) or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(k))
    return out + b.astype(x.dtype), xp[:, -(k - 1):]


def mamba_forward(params, x: jnp.ndarray, cfg: ArchConfig,
                  policy=None) -> jnp.ndarray:
    """x: (B, S, d) with S % CHUNK == 0 (shapes in this repo are)."""
    b, s, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    xz = dot(x, params["in_proj"], policy, "ssm")
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xr, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bt, Ct = _ssm_params(params, xc, cfg)
    A = -jnp.exp(params["A_log"])                        # (di, ds) fp32
    xf = xc.astype(jnp.float32)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, _ = _scan_chunked(dt, Bt, Ct, xf, A, h0)
    y = y + params["D"] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return dot(y, params["out_proj"], policy, "ssm")


class MambaCache(NamedTuple):
    conv: jnp.ndarray     # (B, K-1, di)
    h: jnp.ndarray        # (B, di, ds) fp32


def init_mamba_cache(batch: int, cfg: ArchConfig, dtype) -> MambaCache:
    return MambaCache(
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))


def mamba_decode(params, x: jnp.ndarray, cache: MambaCache,
                 cfg: ArchConfig, policy=None):
    """One-token step. x: (B, 1, d)."""
    b = x.shape[0]
    xz = dot(x, params["in_proj"], policy, "ssm")
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                  cache.conv)
    xc = jax.nn.silu(xc)
    dt, Bt, Ct = _ssm_params(params, xc, cfg)
    A = -jnp.exp(params["A_log"])
    xf = xc.astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :, None] * A[None])            # (B,di,ds)
    dBx = (dt[:, 0] * xf[:, 0])[..., None] * Bt[:, 0, None, :]
    h = dA * cache.h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0],
                   preferred_element_type=jnp.float32)
    y = y + params["D"] * xf[:, 0]
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    out = dot(y, params["out_proj"], policy, "ssm")
    return out, MambaCache(conv_state.astype(cache.conv.dtype), h)
