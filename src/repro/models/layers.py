"""Shared neural layers (functional, no framework dependency).

Conventions:
  * params are nested dicts of jnp arrays; init_* builds them, apply-style
    functions consume them;
  * params live in `param_dtype` (fp32 master by default); compute runs in
    the caller's `dtype` (bf16 on TPU); norm statistics, softmax and router
    logits are pinned to fp32 (the precision-autotuner's non-negotiables,
    DESIGN.md §4);
  * every matmul routes through `dot()` so the precision policy can swap in
    emulated-format semantics (kernels/qmatmul) without touching model code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dot(x: jnp.ndarray, w: jnp.ndarray, policy=None,
        step: str = "default") -> jnp.ndarray:
    """Policy-routable matmul: x @ w with fp32 MXU accumulation."""
    if policy is not None:
        return policy.matmul(x, w, step)
    return jnp.dot(x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)


def activate(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(act)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions: (..., S) int32 -> (cos, sin) of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    if cos.ndim == 2:
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_,
                           x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": init_dense(k1, d_model, d_ff, dtype),
        "wi_up": init_dense(k2, d_model, d_ff, dtype),
        "wo": init_dense(k3, d_ff, d_model, dtype),
    }


def ffn(params, x: jnp.ndarray, act: str, policy=None) -> jnp.ndarray:
    g = activate(dot(x, params["wi_gate"], policy, "ffn"), act)
    u = dot(x, params["wi_up"], policy, "ffn")
    return dot(g * u, params["wo"], policy, "ffn")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (vocab, d_model),
                                         dtype=jnp.float32) * 0.02
                       ).astype(dtype)}
    if not tie:
        p["unembed"] = init_dense(k2, d_model, vocab, dtype)
    return p


def embed(params, tokens: jnp.ndarray, dtype, scale: bool,
          d_model: int) -> jnp.ndarray:
    x = params["embedding"].astype(dtype)[tokens]
    if scale:
        x = x * jnp.asarray(np.sqrt(d_model), dtype)
    return x


def unembed(params, x: jnp.ndarray, tie: bool, policy=None) -> jnp.ndarray:
    if tie:
        w = params["embedding"].astype(x.dtype).T
        return jnp.dot(x, w, preferred_element_type=jnp.float32)
    return jnp.dot(x, params["unembed"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
