"""Mixture-of-Experts with sort-based capacity dispatch.

Design (DESIGN.md §5): tokens are routed with a top-k fp32 router; dispatch
uses argsort + scatter into per-expert capacity buffers (E, C, d) rather
than the dense one-hot (T, E, C) einsum — the dense form materializes
T*E*C elements (1e13 for deepseek-v2 at train_4k) while the scatter form is
O(T*k*d + E*C*d) and keeps FLOPs at the *active*-parameter level, which is
what the 6·N_active·D roofline accounting assumes. Under GSPMD the expert
dimension shards over the "model" axis (EP) and the token dimension over
"data"; the scatter/gather lowers to all-to-all style collectives.

Over-capacity slots drop (standard capacity-factor semantics); the residual
stream carries dropped tokens unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .layers import activate, dot, init_dense, init_ffn, ffn


def init_moe(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    dff = cfg.d_ff_expert or cfg.d_ff
    keys = jax.random.split(key, 5)
    p = {
        "router": init_dense(keys[0], d, cfg.n_experts, jnp.float32,
                             scale=0.02),
        "wi_gate": (jax.random.normal(keys[1], (cfg.n_experts, d, dff),
                                      dtype=jnp.float32)
                    / np.sqrt(d)).astype(dtype),
        "wi_up": (jax.random.normal(keys[2], (cfg.n_experts, d, dff),
                                    dtype=jnp.float32)
                  / np.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(keys[3], (cfg.n_experts, dff, d),
                                 dtype=jnp.float32)
               / np.sqrt(dff)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(keys[4], d,
                               dff * cfg.n_shared_experts, dtype)
    return p


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ffn(params, x: jnp.ndarray, cfg: ArchConfig,
            policy=None) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). Routing per token, group dim = batch."""
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(s, cfg)
    xt = x.reshape(b, s, d)

    # Router in fp32 (pinned — the kappa-sensitive step, DESIGN §4).
    logits = jnp.einsum("bsd,de->bse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, K)             # (B, S, K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # Slot bookkeeping per batch group: sort slots by expert id.
    slot_e = experts.reshape(b, s * K)                   # (B, T)
    order = jnp.argsort(slot_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(slot_e, order, axis=-1)
    # Position within each expert's run = index - first-index-of-expert.
    t = s * K
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    posn = jnp.arange(t)[None] - jnp.take_along_axis(first, sorted_e, -1)
    keep = posn < C

    tok_of_slot = order // K                             # (B, T)
    xin = jnp.take_along_axis(xt, tok_of_slot[..., None], axis=1)  # (B,T,d)
    # Scatter into capacity buffers (B, E, C, d).
    buf = jnp.zeros((b, E, C, d), x.dtype)
    e_idx = jnp.where(keep, sorted_e, 0)
    c_idx = jnp.where(keep, posn, 0).astype(jnp.int32)
    bidx = jnp.arange(b)[:, None] * jnp.ones((1, t), jnp.int32)
    xin_masked = jnp.where(keep[..., None], xin, 0)
    buf = buf.at[bidx, e_idx, c_idx].add(xin_masked)

    # Expert FFN, batched over E: (B,E,C,d) x (E,d,f).
    wd = x.dtype
    g = activate(jnp.einsum("becd,edf->becf", buf,
                            params["wi_gate"].astype(wd),
                            preferred_element_type=jnp.float32).astype(wd),
                 cfg.act)
    u = jnp.einsum("becd,edf->becf", buf, params["wi_up"].astype(wd),
                   preferred_element_type=jnp.float32).astype(wd)
    h = jnp.einsum("becf,efd->becd", g * u, params["wo"].astype(wd),
                   preferred_element_type=jnp.float32).astype(wd)

    # Gather back to slots, weight by gates, combine per token.
    y_slot = h[bidx, e_idx, c_idx]                       # (B, T, d)
    y_slot = jnp.where(keep[..., None], y_slot, 0)
    slot_gate = jnp.take_along_axis(gates.reshape(b, t), order, axis=-1)
    y_slot = y_slot * slot_gate[..., None].astype(wd)
    y = jnp.zeros_like(xt).at[bidx, tok_of_slot].add(y_slot)

    if cfg.n_shared_experts:
        y = y + ffn(params["shared"], xt, cfg.act, policy)
    return y.reshape(b, s, d)


def aux_load_balance_loss(params, x: jnp.ndarray,
                          cfg: ArchConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary (fraction x probability)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * imp)
