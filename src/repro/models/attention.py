"""Attention variants: GQA/MQA (global, windowed, chunked), and MLA.

Prefill/train paths use masked einsum attention (XLA-SPMD friendly; the
Pallas flash kernel in kernels/flash_attention is the TPU drop-in).
Decode paths attend against a KV cache; MLA decode uses the absorbed-matrix
formulation (scores in the latent space — this is what makes 500k-token MLA
caches feasible, and is one of the §Perf hillclimb levers).
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .layers import apply_rope, dot, init_dense, rms_norm, rope_freqs, softcap

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def attn_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, kind: str,
              window: int = 0, chunk: int = 0) -> jnp.ndarray:
    """(..., S_q, S_k) additive-mask boolean: True = attend."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    if kind == "local" and window:
        causal &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    if kind == "chunked" and chunk:
        causal &= (q_pos[..., :, None] // chunk) == \
                  (k_pos[..., None, :] // chunk)
    return causal


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, d, hq * hd, dtype),
        "wk": init_dense(k2, d, hkv * hd, dtype),
        "wv": init_dense(k3, d, hkv * hd, dtype),
        "wo": init_dense(k4, hq * hd, d, dtype),
    }


# Query-chunked attention (memory-term lever, §Perf): >0 splits the query
# axis into this many python-unrolled, rematerialized chunks so the (Sq, Sk)
# score tensor never exceeds (Sq/n, Sk). Unrolled (not lax.scan) so HLO
# flop/byte accounting stays exact for the dry-run. 0 = single-shot einsum.
QCHUNKS = int(os.environ.get("REPRO_ATTN_QCHUNKS", "0"))


def _sdpa_full(q, k, v, mask, scale, attn_cap):
    """q: (B,Sq,Hq,D) k/v: (B,Sk,Hkv,D); grouped heads."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, attn_cap)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out.reshape(b, sq, hq, d)


def _sdpa(q, k, v, mask, scale, attn_cap):
    sq = q.shape[1]
    n = QCHUNKS
    if n <= 1 or sq % n or sq // n < 128:
        return _sdpa_full(q, k, v, mask, scale, attn_cap)
    csz = sq // n
    body = jax.checkpoint(_sdpa_full, static_argnums=(4, 5))
    outs = [body(q[:, i * csz:(i + 1) * csz], k, v,
                 mask[:, i * csz:(i + 1) * csz], scale, attn_cap)
            for i in range(n)]
    return jnp.concatenate(outs, axis=1)


def gqa_forward(params, x: jnp.ndarray, cfg: ArchConfig, kind: str,
                positions: jnp.ndarray, use_rope: bool = True,
                policy=None) -> jnp.ndarray:
    """Train/prefill self-attention. x: (B, S, d); positions: (S,)."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dot(x, params["wq"], policy, "attn").reshape(b, s, hq, hd)
    k = dot(x, params["wk"], policy, "attn").reshape(b, s, hkv, hd)
    v = dot(x, params["wv"], policy, "attn").reshape(b, s, hkv, hd)
    if use_rope:
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    mask = attn_mask(positions, positions, kind, cfg.window,
                     cfg.attn_chunk)[None]
    out = _sdpa(q, k, v, mask, 1.0 / np.sqrt(hd), cfg.attn_softcap)
    return dot(out.reshape(b, s, hq * hd), params["wo"], policy, "attn")


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, Hkv, D) — possibly in a reduced format
    v: jnp.ndarray
    length: jnp.ndarray   # (B,) int32 current fill


def init_kv_cache(batch: int, s_max: int, cfg: ArchConfig,
                  dtype) -> KVCache:
    shape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def gqa_decode(params, x: jnp.ndarray, cache: KVCache, cfg: ArchConfig,
               kind: str, use_rope: bool = True, policy=None,
               cache_fmt=None):
    """One-token decode. x: (B, 1, d). Returns (out, new_cache)."""
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache.length                                   # (B,)
    q = dot(x, params["wq"], policy, "attn").reshape(b, 1, hq, hd)
    k = dot(x, params["wk"], policy, "attn").reshape(b, 1, hkv, hd)
    v = dot(x, params["wv"], policy, "attn").reshape(b, 1, hkv, hd)
    if use_rope:
        cos, sin = rope_freqs(hd, cfg.rope_theta, pos[:, None])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if cache_fmt is not None:                            # KV-format knob
        from repro.precision import chop
        k = chop(k.astype(jnp.float32), cache_fmt).astype(k.dtype)
        v = chop(v.astype(jnp.float32), cache_fmt).astype(v.dtype)
    bidx = jnp.arange(b)
    new_k = cache.k.at[bidx, pos].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[bidx, pos].set(v[:, 0].astype(cache.v.dtype))
    s_max = cache.k.shape[1]
    k_pos = jnp.arange(s_max)[None, :].astype(jnp.int32)
    mask = attn_mask(pos[:, None, None], k_pos[:, None, :], kind,
                     cfg.window, cfg.attn_chunk)[:, 0]   # (B, 1, S_max)
    mask &= (k_pos <= pos[:, None])[:, None, :]
    out = _sdpa(q, new_k.astype(x.dtype), new_v.astype(x.dtype), mask,
                1.0 / np.sqrt(hd), cfg.attn_softcap)
    out = dot(out.reshape(b, 1, hq * hd), params["wo"], policy, "attn")
    return out, KVCache(new_k, new_v, cache.length + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    nd = cfg.head_dim                    # per-head nope dim
    rd = cfg.rope_head_dim
    vd = cfg.v_head_dim or cfg.head_dim
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": init_dense(ks[0], d, r, dtype),
        "w_kr": init_dense(ks[1], d, rd, dtype),
        "kv_norm": jnp.zeros((r,), dtype),
        "w_uk": (jax.random.normal(ks[2], (r, h, nd), dtype=jnp.float32)
                 / np.sqrt(r)).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (r, h, vd), dtype=jnp.float32)
                 / np.sqrt(r)).astype(dtype),
        "wo": init_dense(ks[4], h * vd, d, dtype),
    }
    if qr:
        p["w_dq"] = init_dense(ks[5], d, qr, dtype)
        p["q_norm"] = jnp.zeros((qr,), dtype)
        p["w_uq"] = (jax.random.normal(ks[6], (qr, h, nd + rd),
                                       dtype=jnp.float32)
                     / np.sqrt(qr)).astype(dtype)
    else:
        p["w_uq"] = (jax.random.normal(ks[6], (d, h, nd + rd),
                                       dtype=jnp.float32)
                     / np.sqrt(d)).astype(dtype)
    return p


def _mla_q(params, x, cfg, policy):
    if cfg.q_lora_rank:
        cq = dot(x, params["w_dq"], policy, "attn")
        cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhd->bshd", cq, params["w_uq"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["w_uq"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.split(q, [cfg.head_dim], axis=-1)        # nope, rope


def mla_forward(params, x: jnp.ndarray, cfg: ArchConfig,
                positions: jnp.ndarray, policy=None) -> jnp.ndarray:
    """Train/prefill MLA with full materialization."""
    b, s, _ = x.shape
    h = cfg.n_heads
    vd = cfg.v_head_dim or cfg.head_dim
    q_nope, q_rope = _mla_q(params, x, cfg, policy)
    ckv = dot(x, params["w_dkv"], policy, "attn")
    ckv = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = dot(x, params["w_kr"], policy, "attn")      # (B,S,rd) one head
    k_nope = jnp.einsum("bsr,rhd->bshd", ckv, params["w_uk"].astype(x.dtype),
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhv->bshv", ckv, params["w_uv"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    cos, sin = rope_freqs(cfg.rope_head_dim, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rd)
    scale = 1.0 / np.sqrt(cfg.head_dim + cfg.rope_head_dim)
    mask = attn_mask(positions, positions, "attn")[None]
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope[:, :, 0],
                           preferred_element_type=jnp.float32)) * scale
    probs = jax.nn.softmax(
        jnp.where(mask[:, None], scores, NEG_INF).astype(jnp.float32), -1)
    out = jnp.einsum("bhqk,bkhv->bqhv", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return dot(out.reshape(b, s, h * vd), params["wo"], policy, "attn")


class MLACache(NamedTuple):
    ckv: jnp.ndarray      # (B, S_max, kv_lora_rank)
    k_rope: jnp.ndarray   # (B, S_max, rope_head_dim)
    length: jnp.ndarray


def init_mla_cache(batch: int, s_max: int, cfg: ArchConfig,
                   dtype) -> MLACache:
    return MLACache(jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, s_max, cfg.rope_head_dim), dtype),
                    jnp.zeros((batch,), jnp.int32))


def mla_decode(params, x: jnp.ndarray, cache: MLACache, cfg: ArchConfig,
               policy=None):
    """Absorbed-matrix decode: scores/values in the latent space, so the
    per-token cache is kv_lora + rope_head_dim (~576) regardless of heads."""
    b = x.shape[0]
    h = cfg.n_heads
    vd = cfg.v_head_dim or cfg.head_dim
    pos = cache.length
    q_nope, q_rope = _mla_q(params, x, cfg, policy)      # (B,1,H,*)
    ckv_new = dot(x, params["w_dkv"], policy, "attn")
    ckv_new = rms_norm(ckv_new, params["kv_norm"], cfg.norm_eps)
    kr_new = dot(x, params["w_kr"], policy, "attn")
    cos, sin = rope_freqs(cfg.rope_head_dim, cfg.rope_theta, pos[:, None])
    q_rope = apply_rope(q_rope, cos, sin)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0]
    bidx = jnp.arange(b)
    ckv = cache.ckv.at[bidx, pos].set(ckv_new[:, 0].astype(cache.ckv.dtype))
    krope = cache.k_rope.at[bidx, pos].set(
        kr_new[:, 0].astype(cache.k_rope.dtype))
    # Absorb W_uk into the query: q_abs (B,1,H,r).
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope,
                       params["w_uk"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / np.sqrt(cfg.head_dim + cfg.rope_head_dim)
    s_max = ckv.shape[1]
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, ckv.astype(x.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,btd->bhst", q_rope, krope.astype(x.dtype),
                           preferred_element_type=jnp.float32)) * scale
    valid = (jnp.arange(s_max)[None] <= pos[:, None])[:, None, None]
    probs = jax.nn.softmax(
        jnp.where(valid, scores, NEG_INF).astype(jnp.float32), -1)
    o_lat = jnp.einsum("bhst,btr->bshr", probs.astype(x.dtype),
                       ckv.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshr,rhv->bshv", o_lat,
                     params["w_uv"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = dot(out.reshape(b, 1, h * vd), params["wo"], policy, "attn")
    return out, MLACache(ckv, krope, cache.length + 1)
