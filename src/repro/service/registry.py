"""Versioned policy snapshots with atomic promote / rollback.

Layout (one directory per registry):

    <root>/versions/v0001/{qtable.npz, policy.json, meta.json}
    <root>/CURRENT        — name of the promoted version (atomic os.replace)
    <root>/HISTORY        — one promoted version name per line, append-only

`publish` writes a snapshot (QTable + Discretizer + ActionSpace via
`PrecisionPolicy.save`) without making it live; `promote` flips the CURRENT
pointer atomically so a concurrently-restarting server can never observe a
half-written policy; `rollback` re-promotes the previously live version.
`warm_start` bootstraps version 1 from an offline `train_policy` run.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import List, Optional, Tuple

from repro.core.autotune import TrainConfig, train_policy
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig


def _count(name: str, help: str) -> None:
    """Fail-open lifecycle counter against the process-default metrics
    registry (a PolicyRegistry predates any server's obs bundle, and
    promote/rollback are exactly the events a canary dashboard needs)."""
    try:
        from repro.obs.metrics import default_registry
        default_registry().counter(name, help).inc()
    except Exception:
        pass


class PolicyRegistry:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "versions"), exist_ok=True)
        # Serializes CURRENT/HISTORY writes from one process; cross-process
        # publish races are handled by the atomic mkdir claim in publish().
        self._lock = threading.RLock()

    # -- paths -------------------------------------------------------------
    def _vdir(self, version: str) -> str:
        return os.path.join(self.root, "versions", version)

    @property
    def _current_path(self) -> str:
        return os.path.join(self.root, "CURRENT")

    @property
    def _history_path(self) -> str:
        return os.path.join(self.root, "HISTORY")

    # -- queries -----------------------------------------------------------
    def versions(self) -> List[str]:
        vdir = os.path.join(self.root, "versions")
        return sorted(v for v in os.listdir(vdir)
                      if os.path.isdir(os.path.join(vdir, v)))

    def current_version(self) -> Optional[str]:
        try:
            with open(self._current_path) as f:
                return f.read().strip() or None
        except FileNotFoundError:
            return None

    def history(self) -> List[str]:
        try:
            with open(self._history_path) as f:
                return [ln.strip() for ln in f if ln.strip()]
        except FileNotFoundError:
            return []

    def meta(self, version: str) -> dict:
        with open(os.path.join(self._vdir(version), "meta.json")) as f:
            return json.load(f)

    # -- writes ------------------------------------------------------------
    def publish(self, policy: PrecisionPolicy, note: str = "",
                extra_meta: Optional[dict] = None) -> str:
        """Write a new snapshot; returns its version name (not yet live)."""
        # Numeric max, not existing[-1]: lexicographic order breaks at
        # v10000 and would silently re-allocate (and overwrite) it forever.
        # The version directory is claimed with an atomic exclusive mkdir
        # so two publishers (threads or processes) can never allocate the
        # same name — the loser just re-reads and takes the next number.
        while True:
            existing = self.versions()
            n = 1 + max((int(v[1:]) for v in existing), default=0)
            version = f"v{n:04d}"
            vdir = self._vdir(version)
            try:
                os.makedirs(vdir)
            except FileExistsError:
                continue
            break
        policy.save(vdir)
        meta = {"version": version, "note": note, "created_at": time.time(),
                "n_states": policy.qtable.n_states,
                "n_actions": policy.qtable.n_actions,
                "visited_states": int((policy.qtable.N.sum(axis=1) > 0)
                                      .sum())}
        meta.update(extra_meta or {})
        with open(os.path.join(vdir, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        _count("repro_registry_publishes_total",
               "Policy snapshots published (not yet live).")
        return version

    def promote(self, version: str) -> None:
        """Atomically flip CURRENT to `version`."""
        with self._lock:
            if version not in self.versions():
                raise ValueError(f"unknown version {version!r}")
            fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".current-")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(version + "\n")
                os.replace(tmp, self._current_path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
            with open(self._history_path, "a") as f:
                f.write(version + "\n")
        _count("repro_registry_promotes_total",
               "CURRENT-pointer flips (snapshot promotions).")

    def annotate(self, version: str, key: str, value) -> dict:
        """Atomically merge ``{key: value}`` into a version's meta.json.

        The audit hook for post-publish evidence: the OPE gate writes
        its verdict (estimates, CIs, accept/reject) into the candidate
        version here, so the registry carries the numbers every
        candidate was admitted to — or refused — a canary slice on,
        alongside the telemetry evidence `snapshot()` embeds."""
        with self._lock:
            meta = self.meta(version)
            meta[str(key)] = value
            vdir = self._vdir(version)
            fd, tmp = tempfile.mkstemp(dir=vdir, prefix=".meta-")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(meta, f, indent=1)
                os.replace(tmp, os.path.join(vdir, "meta.json"))
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
        return meta

    def rollback(self) -> str:
        """Re-promote the version that was live before the current one.

        Walks back to before the current version's *first* promotion, so
        consecutive rollbacks step v3 -> v2 -> v1 instead of ping-ponging
        between the last two entries (a rollback itself appends to HISTORY).
        """
        with self._lock:
            hist = self.history()
            cur = self.current_version()
            if cur is None or cur not in hist:
                raise RuntimeError("no earlier version to roll back to")
            prior = [v for v in hist[:hist.index(cur)] if v != cur]
            if not prior:
                raise RuntimeError("no earlier version to roll back to")
            self.promote(prior[-1])
        _count("repro_registry_rollbacks_total",
               "Rollbacks to an earlier promoted version.")
        return prior[-1]

    # -- loading -----------------------------------------------------------
    def load(self, version: Optional[str] = None) -> PrecisionPolicy:
        version = version or self.current_version()
        if version is None:
            raise RuntimeError("registry has no promoted version")
        return PrecisionPolicy.load(self._vdir(version))

    # -- bootstrap ---------------------------------------------------------
    @classmethod
    def warm_start(cls, root: str, task,
                   reward_cfg: RewardConfig,
                   train_cfg: TrainConfig = TrainConfig()
                   ) -> Tuple["PolicyRegistry", str, PrecisionPolicy]:
        """Offline `train_policy` run -> published + promoted version 1.

        `task` is any `TunableTask` (or engine / legacy `GMRESIREnv`)."""
        reg = cls(root)
        policy, hist = train_policy(task, reward_cfg, train_cfg)
        version = reg.publish(
            policy, note="warm start (offline train_policy)",
            extra_meta={"episodes": train_cfg.episodes,
                        "final_reward": (hist.episode_reward[-1]
                                         if hist.episode_reward else None)})
        reg.promote(version)
        return reg, version, policy
