"""Versioned policy snapshots with atomic promote / rollback.

Layout (one directory per registry):

    <root>/versions/v0001/{qtable.npz, policy.json, meta.json}
    <root>/CURRENT        — name of the promoted version (atomic os.replace)
    <root>/HISTORY        — one promoted version name per line, append-only

`publish` writes a snapshot (QTable + Discretizer + ActionSpace via
`PrecisionPolicy.save`) without making it live; `promote` flips the CURRENT
pointer atomically so a concurrently-restarting server can never observe a
half-written policy; `rollback` re-promotes the previously live version.
`warm_start` bootstraps version 1 from an offline `train_policy` run.

Durability contract (DESIGN.md §11.1): every snapshot file is fsync'd,
`meta.json` is written *last* through an atomic tmp+rename (so a version
directory without a valid meta is an incomplete publish, never a
half-written one), and meta carries sha256 checksums of the data files.
`load` verifies checksums and raises `SnapshotCorrupted` on damage;
`load_last_good` walks CURRENT → HISTORY (newest first) past corrupt or
incomplete versions, so recovery after a crash-during-publish or disk
corruption always lands on the newest verifiable snapshot.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import time
from typing import List, Optional, Tuple

from repro import faults
from repro.core.autotune import TrainConfig, train_policy
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig


class SnapshotCorrupted(RuntimeError):
    """A version's files are missing, unreadable, or fail checksum."""

    def __init__(self, version: str, reason: str):
        super().__init__(f"snapshot {version}: {reason}")
        self.version = version
        self.reason = reason


#: Snapshot data files covered by the meta.json checksum manifest.
_DATA_FILES = ("qtable.npz", "policy.json")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 16), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_dir(path: str) -> None:
    """Fsync a directory so a rename inside it is durable. Swallowed on
    platforms/filesystems that refuse directory fds — the rename is
    still atomic, only crash-durability of the *name* is best-effort."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_atomic(path: str, text: str) -> None:
    """Durable atomic file write: tmp in the target dir, flush+fsync,
    rename over, fsync the dir."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix="." + os.path.basename(path)
                               + "-")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _fsync_dir(d)


def _count(name: str, help: str) -> None:
    """Fail-open lifecycle counter against the process-default metrics
    registry (a PolicyRegistry predates any server's obs bundle, and
    promote/rollback are exactly the events a canary dashboard needs)."""
    try:
        from repro.obs.metrics import default_registry
        default_registry().counter(name, help).inc()
    except Exception:
        pass


class PolicyRegistry:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "versions"), exist_ok=True)
        # Serializes CURRENT/HISTORY writes from one process; cross-process
        # publish races are handled by the atomic mkdir claim in publish().
        self._lock = threading.RLock()

    # -- paths -------------------------------------------------------------
    def _vdir(self, version: str) -> str:
        return os.path.join(self.root, "versions", version)

    @property
    def _current_path(self) -> str:
        return os.path.join(self.root, "CURRENT")

    @property
    def _history_path(self) -> str:
        return os.path.join(self.root, "HISTORY")

    # -- queries -----------------------------------------------------------
    def versions(self) -> List[str]:
        vdir = os.path.join(self.root, "versions")
        return sorted(v for v in os.listdir(vdir)
                      if os.path.isdir(os.path.join(vdir, v)))

    def current_version(self) -> Optional[str]:
        try:
            with open(self._current_path) as f:
                return f.read().strip() or None
        except FileNotFoundError:
            return None

    def history(self) -> List[str]:
        try:
            with open(self._history_path) as f:
                return [ln.strip() for ln in f if ln.strip()]
        except FileNotFoundError:
            return []

    def meta(self, version: str) -> dict:
        with open(os.path.join(self._vdir(version), "meta.json")) as f:
            return json.load(f)

    # -- integrity ---------------------------------------------------------
    def verify(self, version: str) -> dict:
        """Checksum-verify a version; returns its meta. Raises
        `SnapshotCorrupted` when meta is missing/unreadable (an
        incomplete publish — meta is written last) or a data file is
        missing or fails its sha256. Pre-checksum snapshots (no
        ``checksums`` key) pass on file existence alone."""
        try:
            meta = self.meta(version)
        except (FileNotFoundError, json.JSONDecodeError) as e:
            raise SnapshotCorrupted(version,
                                    f"meta.json unreadable ({e})") from e
        sums = meta.get("checksums")
        vdir = self._vdir(version)
        for fname in _DATA_FILES:
            path = os.path.join(vdir, fname)
            if not os.path.exists(path):
                raise SnapshotCorrupted(version, f"{fname} missing")
            if sums and fname in sums and _sha256(path) != sums[fname]:
                raise SnapshotCorrupted(version,
                                        f"{fname} fails sha256 checksum")
        return meta

    # -- writes ------------------------------------------------------------
    def publish(self, policy: PrecisionPolicy, note: str = "",
                extra_meta: Optional[dict] = None) -> str:
        """Write a new snapshot; returns its version name (not yet live)."""
        # Numeric max, not existing[-1]: lexicographic order breaks at
        # v10000 and would silently re-allocate (and overwrite) it forever.
        # The version directory is claimed with an atomic exclusive mkdir
        # so two publishers (threads or processes) can never allocate the
        # same name — the loser just re-reads and takes the next number.
        while True:
            existing = self.versions()
            n = 1 + max((int(v[1:]) for v in existing), default=0)
            version = f"v{n:04d}"
            vdir = self._vdir(version)
            try:
                os.makedirs(vdir)
            except FileExistsError:
                continue
            break
        faults.maybe_raise("registry.io", op="publish", version=version)
        policy.save(vdir)
        # Durability order (DESIGN.md §11.1): data files synced first,
        # then meta.json — carrying their checksums — lands atomically
        # as the commit record. A crash anywhere before the meta rename
        # leaves a version that verify()/load_last_good() skip.
        checksums = {}
        for fname in _DATA_FILES:
            fpath = os.path.join(vdir, fname)
            _fsync_file(fpath)
            checksums[fname] = _sha256(fpath)
        meta = {"version": version, "note": note, "created_at": time.time(),
                "n_states": policy.qtable.n_states,
                "n_actions": policy.qtable.n_actions,
                "visited_states": int((policy.qtable.N.sum(axis=1) > 0)
                                      .sum()),
                "checksums": checksums}
        meta.update(extra_meta or {})
        _write_atomic(os.path.join(vdir, "meta.json"),
                      json.dumps(meta, indent=1))
        _count("repro_registry_publishes_total",
               "Policy snapshots published (not yet live).")
        return version

    def promote(self, version: str) -> None:
        """Atomically flip CURRENT to `version`."""
        with self._lock:
            if version not in self.versions():
                raise ValueError(f"unknown version {version!r}")
            faults.maybe_raise("registry.io", op="promote", version=version)
            _write_atomic(self._current_path, version + "\n")
            with open(self._history_path, "a") as f:
                f.write(version + "\n")
                f.flush()
                try:
                    os.fsync(f.fileno())
                except OSError:
                    pass
        _count("repro_registry_promotes_total",
               "CURRENT-pointer flips (snapshot promotions).")

    def annotate(self, version: str, key: str, value) -> dict:
        """Atomically merge ``{key: value}`` into a version's meta.json.

        The audit hook for post-publish evidence: the OPE gate writes
        its verdict (estimates, CIs, accept/reject) into the candidate
        version here, so the registry carries the numbers every
        candidate was admitted to — or refused — a canary slice on,
        alongside the telemetry evidence `snapshot()` embeds."""
        with self._lock:
            meta = self.meta(version)
            meta[str(key)] = value
            _write_atomic(os.path.join(self._vdir(version), "meta.json"),
                          json.dumps(meta, indent=1))
        return meta

    def rollback(self) -> str:
        """Re-promote the version that was live before the current one.

        Walks back to before the current version's *first* promotion, so
        consecutive rollbacks step v3 -> v2 -> v1 instead of ping-ponging
        between the last two entries (a rollback itself appends to HISTORY).
        """
        with self._lock:
            hist = self.history()
            cur = self.current_version()
            if cur is None or cur not in hist:
                raise RuntimeError("no earlier version to roll back to")
            prior = [v for v in hist[:hist.index(cur)] if v != cur]
            if not prior:
                raise RuntimeError("no earlier version to roll back to")
            self.promote(prior[-1])
        _count("repro_registry_rollbacks_total",
               "Rollbacks to an earlier promoted version.")
        return prior[-1]

    # -- loading -----------------------------------------------------------
    def load(self, version: Optional[str] = None,
             verify: bool = True) -> PrecisionPolicy:
        version = version or self.current_version()
        if version is None:
            raise RuntimeError("registry has no promoted version")
        faults.maybe_raise("registry.io", op="load", version=version)
        if verify:
            self.verify(version)
        try:
            return PrecisionPolicy.load(self._vdir(version))
        except Exception as e:
            # Structurally unreadable despite passing (or skipping) the
            # checksum gate — e.g. a pre-checksum snapshot with a
            # truncated npz. Normalize so fallback logic has one type.
            raise SnapshotCorrupted(version, f"unreadable ({e})") from e

    def load_last_good(self) -> Tuple[PrecisionPolicy, str, List[str]]:
        """Newest loadable snapshot: CURRENT first, then promoted
        history newest-first, then any published-but-never-promoted
        versions newest-first. Returns (policy, version,
        corrupt_versions_skipped); raises RuntimeError only when no
        snapshot in the registry is loadable at all.

        The crash-recovery entry point (service.recovery): a torn
        publish or corrupted CURRENT target must fall back, not take
        the server down."""
        candidates: List[str] = []
        cur = self.current_version()
        if cur is not None:
            candidates.append(cur)
        candidates.extend(reversed(self.history()))
        candidates.extend(reversed(self.versions()))
        seen, ordered = set(), []
        for v in candidates:
            if v not in seen:
                seen.add(v)
                ordered.append(v)
        skipped: List[str] = []
        for v in ordered:
            try:
                policy = self.load(v)
            except SnapshotCorrupted:
                skipped.append(v)
                continue
            except FileNotFoundError:
                skipped.append(v)
                continue
            return policy, v, skipped
        raise RuntimeError(
            f"no loadable snapshot in registry {self.root!r} "
            f"(skipped corrupt: {skipped})")

    # -- bootstrap ---------------------------------------------------------
    @classmethod
    def warm_start(cls, root: str, task,
                   reward_cfg: RewardConfig,
                   train_cfg: TrainConfig = TrainConfig()
                   ) -> Tuple["PolicyRegistry", str, PrecisionPolicy]:
        """Offline `train_policy` run -> published + promoted version 1.

        `task` is any `TunableTask` (or engine / legacy `GMRESIREnv`)."""
        reg = cls(root)
        policy, hist = train_policy(task, reward_cfg, train_cfg)
        version = reg.publish(
            policy, note="warm start (offline train_policy)",
            extra_meta={"episodes": train_cfg.episodes,
                        "final_reward": (hist.episode_reward[-1]
                                         if hist.episode_reward else None)})
        reg.promote(version)
        return reg, version, policy
