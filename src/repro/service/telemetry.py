"""Service telemetry: throughput / latency / precision-usage / reward.

Plain in-process counters — cheap enough to update on every request — with a
`snapshot()` that renders the whole state as one JSON-ready dict. Latency
percentiles are computed over a bounded reservoir of the most recent
samples so a long-running server never grows without bound.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, Optional

import numpy as np


class Ewma:
    """Exponentially-weighted moving average with bias-corrected warmup."""

    def __init__(self, coeff: float):
        self.coeff = float(coeff)
        self._acc = 0.0
        self._norm = 0.0

    def update(self, x: float) -> float:
        self._acc = (1.0 - self.coeff) * self._acc + self.coeff * float(x)
        self._norm = (1.0 - self.coeff) * self._norm + self.coeff
        return self.value

    @property
    def value(self) -> float:
        return self._acc / self._norm if self._norm > 0 else 0.0


class Telemetry:
    def __init__(self, max_latency_samples: int = 4096,
                 reward_coeff: float = 0.02,
                 max_bucket_latency_samples: int = 1024):
        self.requests = 0
        self.responses = 0
        self.solver_batches = 0
        self.solver_rows = 0          # rows actually solved (incl. padding)
        self.padded_rows = 0          # wasted rows from fixed-shape padding
        self.drift_events = 0
        self.updates = 0
        self.batches_per_bucket: Dict[int, int] = {}
        self.requests_per_bucket: Dict[int, int] = {}
        self.usage: Dict[str, int] = {}           # per-step format counts
        self.action_counts: Dict[int, int] = {}
        # Outcome-status histogram (core.task codes: 0=CONVERGED,
        # 1=STAGNATED, 2=MAXITER, 3=FAILED). `converged_frac` is the
        # ferr/nbe pass-rate gate of the canary rollout controller —
        # CONVERGED means the solver met its ferr/nbe tolerance.
        self.status_counts: Dict[int, int] = {}
        self.reward_ewma = Ewma(reward_coeff)
        self.reward_sum = 0.0
        self.abs_rpe_ewma = Ewma(reward_coeff)
        self._latencies = deque(maxlen=max_latency_samples)
        # Per-bucket reservoirs: per-bucket p99 is the promotion gate the
        # canary workstream needs, and one global reservoir cannot
        # recover it (small buckets drown in big-bucket samples).
        self._bucket_latency_cap = max_bucket_latency_samples
        self._latencies_per_bucket: Dict[int, deque] = {}
        # (first_submit_t, last_response_t): the wall-clock window is
        # anchored at the FIRST SUBMIT, not the first response —
        # anchoring at the first response made single-response and
        # warmup-heavy runs report 0 or inflated rates.
        self._wall: Optional[tuple] = None

    # -- recording ---------------------------------------------------------
    def on_submit(self, bucket: int, now: Optional[float] = None) -> None:
        self.requests += 1
        self.requests_per_bucket[bucket] = \
            self.requests_per_bucket.get(bucket, 0) + 1
        if now is not None and self._wall is None:
            self._wall = (now, now)

    def on_batch(self, bucket: int, n_live: int, n_rows: int) -> None:
        self.solver_batches += 1
        self.solver_rows += n_rows
        self.padded_rows += n_rows - n_live
        self.batches_per_bucket[bucket] = \
            self.batches_per_bucket.get(bucket, 0) + 1

    def on_response(self, latency_s: float, action_names, action: int,
                    reward: float, now: float,
                    bucket: Optional[int] = None,
                    status: Optional[int] = None) -> None:
        self.responses += 1
        if status is not None:
            self.status_counts[int(status)] = \
                self.status_counts.get(int(status), 0) + 1
        self._latencies.append(float(latency_s))
        if bucket is not None:
            res = self._latencies_per_bucket.get(bucket)
            if res is None:
                res = self._latencies_per_bucket[bucket] = deque(
                    maxlen=self._bucket_latency_cap)
            res.append(float(latency_s))
        for name in action_names:
            self.usage[name] = self.usage.get(name, 0) + 1
        self.action_counts[int(action)] = \
            self.action_counts.get(int(action), 0) + 1
        # A NaN reward would poison both aggregates permanently (NaN is
        # absorbing under += and EWMA); injected-NaN outcomes still count
        # as responses above, they just don't move the reward telemetry.
        if math.isfinite(float(reward)):
            self.reward_ewma.update(reward)
            self.reward_sum += float(reward)
        if self._wall is None:
            self._wall = (now, now)
        else:
            self._wall = (self._wall[0], now)

    def on_update(self, abs_rpe: float, drift: bool) -> None:
        self.updates += 1
        self.abs_rpe_ewma.update(abs_rpe)
        if drift:
            self.drift_events += 1

    # -- reporting ---------------------------------------------------------
    def latency_percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        if not self._latencies:
            return {f"p{q}": 0.0 for q in qs}
        arr = np.asarray(self._latencies)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def latency_percentiles_per_bucket(self, qs=(50, 99)
                                       ) -> Dict[int, Dict[str, float]]:
        """Per-bucket percentiles over the bounded per-bucket reservoirs
        (the canary promotion gate reads p99 from here)."""
        out: Dict[int, Dict[str, float]] = {}
        for bucket, res in sorted(self._latencies_per_bucket.items()):
            arr = np.asarray(res)
            out[bucket] = {f"p{q}": float(np.percentile(arr, q))
                           for q in qs}
        return out

    @property
    def converged_frac(self) -> float:
        """Fraction of responses whose solve met its ferr/nbe tolerance
        (status CONVERGED) — the rollout controller's pass-rate gate."""
        if not self.responses:
            return 0.0
        return self.status_counts.get(0, 0) / self.responses

    @property
    def throughput_rps(self) -> float:
        """Responses per second over [first submit, last response].

        The window opens at the first *submit* (when `on_submit` is
        given a timestamp): a run that submits, waits, and receives one
        response reports 1/window — the first-response anchor used to
        make that 0, and made warmup-heavy runs look inflated because
        all queue time before the first response was dropped."""
        if self._wall is None or self._wall[1] <= self._wall[0]:
            return 0.0
        return self.responses / (self._wall[1] - self._wall[0])

    def snapshot(self) -> dict:
        total = max(self.responses, 1)
        return {
            "requests": self.requests,
            "responses": self.responses,
            "updates": self.updates,
            "drift_events": self.drift_events,
            "solver_batches": self.solver_batches,
            "solver_rows": self.solver_rows,
            "padded_rows": self.padded_rows,
            # Real work vs fixed-shape padding waste, split out explicitly
            # (mirrors AutotuneEngine.n_solves / n_pad_solves offline).
            "n_solves": self.solver_rows - self.padded_rows,
            "n_pad_solves": self.padded_rows,
            "pad_waste_frac": self.padded_rows / max(self.solver_rows, 1),
            "status_counts": {str(k): v
                              for k, v in sorted(self.status_counts
                                                 .items())},
            "converged_frac": self.converged_frac,
            "batches_per_bucket": dict(self.batches_per_bucket),
            "requests_per_bucket": dict(self.requests_per_bucket),
            "usage_per_solve": {k: v / total
                                for k, v in sorted(self.usage.items())},
            "reward_ewma": self.reward_ewma.value,
            "reward_mean": self.reward_sum / total,
            "abs_rpe_ewma": self.abs_rpe_ewma.value,
            "latency_s": self.latency_percentiles(),
            "latency_s_per_bucket": self.latency_percentiles_per_bucket(),
            "throughput_rps": self.throughput_rps,
        }
