"""Fail-open bridge from serving-loop events to the obs layer.

`ServiceInstruments` owns every metric family, trace span, and
trajectory-log record the `AutotuneServer` emits; `LearnerInstruments`
does the same for the `OnlineLearner` (epsilon gauge, drift counter).
`Telemetry` remains the in-process *computation* layer — the gauges
here re-export its EWMAs rather than recomputing them (ROADMAP: "expose
it, don't reinvent it").

Every public method is wrapped in `obs.metrics.fail_open`: an exception
anywhere inside — a raising exporter sink, a monkeypatched tracer, a
full disk under the trajectory log — is swallowed, counted in
``repro_obs_errors_total``, and never reaches `submit()`/`step()`
(DESIGN.md §8.1; the property is pinned by tests/test_obs.py).

Metric name conventions (linted live in CI): ``repro_`` prefix,
snake_case, counters ``_total``, time histograms ``_seconds``. Labels:
``task`` (TunableTask name), ``bucket`` (padded size bucket),
``executor`` (SolveExecutor name), ``action`` (action-space index),
``mode`` (``explore``/``greedy``).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.obs import Observability
from repro.obs.metrics import RATIO_BUCKETS, fail_open


class ServiceInstruments:
    """Per-server instrumentation facade (request path)."""

    def __init__(self, obs: Observability, task_name: str,
                 executor_name: str):
        self.obs = obs
        self.registry = obs.registry          # fail_open counts here
        self.task = str(task_name)
        self.executor = str(executor_name)
        r = obs.registry
        self.requests = r.counter(
            "repro_service_requests_total",
            "Solve requests accepted by submit().", ("task", "bucket"))
        self.responses = r.counter(
            "repro_service_responses_total",
            "Completed responses (solve + reward + Q-update).",
            ("task", "bucket"))
        self.pending = r.gauge(
            "repro_service_pending_requests",
            "Requests queued in the micro-batcher.", ("task",))
        self.batches = r.counter(
            "repro_service_solver_batches_total",
            "Fixed-shape micro-batches flushed.",
            ("task", "bucket", "executor"))
        self.rows = r.counter(
            "repro_service_solver_rows_total",
            "Rows solved, including fixed-shape padding.",
            ("task", "bucket"))
        self.pad_rows = r.counter(
            "repro_service_padded_rows_total",
            "Wasted padding rows from fixed-shape flushes.",
            ("task", "bucket"))
        self.pad_waste = r.histogram(
            "repro_service_flush_pad_waste_ratio",
            "Per-flush fraction of rows that were padding.",
            ("task", "bucket"), buckets=RATIO_BUCKETS)
        self.latency = r.histogram(
            "repro_service_request_latency_seconds",
            "Submit-to-response latency.", ("task", "bucket"))
        self.queue_wait = r.histogram(
            "repro_service_queue_wait_seconds",
            "Enqueue-to-solve-start wait in the micro-batcher.",
            ("task", "bucket"))
        self.solve_seconds = r.histogram(
            "repro_service_solve_batch_seconds",
            "Wall time of one micro-batch solve_rows call.",
            ("task", "bucket", "executor"))
        self.reward_ewma = r.gauge(
            "repro_service_reward_ewma",
            "Telemetry reward EWMA (exposed, not recomputed).", ("task",))
        self.abs_rpe_ewma = r.gauge(
            "repro_service_abs_rpe_ewma",
            "Telemetry |reward-prediction-error| EWMA.", ("task",))
        self.actions = r.counter(
            "repro_service_actions_total",
            "Actions selected, by action index and selection mode.",
            ("task", "action", "mode"))
        self.policy_info = r.gauge(
            "repro_service_policy_info",
            "Constant 1 for the live policy version (info pattern).",
            ("task", "version"))
        self.snapshots = r.counter(
            "repro_service_snapshots_total",
            "Live-policy snapshots published from this server.", ("task",))
        self.evicted = r.counter(
            "repro_server_responses_evicted_total",
            "Unclaimed SolveResponses evicted from the bounded LRU "
            "retention (consumers that never poll()).", ("task",))
        # Fault-tolerance surface (DESIGN.md §11).
        self.breaker_state = r.gauge(
            "repro_breaker_state",
            "Per-bucket circuit-breaker state "
            "(0=closed, 0.5=half_open, 1=open).", ("task", "bucket"))
        self.breaker_transitions = r.counter(
            "repro_breaker_transitions_total",
            "Circuit-breaker state changes, by edge.",
            ("task", "bucket", "from", "to"))
        self.quarantined = r.counter(
            "repro_quarantined_updates_total",
            "Rewards observed but NOT applied to the Q-table (breaker "
            "open, pinned traffic, or non-finite reward).",
            ("task", "bucket"))
        self.expired = r.counter(
            "repro_expired_requests_total",
            "Requests answered with a terminal FAILED response because "
            "their batcher deadline expired before a solve ran.",
            ("task", "bucket"))

    # -- request path ------------------------------------------------------
    @fail_open
    def on_submit(self, bucket: int, action: int, explore: bool,
                  pending: int) -> None:
        self.requests.labels(task=self.task, bucket=bucket).inc()
        self.actions.labels(task=self.task, action=action,
                            mode="explore" if explore else "greedy").inc()
        self.pending.labels(task=self.task).set(pending)

    @fail_open
    def on_flush(self, flush, pending: int) -> None:
        n_live = len(flush.req_ids)
        lab = dict(task=self.task, bucket=flush.bucket)
        self.batches.labels(executor=self.executor, **lab).inc()
        self.rows.labels(**lab).inc(flush.n_rows)
        self.pad_rows.labels(**lab).inc(flush.n_rows - n_live)
        self.pad_waste.labels(**lab).observe(
            (flush.n_rows - n_live) / max(flush.n_rows, 1))
        self.solve_seconds.labels(executor=self.executor, **lab).observe(
            flush.solve_s)
        self.pending.labels(task=self.task).set(pending)

    @fail_open
    def on_complete(self, resp, info, flush, telemetry,
                    t_reward: float, t_update: float) -> None:
        """One finished request: metrics + trace spans + trajectory."""
        lab = dict(task=self.task, bucket=resp.bucket)
        self.responses.labels(**lab).inc()
        self.latency.labels(**lab).observe(resp.latency_s)
        self.reward_ewma.labels(task=self.task).set(
            telemetry.reward_ewma.value)
        self.abs_rpe_ewma.labels(task=self.task).set(
            telemetry.abs_rpe_ewma.value)
        self.policy_info.labels(task=self.task,
                                version=resp.policy_version).set(1)
        rid = resp.request_id
        t_sub, t_done = info.submitted_at, info.submitted_at + resp.latency_s
        tracer = self.obs.tracer
        tracer.add_span("request", info.t_accept, t_done, tid=rid,
                        bucket=resp.bucket, action=resp.action,
                        reward=resp.reward)
        tracer.add_span("submit", info.t_accept, t_sub, tid=rid)
        if flush is not None:
            self.queue_wait.labels(**lab).observe(
                max(flush.t_solve_start - t_sub, 0.0))
            tracer.add_span("queue_wait", t_sub, flush.t_solve_start,
                            tid=rid)
            tracer.add_span("solve", flush.t_solve_start,
                            flush.t_solve_end, tid=rid,
                            bucket=resp.bucket, n_rows=flush.n_rows)
            tracer.add_span("reward", flush.t_solve_end, t_reward,
                            tid=rid)
        tracer.add_span("q_update", t_reward, t_update, tid=rid,
                        state=resp.state, drift=resp.drift)
        if self.obs.trajlog is not None:
            rec = resp.record
            self.obs.trajlog.append({
                "ts": time.time(),
                "request_id": rid,
                "task": self.task,
                "bucket": int(resp.bucket),
                "features": [float(x) for x in info.features],
                "state": int(resp.state),
                "action": int(resp.action),
                "action_names": list(resp.action_names),
                "eps": float(resp.eps),
                "explore": bool(info.explore),
                "reward": float(resp.reward),
                "outcome": {"status": int(rec.status),
                            "cost": float(rec.cost),
                            **{k: v for k, v in rec.metrics.items()}},
                "latency_s": float(resp.latency_s),
                "policy_version": resp.policy_version,
                "drift": bool(resp.drift),
                # WAL keys (service.recovery): `seq` orders records
                # against snapshot watermarks; `quarantined` records are
                # skipped on replay — they never trained the live table.
                "seq": int(resp.seq),
                "quarantined": bool(resp.quarantined),
            })

    # -- fault tolerance ---------------------------------------------------
    @fail_open
    def on_breaker_transition(self, bucket: int, old: str,
                              new: str) -> None:
        from repro.service.breaker import STATE_VALUES
        self.breaker_state.labels(task=self.task, bucket=bucket).set(
            STATE_VALUES.get(new, 1.0))
        self.breaker_transitions.labels(
            task=self.task, bucket=bucket,
            **{"from": old, "to": new}).inc()

    @fail_open
    def on_quarantine(self, bucket: int) -> None:
        self.quarantined.labels(task=self.task, bucket=bucket).inc()

    @fail_open
    def on_expired(self, bucket: int) -> None:
        self.expired.labels(task=self.task, bucket=bucket).inc()

    @fail_open
    def on_snapshot(self, version: str) -> None:
        self.snapshots.labels(task=self.task).inc()
        self.policy_info.labels(task=self.task, version=version).set(1)

    @fail_open
    def on_evict(self, n: int = 1) -> None:
        self.evicted.labels(task=self.task).inc(n)


class RolloutInstruments:
    """Canary rollout-controller instrumentation (service.rollout).

    Label vocabulary extends the service set with ``outcome``
    (``hold``/``promote``/``rollback``) and ``arm``
    (``primary``/``candidate``/``shadow``)."""

    def __init__(self, obs: Observability, task_name: str):
        self.obs = obs
        self.registry = obs.registry
        self.task = str(task_name)
        r = obs.registry
        self.decisions = r.counter(
            "repro_rollout_decisions_total",
            "Canary gate decisions, by outcome.", ("task", "outcome"))
        self.routed = r.counter(
            "repro_rollout_requests_total",
            "Requests routed by the shadow server, by arm.",
            ("task", "arm"))
        self.active = r.gauge(
            "repro_rollout_active",
            "1 while a canary rollout is in flight.", ("task",))
        self.windows = r.gauge(
            "repro_rollout_windows_passed",
            "Consecutive decision windows the candidate has passed.",
            ("task",))
        self.candidate_responses = r.gauge(
            "repro_rollout_candidate_responses",
            "Candidate-arm responses accumulated this rollout.", ("task",))

    @fail_open
    def on_route(self, arm: str) -> None:
        self.routed.labels(task=self.task, arm=arm).inc()

    @fail_open
    def on_state(self, active: bool, windows_passed: int,
                 candidate_responses: int) -> None:
        self.active.labels(task=self.task).set(1 if active else 0)
        self.windows.labels(task=self.task).set(windows_passed)
        self.candidate_responses.labels(task=self.task).set(
            candidate_responses)

    @fail_open
    def on_decision(self, outcome: str) -> None:
        self.decisions.labels(task=self.task, outcome=outcome).inc()


class LearnerInstruments:
    """Epsilon/drift instrumentation for the continual learner."""

    def __init__(self, obs: Observability):
        self.obs = obs
        self.registry = obs.registry
        r = obs.registry
        self.epsilon = r.gauge(
            "repro_online_epsilon",
            "Exploration rate currently in force.")
        self.updates = r.counter(
            "repro_online_updates_total", "Online Q-updates applied.")
        self.drifts = r.counter(
            "repro_online_drift_events_total",
            "Drift-detector triggers (epsilon re-boosts).")

    @fail_open
    def on_update(self, upd) -> None:
        self.epsilon.set(upd.eps)
        self.updates.inc()
        if upd.drift:
            self.drifts.inc()
