"""Crash recovery: rebuild a server's learner state from the registry
plus the trajectory-log tail (DESIGN.md §11.1).

The trajectory log is the learner's write-ahead record: every completed
request appends one JSONL line carrying the WAL keys ``seq`` (the
server's completion sequence number) and ``quarantined`` (True when the
reward did NOT train the Q-table — breaker open, pinned traffic,
non-finite reward, or deadline expiry). Every registry snapshot embeds
the watermark ``meta["wal"]["seq"]`` it covers, plus the epsilon
controller's anneal state at that point.

Recovery is therefore::

    policy, version <- registry.load_last_good()   # skip corrupt snaps
    heal CURRENT if it pointed at a corrupt/torn version
    server <- AutotuneServer(registry, ...)        # loads the snapshot
    restore epsilon from meta["wal"]
    for rec in log where rec.seq > wal.seq and not rec.quarantined:
        server.learner.update(rec.state, rec.action, rec.reward,
                              explore=rec.explore)

The replayed tail goes through the *same* Q-update path the live
server used (`OnlineLearner.update` -> `QTable.update`), with the same
fixed alpha, in the same order, on the same float64 values (JSON
round-trips finite doubles exactly) — so the recovered Q/N tables are
bit-identical to what a server that never crashed would hold, which is
exactly what tests/test_recovery.py's kill-and-recover e2e asserts.
Quarantined records are skipped because they never touched the live
table either; the epsilon controller steps only on applied updates, so
its trajectory matches too.

Optionally the tail is first *verified* through `eval.replay` — the
logged outcomes re-solved and diffed bit-identically — turning
recovery into a checked restore rather than a trusting one (callers
supply the ``request_id -> instance`` mapping replay needs).

Durability contract (what can be lost): with ``trajectory_sync="none"``
a host crash may lose the page-cache tail of the log — recovery then
restores the newest durable prefix, which is still a valid (slightly
older) learner state. ``"always"`` closes that window at the fsync
price quantified in benchmarks/service_bench.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from repro.obs.trajlog import TrajectoryLog
from repro.service.registry import PolicyRegistry
from repro.service.server import AutotuneServer


@dataclasses.dataclass
class RecoveryReport:
    """What one `recover_server` call did (also mirrored, JSON-ready,
    into ``server.last_recovery`` for /healthz)."""
    version: Optional[str]        # snapshot the server restarted from
    healed_current: bool          # CURRENT re-pointed off a corrupt snap
    corrupt_versions: List[str]   # snapshots skipped as corrupt/torn
    snapshot_seq: int             # WAL watermark the snapshot covered
    log_records: int              # records seen in the trajectory log
    replayed: int                 # Q-updates re-applied from the tail
    skipped_stale: int            # seq <= snapshot watermark
    skipped_quarantined: int      # never trained the live table
    skipped_unsequenced: int      # pre-WAL records (no seq key)
    final_seq: int                # server.update_seq after recovery

    def as_meta(self) -> dict:
        return dataclasses.asdict(self)


def _count_recovery(server: AutotuneServer, outcome: str) -> None:
    """Fail-open repro_recovery_total{outcome} on the server's metrics
    registry (falling back to the process default when obs is off)."""
    try:
        if server is not None and server.obs is not None:
            reg = server.obs.registry
        else:
            from repro.obs.metrics import default_registry
            reg = default_registry()
        reg.counter("repro_recovery_total",
                    "Crash-recovery attempts, by outcome.",
                    ("outcome",)).labels(outcome=outcome).inc()
    except Exception:
        pass


def replay_wal_tail(server: AutotuneServer, trajlog_path: str,
                    snapshot_seq: int,
                    task: Optional[str] = None) -> RecoveryReport:
    """Replay trajectory-log records with ``seq > snapshot_seq`` through
    the server's live learner; returns the (not yet version-stamped)
    tally. Exposed separately so tests can drive replay against a
    hand-built server."""
    replayed = stale = quarantined = unsequenced = 0
    n = 0
    max_seq = int(snapshot_seq)
    task = task if task is not None else getattr(server.task, "name", None)
    for rec in TrajectoryLog.read(trajlog_path, task=task):
        n += 1
        seq = rec.get("seq")
        if seq is None:
            # Pre-WAL record: no way to order it against the snapshot
            # watermark, so it cannot be safely re-applied.
            unsequenced += 1
            continue
        seq = int(seq)
        max_seq = max(max_seq, seq)
        if seq <= snapshot_seq:
            stale += 1
            continue
        if rec.get("quarantined", False):
            quarantined += 1
            continue
        r = float(rec["reward"])
        if not math.isfinite(r):        # belt over the quarantine flag
            quarantined += 1
            continue
        server.learner.update(int(rec["state"]), int(rec["action"]), r,
                              explore=bool(rec.get("explore", False)))
        replayed += 1
    server.update_seq = max(server.update_seq, max_seq)
    return RecoveryReport(
        version=None, healed_current=False, corrupt_versions=[],
        snapshot_seq=int(snapshot_seq), log_records=n, replayed=replayed,
        skipped_stale=stale, skipped_quarantined=quarantined,
        skipped_unsequenced=unsequenced, final_seq=server.update_seq)


def recover_server(registry: PolicyRegistry, trajlog_path: str,
                   verify_with=None, **server_kwargs) -> AutotuneServer:
    """Restart an `AutotuneServer` from what survived a crash.

    Loads the newest intact snapshot (healing CURRENT if it pointed at
    a corrupt or torn publish), builds the server on it, restores the
    epsilon controller from the snapshot's WAL meta, and replays the
    trajectory-log tail through the live Q-update path. The report
    lands in ``server.last_recovery`` (surfaced by /healthz) and
    ``repro_recovery_total{outcome}``.

    ``verify_with``: optional ``request_id -> instance`` mapping (or
    callable); when given, the tail is first re-solved through
    `eval.replay.replay_records` and recovery raises on any bit-level
    mismatch between the log and the recomputed outcomes/rewards.

    Remaining kwargs go to the `AutotuneServer` constructor.
    """
    policy, version, corrupt = registry.load_last_good()
    healed = False
    if registry.current_version() != version:
        # CURRENT pointed at a corrupt/missing snapshot (or at nothing):
        # re-promote the newest good version so this server — and any
        # naive restart after it — loads cleanly.
        registry.promote(version)
        healed = True
    try:
        meta = registry.meta(version)
    except Exception:
        meta = {}
    wal = meta.get("wal") or {}
    snapshot_seq = int(wal.get("seq", 0))

    server = AutotuneServer(registry, **server_kwargs)
    if "eps_level" in wal:
        server.learner.epsilon._level = float(wal["eps_level"])
        server.learner.epsilon._t = int(wal.get("eps_t", 0))
    server.update_seq = snapshot_seq

    try:
        if verify_with is not None:
            from repro.eval.replay import assert_replay_ok, replay_records
            tail = [rec for rec in TrajectoryLog.read(
                        trajlog_path,
                        task=getattr(server.task, "name", None))
                    if rec.get("seq") is not None
                    and int(rec["seq"]) > snapshot_seq]
            if tail:
                assert_replay_ok(replay_records(server.engine, tail,
                                                verify_with))
        report = replay_wal_tail(server, trajlog_path, snapshot_seq)
    except Exception:
        _count_recovery(server, "failed")
        raise
    report.version = version
    report.healed_current = healed
    report.corrupt_versions = list(corrupt)
    server.last_recovery = report.as_meta()
    _count_recovery(server, "ok")
    return server
