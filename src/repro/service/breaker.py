"""Per-bucket circuit breaker: graceful degradation to the safe arm
(DESIGN.md §11.2).

The learned policy can misbehave — a drifted Q-table, a poisoned solve
stream (NaN/divergence), a numerically hostile request mix. The paper's
safety story is that the *all-fp64 arm always exists*: it is the arm a
zeroed Q-row tie-breaks to (`QTable.greedy` breaks ties toward the
highest action index, pinned by tests), the arm offline training
baselines against, and the arm whose outcome a client would have gotten
from a non-autotuning solver. The breaker makes falling back to it
automatic, per size bucket:

  closed     normal serving; solve outcomes feed a sliding window.
             When ≥ `min_samples` of the last `window` outcomes are
             failures (status FAILED, or a non-finite reward/metric)
             and the failure fraction ≥ `failure_threshold`: → open.
  open       selection is pinned to the safe arm (explore coin
             suppressed); Q-updates are quarantined — no reward
             observed while not closed touches the table. Every
             `probe_interval`-th selection in the bucket is a *probe*:
             it uses the learned greedy policy; the first probe moves
             the breaker to half_open.
  half_open  probes continue at the same cadence (non-probe traffic
             stays pinned + quarantined). `probe_successes` consecutive
             healthy probe outcomes close the breaker (window cleared,
             learning resumes); one failed probe falls back to open.

The breaker is deliberately selection-side only: it never cancels an
in-flight solve, and quarantine decisions are made at completion time
against the state the breaker was in *before* that outcome is recorded,
so the probe that closes the breaker is itself still quarantined — only
post-recovery traffic trains the table.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

#: Gauge encoding for repro_breaker_state{bucket}.
STATE_VALUES = {CLOSED: 0.0, HALF_OPEN: 0.5, OPEN: 1.0}


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    enabled: bool = True
    window: int = 16              # sliding outcome window per bucket
    min_samples: int = 8          # no trip below this many in the window
    failure_threshold: float = 0.5
    probe_interval: int = 4       # while not closed: every Nth request
                                  # probes the learned policy
    probe_successes: int = 3      # consecutive healthy probes to close


@dataclasses.dataclass
class _Bucket:
    state: str = CLOSED
    outcomes: deque = dataclasses.field(default_factory=deque)
    selections_while_open: int = 0
    probe_streak: int = 0
    opened_count: int = 0


class CircuitBreakers:
    """All per-bucket breakers of one server.

    ``on_transition(bucket, old, new)`` (optional) fires on every state
    change — the server wires it to metrics/trace.
    """

    def __init__(self, cfg: BreakerConfig = BreakerConfig(),
                 on_transition: Optional[Callable[[int, str, str],
                                                  None]] = None):
        self.cfg = cfg
        self.on_transition = on_transition
        self._buckets: Dict[int, _Bucket] = {}

    def _get(self, bucket: int) -> _Bucket:
        return self._buckets.setdefault(int(bucket), _Bucket())

    def _set_state(self, bucket: int, b: _Bucket, new: str) -> None:
        old, b.state = b.state, new
        if old != new and self.on_transition is not None:
            self.on_transition(bucket, old, new)

    # -- selection side ----------------------------------------------------
    def on_select(self, bucket: int) -> str:
        """Route for the next selection in `bucket`: ``"normal"`` |
        ``"pinned"`` (forced safe arm) | ``"probe"`` (learned policy,
        outcome judged as a probe)."""
        if not self.cfg.enabled:
            return "normal"
        b = self._get(bucket)
        if b.state == CLOSED:
            return "normal"
        b.selections_while_open += 1
        if b.selections_while_open % max(self.cfg.probe_interval, 1) == 0:
            if b.state == OPEN:
                self._set_state(bucket, b, HALF_OPEN)
            return "probe"
        return "pinned"

    # -- completion side ---------------------------------------------------
    def state(self, bucket: int) -> str:
        if not self.cfg.enabled:
            return CLOSED
        b = self._buckets.get(int(bucket))
        return b.state if b is not None else CLOSED

    def on_outcome(self, bucket: int, healthy: bool,
                   probe: bool = False) -> str:
        """Record one completed solve; returns the (possibly new)
        state. Pinned-traffic outcomes while not closed are ignored —
        they ran the safe arm, so they carry no evidence about the
        learned policy's health."""
        if not self.cfg.enabled:
            return CLOSED
        b = self._get(bucket)
        if b.state == CLOSED:
            b.outcomes.append(bool(healthy))
            while len(b.outcomes) > self.cfg.window:
                b.outcomes.popleft()
            n = len(b.outcomes)
            fails = n - sum(b.outcomes)
            if (n >= self.cfg.min_samples
                    and fails / n >= self.cfg.failure_threshold):
                b.outcomes.clear()
                b.selections_while_open = 0
                b.probe_streak = 0
                b.opened_count += 1
                self._set_state(bucket, b, OPEN)
        elif probe:
            if healthy:
                b.probe_streak += 1
                if b.probe_streak >= self.cfg.probe_successes:
                    b.outcomes.clear()
                    b.selections_while_open = 0
                    b.probe_streak = 0
                    self._set_state(bucket, b, CLOSED)
            else:
                b.probe_streak = 0
                self._set_state(bucket, b, OPEN)
        return b.state

    # -- reporting ---------------------------------------------------------
    def open_buckets(self) -> List[int]:
        return sorted(k for k, b in self._buckets.items()
                      if b.state != CLOSED)

    def describe(self) -> Dict[str, dict]:
        """Per-bucket state for /healthz: only buckets that have ever
        tracked an outcome appear."""
        out = {}
        for k in sorted(self._buckets):
            b = self._buckets[k]
            n = len(b.outcomes)
            out[str(k)] = {
                "state": b.state,
                "window": n,
                "failure_frac": ((n - sum(b.outcomes)) / n) if n else 0.0,
                "probe_streak": b.probe_streak,
                "times_opened": b.opened_count,
            }
        return out
