"""Online precision-autotuning service, solver-agnostic.

Streaming counterpart of `core.autotune`: accepts solve requests for any
hosted `TunableTask` (GMRES-IR, CG-IR, ...), picks per-step precisions
with the live bandit policy, executes through per-bucket fixed-shape
micro-batches (one compiled executable per task bucket), and keeps
learning from every observed reward — continual epsilon control,
EWMA-|RPE| drift detection, and versioned policy snapshots with atomic
promote/rollback. All algorithm-specific behavior flows through the
task's `TunableTask` hooks; the server and batcher import no solver.
"""
from repro.obs import Observability
from .batcher import BatcherConfig, FlushResult, MicroBatcher
from .breaker import BreakerConfig, CircuitBreakers
from .instrument import (LearnerInstruments, RolloutInstruments,
                         ServiceInstruments)
from .online import (DriftDetector, EpsilonController, OnlineConfig,
                     OnlineLearner, OnlineUpdate)
from .recovery import RecoveryReport, recover_server, replay_wal_tail
from .registry import PolicyRegistry, SnapshotCorrupted
from .rollout import (OPEGateRejected, RolloutConfig, RolloutDecision,
                      ShadowServer)
from .server import AutotuneServer, SolveResponse
from .telemetry import Ewma, Telemetry

__all__ = [
    "AutotuneServer", "BatcherConfig", "BreakerConfig", "CircuitBreakers",
    "DriftDetector", "EpsilonController", "Ewma", "FlushResult",
    "LearnerInstruments", "MicroBatcher", "Observability", "OnlineConfig",
    "OnlineLearner", "OnlineUpdate", "OPEGateRejected", "PolicyRegistry",
    "RecoveryReport", "RolloutConfig", "RolloutDecision",
    "RolloutInstruments", "ServiceInstruments", "ShadowServer",
    "SnapshotCorrupted", "SolveResponse", "Telemetry", "recover_server",
    "replay_wal_tail",
]
