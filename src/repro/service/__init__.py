"""Online precision-autotuning service.

Streaming counterpart of `core.autotune`: accepts `Ax = b` solve requests,
picks per-step precisions with the live bandit policy, executes through
size-bucketed fixed-shape micro-batches (one compiled solver per bucket),
and keeps learning from every observed reward — continual epsilon control,
EWMA-|RPE| drift detection, and versioned policy snapshots with atomic
promote/rollback.
"""
from .batcher import BatcherConfig, FlushResult, MicroBatcher
from .online import (DriftDetector, EpsilonController, OnlineConfig,
                     OnlineLearner, OnlineUpdate)
from .registry import PolicyRegistry
from .server import AutotuneServer, SolveResponse
from .telemetry import Ewma, Telemetry

__all__ = [
    "AutotuneServer", "BatcherConfig", "DriftDetector", "EpsilonController",
    "Ewma", "FlushResult", "MicroBatcher", "OnlineConfig", "OnlineLearner",
    "OnlineUpdate", "PolicyRegistry", "SolveResponse", "Telemetry",
]
