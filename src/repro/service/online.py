"""Continual online learning: epsilon control + drift detection.

The offline trainer anneals epsilon to `eps_min` over a fixed episode
budget (Eq. 13) and stops. A long-running service never stops: it keeps a
small exploration floor forever, and must *re-open* exploration when the
instance distribution drifts — "Learning to Relax" (Khodak et al.) treats
the online sequence-of-instances setting; Chen's RL-CG work observes that
precision policies go stale under drift.

Drift signal: two EWMAs of |reward-prediction-error|. The slow one tracks
the long-run surprise baseline; the fast one tracks the current regime. A
fast/slow ratio blow-out (after warmup, with a cooldown between triggers)
means the Q-table's predictions stopped matching observed rewards —
i.e. the request distribution moved — and epsilon is boosted back up to
`eps_boost`, then re-annealed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.bandit import QTable
from repro.service.telemetry import Ewma


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    eps0: float = 0.10            # exploration right after warm-start
    eps_min: float = 0.02         # permanent exploration floor
    eps_boost: float = 0.50       # re-exploration level after drift
    decay_updates: int = 500      # updates to anneal eps -> eps_min
    alpha: Optional[float] = 0.1  # online learning rate (None => 1/N)
    ewma_fast: float = 0.10       # fast |RPE| EWMA coefficient
    ewma_slow: float = 0.01       # baseline |RPE| EWMA coefficient
    drift_ratio: float = 2.0      # trigger: fast > ratio * slow + margin
    drift_margin: float = 0.25    # absolute slack (units of reward)
    warmup_updates: int = 64      # no drift checks before this many updates
    cooldown_updates: int = 128   # min updates between triggers


class EpsilonController:
    """Linear anneal from a (re)startable level down to the floor."""

    def __init__(self, cfg: OnlineConfig):
        self.cfg = cfg
        self._level = cfg.eps0
        self._t = 0

    @property
    def value(self) -> float:
        frac = min(self._t / max(self.cfg.decay_updates, 1), 1.0)
        return max(self.cfg.eps_min,
                   self._level + (self.cfg.eps_min - self._level) * frac)

    def step(self) -> None:
        self._t += 1

    def boost(self) -> None:
        """Drift response: re-open exploration and re-anneal."""
        self._level = self.cfg.eps_boost
        self._t = 0


class DriftDetector:
    """Fast-EWMA vs frozen-then-adaptive baseline on |RPE|.

    The fast EWMA (bias-corrected) tracks the current surprise level. The
    baseline is pinned to the fast value when warmup ends — the established
    regime — and from then on adapts as a plain EWMA over *non-anomalous*
    samples only: a sample that already exceeds the trigger threshold is
    evidence of a new regime and must not drag the reference along before
    the trigger fires. (A naive bias-corrected slow EWMA degenerates to a
    running mean at small sample counts and chases the fast EWMA, so the
    ratio never opens; pin-then-gate avoids that.)
    """

    def __init__(self, cfg: OnlineConfig):
        self.cfg = cfg
        self._fast = Ewma(cfg.ewma_fast)
        self._slow: Optional[float] = None
        self._updates = 0
        self._last_trigger = -cfg.cooldown_updates

    @property
    def fast(self) -> float:
        return self._fast.value

    @property
    def slow(self) -> float:
        return self._slow if self._slow is not None else 0.0

    def update(self, abs_rpe: float) -> bool:
        """Feed one |RPE| sample; True iff this sample triggers drift."""
        c = self.cfg
        x = abs(abs_rpe)
        self._updates += 1
        self._fast.update(x)
        if self._updates < c.warmup_updates:
            return False
        if self._slow is None:        # warmup just ended: pin the baseline
            self._slow = self.fast
        anomalous = self.fast > c.drift_ratio * self._slow + c.drift_margin
        if not anomalous:
            self._slow += c.ewma_slow * (x - self._slow)
        if self._updates - self._last_trigger < c.cooldown_updates:
            return False
        if anomalous:
            self._last_trigger = self._updates
            # Re-baseline so one regime change fires exactly once.
            self._slow = self.fast
            return True
        return False


@dataclasses.dataclass
class OnlineUpdate:
    rpe: float
    eps: float
    drift: bool


class OnlineLearner:
    """Continual-learning wrapper: epsilon control + drift detection on
    top of the single Q-update primitive.

    Accepts the live `QTable` directly, or anything exposing one via a
    `.qtable` attribute (an `AutotuneEngine` or `PrecisionPolicy`), so
    the server can hand it the shared engine.

    `obs` (an `repro.obs.Observability`) exports the live epsilon gauge
    and drift/update counters; the hook is fail-open (DESIGN.md §8.1)
    and optional, so offline/test users pay nothing."""

    def __init__(self, qtable, cfg: OnlineConfig = OnlineConfig(),
                 obs=None):
        self.qtable: QTable = getattr(qtable, "qtable", qtable)
        self.cfg = cfg
        self.epsilon = EpsilonController(cfg)
        self.drift = DriftDetector(cfg)
        self._instr = None
        if obs is not None:
            from repro.service.instrument import LearnerInstruments
            self._instr = LearnerInstruments(obs)

    def select(self, state: int) -> int:
        return self.qtable.select(state, self.epsilon.value)

    def update(self, state: int, action: int, reward: float,
               explore: bool = False) -> OnlineUpdate:
        """Q-update + drift check.

        `explore=True` marks an action taken by the epsilon coin: its RPE
        still trains Q, but is excluded from drift detection — exploratory
        actions have intentionally unconverged Q estimates, so their large
        RPEs are expected noise, not evidence the greedy policy went stale.
        First visits to a state are excluded for the same reason: the RPE
        against an all-zero Q row is trivially the full reward magnitude.
        """
        novel = not self.qtable.visited(state)
        rpe = self.qtable.update(state, action, reward)
        drifted = (False if (explore or novel)
                   else self.drift.update(abs(rpe)))
        if drifted:
            self.epsilon.boost()
        self.epsilon.step()
        upd = OnlineUpdate(rpe, self.epsilon.value, drifted)
        if self._instr is not None:
            self._instr.on_update(upd)
        return upd
