"""Streaming autotuning server — one server, any `TunableTask`.

Lifecycle of one request (all single-threaded, pump-driven):

  submit(instance) ── context features via the task's `feature_of` →
      epsilon-greedy action from the *live* policy through the shared
      `AutotuneEngine` (greedy side goes through PrecisionPolicy's
      nearest-visited-bin fallback) → enqueued in the per-bucket
      micro-batcher, which delegates all shape/solve semantics to the
      task.

  step() ── flushes due buckets (full batch or deadline), and for every
      solved row: task reward from the observed `Outcome` → online
      Q-update (continual epsilon + drift detection, service.online) →
      telemetry → an Outcome-carrying response retrievable via poll().

The server contains no algorithm-specific code: GMRES-IR, CG-IR, or any
user task is hosted identically (legacy solver configs are adapted via
`core.task.coerce_task`). The live Q-table starts as a copy of the
promoted registry snapshot, so the snapshot stays immutable;
`snapshot()` publishes the live state back as a new version (and
promotes it) — crash recovery is just "reload CURRENT".

Every lifecycle event is mirrored into the fail-open observability
layer (`repro.obs`, DESIGN.md §8) through `ServiceInstruments`:
metrics, per-request trace spans, and the JSONL trajectory log. A
fault anywhere in that layer is swallowed and counted, never surfaced
to a caller of `submit()`/`step()`; `serve_obs()` opens the HTTP
front door (`/metrics`, `/healthz`, `/readyz`).
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro import faults
from repro.core import aot
from repro.core.bandit import QTable
from repro.core.engine import AutotuneEngine
from repro.core.executor import resolve_executor
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig
from repro.core.task import FAILED, Outcome, coerce_task
from repro.obs import Observability
from repro.service.batcher import BatcherConfig, MicroBatcher
from repro.service.breaker import CLOSED, BreakerConfig, CircuitBreakers
from repro.service.instrument import ServiceInstruments
from repro.service.online import OnlineConfig, OnlineLearner
from repro.service.registry import PolicyRegistry
from repro.service.telemetry import Telemetry


@dataclasses.dataclass
class SolveResponse:
    request_id: int
    action: int                      # index into the action space
    action_names: Tuple[str, ...]    # per-step format names
    record: Outcome
    reward: float
    state: int
    eps: float                       # epsilon in force when selected
    policy_version: str
    bucket: int
    latency_s: float
    drift: bool                      # this update triggered re-exploration
    # Fault-tolerance surface (DESIGN.md §11). `seq` is the WAL
    # sequence number stamped into the trajectory log; recovery replays
    # records with seq > the last snapshot's. `quarantined` marks a
    # reward that did NOT train the Q-table (breaker open, non-finite
    # reward, or deadline expiry).
    seq: int = 0
    quarantined: bool = False
    pinned: bool = False             # selection forced to the safe arm
    probe: bool = False              # half-open probe of the learned policy
    expired: bool = False            # request deadline hit before solve


@dataclasses.dataclass
class _InFlight:
    instance: object
    state: int
    action: int
    eps: float
    explore: bool               # epsilon coin fired (random action)
    submitted_at: float
    bucket: int
    features: object = None     # context vector (trajectory log)
    t_accept: float = 0.0       # submit() entry (trace: selection span)
    pinned: bool = False        # breaker forced the safe arm
    probe: bool = False         # breaker probe (learned policy on trial)


def _live_qtable(snapshot: QTable, alpha, seed: int) -> QTable:
    qt = QTable(snapshot.n_states, snapshot.n_actions, alpha, seed)
    qt.Q = snapshot.Q.copy()
    qt.N = snapshot.N.copy()
    return qt


class AutotuneServer:
    def __init__(self,
                 registry: Union[PolicyRegistry, PrecisionPolicy],
                 task=None,
                 reward_cfg: RewardConfig = RewardConfig(),
                 batcher_cfg: BatcherConfig = BatcherConfig(),
                 online_cfg: OnlineConfig = OnlineConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0,
                 max_retained_responses: int = 65536,
                 executor=None,
                 obs: Union[None, bool, Observability] = None,
                 auto_step: bool = True,
                 breaker_cfg: BreakerConfig = BreakerConfig(),
                 warmup: Optional[str] = None,
                 warmup_buckets: Optional[List[int]] = None,
                 compile_cache_dir: Optional[str] = None,
                 warmup_pace: Optional[Callable] = None):
        if isinstance(registry, PolicyRegistry):
            self.registry: Optional[PolicyRegistry] = registry
            snapshot = registry.load()
            self.policy_version = registry.current_version() or "unversioned"
        else:
            self.registry = None
            snapshot = registry
            self.policy_version = "unversioned"
        # Accept a TunableTask or a legacy solver config (adapted, using
        # this server's batcher bucket settings). An explicit `executor`
        # (a `core.executor` spec — "local", "sharded", or an instance)
        # overrides the task's; the micro-batcher sizes its flushes to
        # the executor's mesh width (DESIGN.md §7).
        self.task = coerce_task(task, bucket_step=batcher_cfg.bucket_step,
                                min_bucket=batcher_cfg.min_bucket)
        if executor is not None:
            self.task.executor = resolve_executor(executor)
        self.executor = resolve_executor(
            getattr(self.task, "executor", None))
        task_space = getattr(self.task, "action_space", None)
        if task_space is None:
            self.task.action_space = snapshot.action_space
        elif not np.array_equal(task_space.actions,
                                snapshot.action_space.actions):
            # The batcher executes snapshot-space actions; rewarding them
            # through a different task space would silently score actions
            # that were never run.
            raise ValueError(
                "task.action_space does not match the policy snapshot's "
                "action space; build the task with the snapshot's space "
                "(or leave it None to inherit it)")
        self.action_space = snapshot.action_space
        self.discretizer = snapshot.discretizer
        self.live = PrecisionPolicy(
            snapshot.action_space, snapshot.discretizer,
            _live_qtable(snapshot.qtable, online_cfg.alpha, seed))
        # Observability is on by default (fail-open, DESIGN.md §8):
        # None/True joins the process-default metrics registry; an
        # explicit `Observability` isolates/extends (trajectory log,
        # private registry); False disables the whole layer (the
        # metrics-off arm of benchmarks/service_bench.py).
        if obs is False:
            self.obs: Optional[Observability] = None
        elif obs is None or obs is True:
            self.obs = Observability()
        else:
            self.obs = obs
        self.engine = AutotuneEngine(self.task, reward_cfg,
                                     policy=self.live, seed=seed)
        self.learner = OnlineLearner(self.engine, online_cfg,
                                     obs=self.obs)
        self.reward_cfg = reward_cfg
        # Clock-skew fault site: with a `clock:clock_skew` spec active
        # the wrapped clock accumulates injected offsets (deadline and
        # drain logic must survive time jumping forward).
        self.clock = faults.wrap_clock(clock)
        self.batcher = MicroBatcher(self.task, batcher_cfg, self.clock)
        self.telemetry = Telemetry()
        # Graceful degradation (DESIGN.md §11.2): per-bucket circuit
        # breakers pin selection to the safe all-fp64 arm and quarantine
        # Q-updates when a bucket's failure/divergence rate trips.
        self.breakers = CircuitBreakers(
            breaker_cfg, on_transition=self._on_breaker_transition)
        self.safe_action = self.live.safe_action
        # Write-ahead sequencing for crash recovery (service.recovery):
        # every completed request gets the next seq, stamped into its
        # trajectory-log record; snapshot() embeds the seq it covers.
        self.update_seq = 0
        self.quarantined_updates = 0
        self.expired_requests = 0
        self.last_recovery: Optional[dict] = None
        self._instr = (ServiceInstruments(
            self.obs, getattr(self.task, "name", "unknown"),
            self.executor.name) if self.obs is not None else None)
        self._inflight: Dict[int, _InFlight] = {}
        # Bounded LRU retention for poll(): poll() evicts on retrieval,
        # and the oldest *unclaimed* responses are evicted past the cap
        # (counted in repro_server_responses_evicted_total), so consumers
        # that never poll don't leak memory over a long-running server's
        # lifetime.
        self._responses: "OrderedDict[int, SolveResponse]" = OrderedDict()
        self._max_retained = max_retained_responses
        self.responses_evicted = 0
        # When False, submit() only enqueues — an external pump (the HTTP
        # front door's background flush loop) drives step() instead of
        # every caller.
        self.auto_step = auto_step
        # Optional subscriber, called with each SolveResponse in completion
        # order (the order Q-updates were applied) — push-style consumers.
        self.on_response: Optional[Callable[[SolveResponse], None]] = None
        # Compile-cliff controls (DESIGN.md §12): persistent compile
        # cache (env-driven; no-op when neither the kwarg nor
        # REPRO_COMPILE_CACHE_DIR is set) + optional AOT warmup of the
        # executable grid. `warm_buckets` feeds the readiness gate — a
        # bucket is warm once it has either flushed a live batch or
        # been AOT-precompiled; with a warmup grid configured, /readyz
        # holds at 503 until the whole expected grid is warm.
        aot.enable_persistent_cache(compile_cache_dir)
        self.warm_buckets: set = set()
        self.warm_order: List[int] = []
        self.warmup = None
        self._warmup_mode = warmup
        self._warmup_expected: frozenset = frozenset()
        if warmup is not None:
            if warmup not in ("sync", "background"):
                raise ValueError("warmup must be None, 'sync' or "
                                 f"'background', got {warmup!r}")
            trajlog = getattr(self.obs, "trajlog", None)
            entries = aot.plan(
                [self.task], self._warmup_bucket_list(warmup_buckets),
                batcher_cfg.max_batch,
                trajectory_path=getattr(trajlog, "path", None))
            self._warmup_expected = frozenset(e.bucket for e in entries)
            if warmup == "sync":
                self.warmup = aot.precompile(entries,
                                             on_entry=self._on_warm)
            else:
                self.warmup = aot.BackgroundWarmup(
                    entries, on_entry=self._on_warm,
                    pace=warmup_pace).start()

    # -- request path ------------------------------------------------------
    def select_action(self, features) -> Tuple[int, int, float, bool]:
        """(state, action, eps, explore): epsilon-greedy, live policy."""
        eps = self.learner.epsilon.value
        state, action, explore = self.engine.select_for_features(features,
                                                                 eps)
        return state, action, eps, explore

    def submit(self, instance, req_id: Optional[int] = None) -> int:
        t_accept = self.clock()
        feats = self.task.feature_of(instance)
        state, action, eps, explore = self.select_action(feats)
        # Breaker routing (DESIGN.md §11.2): while a bucket's breaker is
        # not closed, non-probe selections are pinned to the safe
        # all-fp64 arm; probes keep the learned choice so recovery has
        # evidence to close on. The epsilon-greedy draw above always
        # happens, so the selection RNG stream is identical whether or
        # not the breaker interferes.
        route = self.breakers.on_select(self.task.bucket_key(instance))
        if route == "pinned":
            action, explore = self.safe_action, False
        req_id, bucket = self.batcher.submit(
            instance, self.action_space.actions[action], req_id=req_id)
        now = self.clock()
        self._inflight[req_id] = _InFlight(instance, state, action, eps,
                                           explore, now, bucket,
                                           features=feats,
                                           t_accept=t_accept,
                                           pinned=(route == "pinned"),
                                           probe=(route == "probe"))
        self.telemetry.on_submit(bucket, now)
        if self._instr is not None:
            self._instr.on_submit(bucket, action, explore, self.pending)
        if self.auto_step:
            self.step()      # flush any bucket this submit filled
        return req_id

    def step(self, force: bool = False) -> List[SolveResponse]:
        """Pump due micro-batches through solve -> reward -> Q-update."""
        done: List[SolveResponse] = []
        for entry in self.batcher.expire_overdue():
            done.append(self._complete_expired(entry))
        for flush in self.batcher.pump(force=force):
            self.telemetry.on_batch(flush.bucket, len(flush.req_ids),
                                    flush.n_rows)
            if self._instr is not None:
                self._instr.on_flush(flush, self.pending)
            for req_id, rec in zip(flush.req_ids, flush.records):
                done.append(self._complete(req_id, rec, flush))
        return done

    def drain(self) -> List[SolveResponse]:
        """Force-flush everything still queued."""
        return self.step(force=True)

    def poll(self, req_id: int) -> Optional[SolveResponse]:
        """Response for `req_id` if finished (removes it), else None."""
        return self._responses.pop(req_id, None)

    @property
    def pending(self) -> int:
        return self.batcher.pending

    # -- learn path --------------------------------------------------------
    @staticmethod
    def _healthy(rec: Outcome, r: float) -> bool:
        """Breaker-window health of one solve: FAILED status or any
        non-finite reward/cost/metric counts as a failure."""
        if int(rec.status) == FAILED or not math.isfinite(r):
            return False
        try:
            vals = [float(rec.cost)] + [float(v)
                                        for v in rec.metrics.values()]
        except (TypeError, ValueError):
            return False
        return all(math.isfinite(v) for v in vals)

    def _on_breaker_transition(self, bucket: int, old: str,
                               new: str) -> None:
        if self._instr is not None:
            self._instr.on_breaker_transition(bucket, old, new)

    def _complete(self, req_id: int, rec: Outcome,
                  flush=None) -> SolveResponse:
        info = self._inflight.pop(req_id)
        r = self.engine.reward_for(rec, info.action, info.instance)
        t_reward = self.clock()
        healthy = self._healthy(rec, r)
        # Quarantine is decided against the breaker state *before* this
        # outcome is recorded (DESIGN.md §11.2): the probe that closes
        # the breaker is itself still quarantined, and only traffic
        # selected after recovery trains the table. Pinned outcomes ran
        # the safe arm — no evidence about the learned policy — so they
        # never feed the breaker window.
        state_before = self.breakers.state(info.bucket)
        if not info.pinned:
            self.breakers.on_outcome(info.bucket, healthy,
                                     probe=info.probe)
        quarantined = (state_before != CLOSED or info.pinned
                       or not math.isfinite(r))
        if quarantined:
            self.quarantined_updates += 1
            rpe, drift = 0.0, False
            if self._instr is not None:
                self._instr.on_quarantine(info.bucket)
        else:
            upd = self.learner.update(info.state, info.action, r,
                                      explore=info.explore)
            rpe, drift = upd.rpe, upd.drift
            self.telemetry.on_update(abs(rpe), drift)
        self.update_seq += 1
        now = self.clock()
        resp = SolveResponse(
            request_id=req_id, action=info.action,
            action_names=self.action_space.names(info.action),
            record=rec, reward=r, state=info.state, eps=info.eps,
            policy_version=self.policy_version, bucket=info.bucket,
            latency_s=now - info.submitted_at, drift=drift,
            seq=self.update_seq, quarantined=quarantined,
            pinned=info.pinned, probe=info.probe)
        self.telemetry.on_response(resp.latency_s, resp.action_names,
                                   resp.action, r, now,
                                   bucket=info.bucket,
                                   status=int(rec.status))
        if self._instr is not None:
            self._instr.on_complete(resp, info, flush, self.telemetry,
                                    t_reward, now)
        return self._deliver(resp)

    def _complete_expired(self, entry) -> SolveResponse:
        """Terminal FAILED response for a request whose batcher deadline
        expired before it was solved. No Q-update (quarantined), no
        breaker evidence — the solve never ran."""
        info = self._inflight.pop(entry.req_id)
        self.expired_requests += 1
        self.update_seq += 1
        rec = Outcome(status=FAILED, cost=0.0, metrics={"expired": 1.0})
        r = float(getattr(self.reward_cfg, "fail_reward", -30.0))
        now = self.clock()
        resp = SolveResponse(
            request_id=entry.req_id, action=info.action,
            action_names=self.action_space.names(info.action),
            record=rec, reward=r, state=info.state, eps=info.eps,
            policy_version=self.policy_version, bucket=info.bucket,
            latency_s=now - info.submitted_at, drift=False,
            seq=self.update_seq, quarantined=True,
            pinned=info.pinned, probe=info.probe, expired=True)
        self.telemetry.on_response(resp.latency_s, resp.action_names,
                                   resp.action, r, now,
                                   bucket=info.bucket,
                                   status=int(rec.status))
        if self._instr is not None:
            self._instr.on_expired(info.bucket)
            self._instr.on_complete(resp, info, None, self.telemetry,
                                    now, now)
        return self._deliver(resp)

    def _deliver(self, resp: SolveResponse) -> SolveResponse:
        self._responses[resp.request_id] = resp
        while len(self._responses) > self._max_retained:
            self._responses.popitem(last=False)
            self.responses_evicted += 1
            if self._instr is not None:
                self._instr.on_evict()
        if self.on_response is not None:
            self.on_response(resp)
        return resp

    # -- AOT warmup (DESIGN.md §12) ----------------------------------------
    def _warmup_bucket_list(self, warmup_buckets) -> List[int]:
        """Bucket keys the warmup grid covers: explicit expected request
        sizes (normalized through the task's bucketing, so callers may
        pass either raw n's or bucket keys), else the buckets of the
        task's own instances, else the minimum bucket."""
        from repro.core.task import bucket_of
        step = getattr(self.task, "bucket_step",
                       self.batcher.cfg.bucket_step)
        lo = getattr(self.task, "min_bucket", self.batcher.cfg.min_bucket)
        if warmup_buckets:
            return sorted({bucket_of(int(n), step, lo)
                           for n in warmup_buckets})
        instances = getattr(self.task, "instances", ())
        if instances:
            return sorted({self.task.bucket_key(s) for s in instances})
        return [int(lo)]

    def _on_warm(self, entry, warmed: bool) -> None:
        # warmed=False still flips the gate: the task has no AOT form
        # for that cell, so holding /readyz on it would never resolve —
        # the bucket compiles on first hit exactly as it always did.
        self.warm_buckets.add(int(entry.bucket))
        self.warm_order.append(int(entry.bucket))

    def warmup_state(self) -> Optional[dict]:
        """Per-bucket AOT warmup progress, surfaced through `/readyz`
        and `/healthz` (None when no warmup was configured)."""
        if self._warmup_mode is None:
            return None
        rep = getattr(self.warmup, "report", self.warmup)
        return {"mode": self._warmup_mode,
                "expected_buckets": sorted(self._warmup_expected),
                "warmed_buckets": sorted(self.warm_buckets),
                "pending_buckets": sorted(self._warmup_expected
                                          - self.warm_buckets),
                "done": bool(rep.done),
                "elapsed_s": round(float(rep.seconds), 3),
                "errors": list(rep.errors),
                "compile_cache": aot.cache_stats()}

    # -- observability front door ------------------------------------------
    @property
    def ready(self) -> bool:
        """Readiness (the `/readyz` gate): a policy snapshot is loaded
        and the bucket grid is warm. A bucket counts as warm once it
        has flushed (= compiled) at least one live micro-batch OR been
        AOT-precompiled (DESIGN.md §12). With a warmup grid configured
        the whole expected grid must be warm — the background sweep
        flips this per bucket; without one the legacy rule holds: at
        least one batch has run and no traffic-seen bucket is cold. A
        server that reports ready will not serve a request through an
        XLA compile."""
        if self.live is None:
            return False
        warmed = set(self.telemetry.batches_per_bucket) | self.warm_buckets
        seen = set(self.telemetry.requests_per_bucket)
        if self._warmup_expected:
            return self._warmup_expected <= warmed and seen <= warmed
        return bool(warmed) and seen <= warmed

    def degradation_state(self) -> dict:
        """Fault-tolerance surface for `/healthz` + `/readyz`
        (DESIGN.md §11): open breakers per bucket, quarantine/expiry
        counters, and what the last crash recovery replayed."""
        open_buckets = self.breakers.open_buckets()
        out = {
            "degraded": bool(open_buckets),
            "breakers": self.breakers.describe(),
            "open_buckets": open_buckets,
            "quarantined_updates": self.quarantined_updates,
            "expired_requests": self.expired_requests,
            "update_seq": self.update_seq,
        }
        if self.last_recovery is not None:
            out["last_recovery"] = dict(self.last_recovery)
        warmup = self.warmup_state()
        if warmup is not None:
            out["warmup"] = warmup
        return out

    def serve_obs(self, host: str = "127.0.0.1", port: int = 0):
        """Open the HTTP observability surface (`/metrics`, `/healthz`,
        `/readyz`, `/telemetry`, `/trace`); returns the `ObsHTTPServer`
        (read `.url`). The first externally visible face of the server."""
        if self.obs is None:
            raise RuntimeError("server was built with obs=False")
        return self.obs.serve(host=host, port=port,
                              ready_fn=lambda: self.ready,
                              telemetry_fn=self.telemetry.snapshot,
                              health_fn=self.degradation_state)

    # -- snapshotting ------------------------------------------------------
    def snapshot(self, note: str = "online snapshot") -> str:
        """Publish + promote the live policy as a new registry version.

        The version's meta embeds the current telemetry evidence
        (reward/|RPE| EWMAs, per-bucket p99, drift count) so every
        promoted policy carries the numbers it was promoted on — the
        gating inputs of the canary-promotion workstream."""
        if self.registry is None:
            raise RuntimeError("server was built without a registry")
        tel = self.telemetry
        version = self.registry.publish(
            self.live, note=note,
            extra_meta={"task": getattr(self.task, "name", "unknown"),
                        "online_updates": tel.updates,
                        "drift_events": tel.drift_events,
                        # Crash-recovery watermark (service.recovery):
                        # this snapshot covers every trajectory-log
                        # record with seq <= wal.seq; replay resumes
                        # after it, with epsilon restored.
                        "wal": {
                            "seq": self.update_seq,
                            "eps_level": self.learner.epsilon._level,
                            "eps_t": self.learner.epsilon._t,
                        },
                        "telemetry": {
                            "responses": tel.responses,
                            "reward_ewma": tel.reward_ewma.value,
                            "abs_rpe_ewma": tel.abs_rpe_ewma.value,
                            "converged_frac": tel.converged_frac,
                            "status_counts": {
                                str(k): v for k, v
                                in sorted(tel.status_counts.items())},
                            "drift_events": tel.drift_events,
                            "throughput_rps": tel.throughput_rps,
                            "latency_s": tel.latency_percentiles(),
                            "latency_s_per_bucket":
                                tel.latency_percentiles_per_bucket(),
                        }})
        self.registry.promote(version)
        self.policy_version = version
        if self._instr is not None:
            self._instr.on_snapshot(version)
        return version
