"""Streaming autotuning server — one server, any `TunableTask`.

Lifecycle of one request (all single-threaded, pump-driven):

  submit(instance) ── context features via the task's `feature_of` →
      epsilon-greedy action from the *live* policy through the shared
      `AutotuneEngine` (greedy side goes through PrecisionPolicy's
      nearest-visited-bin fallback) → enqueued in the per-bucket
      micro-batcher, which delegates all shape/solve semantics to the
      task.

  step() ── flushes due buckets (full batch or deadline), and for every
      solved row: task reward from the observed `Outcome` → online
      Q-update (continual epsilon + drift detection, service.online) →
      telemetry → an Outcome-carrying response retrievable via poll().

The server contains no algorithm-specific code: GMRES-IR, CG-IR, or any
user task is hosted identically (legacy solver configs are adapted via
`core.task.coerce_task`). The live Q-table starts as a copy of the
promoted registry snapshot, so the snapshot stays immutable;
`snapshot()` publishes the live state back as a new version (and
promotes it) — crash recovery is just "reload CURRENT".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.bandit import QTable
from repro.core.engine import AutotuneEngine
from repro.core.executor import resolve_executor
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig
from repro.core.task import Outcome, coerce_task
from repro.service.batcher import BatcherConfig, MicroBatcher
from repro.service.online import OnlineConfig, OnlineLearner
from repro.service.registry import PolicyRegistry
from repro.service.telemetry import Telemetry


@dataclasses.dataclass
class SolveResponse:
    request_id: int
    action: int                      # index into the action space
    action_names: Tuple[str, ...]    # per-step format names
    record: Outcome
    reward: float
    state: int
    eps: float                       # epsilon in force when selected
    policy_version: str
    bucket: int
    latency_s: float
    drift: bool                      # this update triggered re-exploration


@dataclasses.dataclass
class _InFlight:
    instance: object
    state: int
    action: int
    eps: float
    explore: bool               # epsilon coin fired (random action)
    submitted_at: float
    bucket: int


def _live_qtable(snapshot: QTable, alpha, seed: int) -> QTable:
    qt = QTable(snapshot.n_states, snapshot.n_actions, alpha, seed)
    qt.Q = snapshot.Q.copy()
    qt.N = snapshot.N.copy()
    return qt


class AutotuneServer:
    def __init__(self,
                 registry: Union[PolicyRegistry, PrecisionPolicy],
                 task=None,
                 reward_cfg: RewardConfig = RewardConfig(),
                 batcher_cfg: BatcherConfig = BatcherConfig(),
                 online_cfg: OnlineConfig = OnlineConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0,
                 max_retained_responses: int = 65536,
                 executor=None):
        if isinstance(registry, PolicyRegistry):
            self.registry: Optional[PolicyRegistry] = registry
            snapshot = registry.load()
            self.policy_version = registry.current_version() or "unversioned"
        else:
            self.registry = None
            snapshot = registry
            self.policy_version = "unversioned"
        # Accept a TunableTask or a legacy solver config (adapted, using
        # this server's batcher bucket settings). An explicit `executor`
        # (a `core.executor` spec — "local", "sharded", or an instance)
        # overrides the task's; the micro-batcher sizes its flushes to
        # the executor's mesh width (DESIGN.md §7).
        self.task = coerce_task(task, bucket_step=batcher_cfg.bucket_step,
                                min_bucket=batcher_cfg.min_bucket)
        if executor is not None:
            self.task.executor = resolve_executor(executor)
        self.executor = resolve_executor(
            getattr(self.task, "executor", None))
        task_space = getattr(self.task, "action_space", None)
        if task_space is None:
            self.task.action_space = snapshot.action_space
        elif not np.array_equal(task_space.actions,
                                snapshot.action_space.actions):
            # The batcher executes snapshot-space actions; rewarding them
            # through a different task space would silently score actions
            # that were never run.
            raise ValueError(
                "task.action_space does not match the policy snapshot's "
                "action space; build the task with the snapshot's space "
                "(or leave it None to inherit it)")
        self.action_space = snapshot.action_space
        self.discretizer = snapshot.discretizer
        self.live = PrecisionPolicy(
            snapshot.action_space, snapshot.discretizer,
            _live_qtable(snapshot.qtable, online_cfg.alpha, seed))
        self.engine = AutotuneEngine(self.task, reward_cfg,
                                     policy=self.live, seed=seed)
        self.learner = OnlineLearner(self.engine, online_cfg)
        self.reward_cfg = reward_cfg
        self.clock = clock
        self.batcher = MicroBatcher(self.task, batcher_cfg, clock)
        self.telemetry = Telemetry()
        self._inflight: Dict[int, _InFlight] = {}
        # Bounded retention for poll(): oldest un-polled responses are
        # evicted past the cap, so push-style consumers that never poll
        # don't leak memory over a long-running server's lifetime.
        self._responses: Dict[int, SolveResponse] = {}
        self._max_retained = max_retained_responses
        # Optional subscriber, called with each SolveResponse in completion
        # order (the order Q-updates were applied) — push-style consumers.
        self.on_response: Optional[Callable[[SolveResponse], None]] = None

    # -- request path ------------------------------------------------------
    def select_action(self, features) -> Tuple[int, int, float, bool]:
        """(state, action, eps, explore): epsilon-greedy, live policy."""
        eps = self.learner.epsilon.value
        state, action, explore = self.engine.select_for_features(features,
                                                                 eps)
        return state, action, eps, explore

    def submit(self, instance) -> int:
        feats = self.task.feature_of(instance)
        state, action, eps, explore = self.select_action(feats)
        req_id, bucket = self.batcher.submit(
            instance, self.action_space.actions[action])
        self._inflight[req_id] = _InFlight(instance, state, action, eps,
                                           explore, self.clock(), bucket)
        self.telemetry.on_submit(bucket)
        self.step()          # flush any bucket this submit filled
        return req_id

    def step(self, force: bool = False) -> List[SolveResponse]:
        """Pump due micro-batches through solve -> reward -> Q-update."""
        done: List[SolveResponse] = []
        for flush in self.batcher.pump(force=force):
            self.telemetry.on_batch(flush.bucket, len(flush.req_ids),
                                    flush.n_rows)
            for req_id, rec in zip(flush.req_ids, flush.records):
                done.append(self._complete(req_id, rec))
        return done

    def drain(self) -> List[SolveResponse]:
        """Force-flush everything still queued."""
        return self.step(force=True)

    def poll(self, req_id: int) -> Optional[SolveResponse]:
        """Response for `req_id` if finished (removes it), else None."""
        return self._responses.pop(req_id, None)

    @property
    def pending(self) -> int:
        return self.batcher.pending

    # -- learn path --------------------------------------------------------
    def _complete(self, req_id: int, rec: Outcome) -> SolveResponse:
        info = self._inflight.pop(req_id)
        now = self.clock()
        r = self.engine.reward_for(rec, info.action, info.instance)
        upd = self.learner.update(info.state, info.action, r,
                                  explore=info.explore)
        self.telemetry.on_update(abs(upd.rpe), upd.drift)
        resp = SolveResponse(
            request_id=req_id, action=info.action,
            action_names=self.action_space.names(info.action),
            record=rec, reward=r, state=info.state, eps=info.eps,
            policy_version=self.policy_version, bucket=info.bucket,
            latency_s=now - info.submitted_at, drift=upd.drift)
        self.telemetry.on_response(resp.latency_s, resp.action_names,
                                   resp.action, r, now)
        self._responses[req_id] = resp
        while len(self._responses) > self._max_retained:
            self._responses.pop(next(iter(self._responses)))
        if self.on_response is not None:
            self.on_response(resp)
        return resp

    # -- snapshotting ------------------------------------------------------
    def snapshot(self, note: str = "online snapshot") -> str:
        """Publish + promote the live policy as a new registry version."""
        if self.registry is None:
            raise RuntimeError("server was built without a registry")
        version = self.registry.publish(
            self.live, note=note,
            extra_meta={"task": getattr(self.task, "name", "unknown"),
                        "online_updates": self.telemetry.updates,
                        "drift_events": self.telemetry.drift_events})
        self.registry.promote(version)
        self.policy_version = version
        return version
