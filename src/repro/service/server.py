"""Streaming precision-autotuning server.

Lifecycle of one request (all single-threaded, pump-driven):

  submit(system) ── feature extraction (already attached to the
      LinearSystem at ingest) → state via the snapshot Discretizer →
      epsilon-greedy action from the *live* Q-table (greedy side goes
      through PrecisionPolicy's nearest-visited-bin fallback) → enqueued
      in the per-bucket micro-batcher.

  step() ── flushes due buckets (full batch or deadline), and for every
      solved row: Eq. 21 reward from the observed SolveRecord → online
      Q-update (continual epsilon + drift detection, service.online) →
      telemetry → a SolveRecord-carrying response retrievable via poll().

The live Q-table starts as a copy of the promoted registry snapshot, so
the snapshot stays immutable; `snapshot()` publishes the live state back
as a new version (and promotes it) — crash recovery is just "reload
CURRENT".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.bandit import QTable
from repro.core.batching import SolveRecord
from repro.core.features import feature_vector
from repro.core.policy import PrecisionPolicy
from repro.core.rewards import RewardConfig, reward as reward_fn
from repro.data.matrices import LinearSystem
from repro.solvers.ir import IRConfig
from repro.service.batcher import BatcherConfig, MicroBatcher
from repro.service.online import OnlineConfig, OnlineLearner
from repro.service.registry import PolicyRegistry
from repro.service.telemetry import Telemetry


@dataclasses.dataclass
class SolveResponse:
    request_id: int
    action: int                      # index into the action space
    action_names: Tuple[str, ...]    # (u_f, u, u_g, u_r) format names
    record: SolveRecord
    reward: float
    state: int
    eps: float                       # epsilon in force when selected
    policy_version: str
    bucket: int
    latency_s: float
    drift: bool                      # this update triggered re-exploration


@dataclasses.dataclass
class _InFlight:
    system: LinearSystem
    state: int
    action: int
    eps: float
    explore: bool               # epsilon coin fired (random action)
    submitted_at: float
    bucket: int


def _live_qtable(snapshot: QTable, alpha, seed: int) -> QTable:
    qt = QTable(snapshot.n_states, snapshot.n_actions, alpha, seed)
    qt.Q = snapshot.Q.copy()
    qt.N = snapshot.N.copy()
    return qt


class AutotuneServer:
    def __init__(self,
                 registry: Union[PolicyRegistry, PrecisionPolicy],
                 ir_cfg: IRConfig = IRConfig(),
                 reward_cfg: RewardConfig = RewardConfig(),
                 batcher_cfg: BatcherConfig = BatcherConfig(),
                 online_cfg: OnlineConfig = OnlineConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0,
                 max_retained_responses: int = 65536):
        if isinstance(registry, PolicyRegistry):
            self.registry: Optional[PolicyRegistry] = registry
            snapshot = registry.load()
            self.policy_version = registry.current_version() or "unversioned"
        else:
            self.registry = None
            snapshot = registry
            self.policy_version = "unversioned"
        self.action_space: ActionSpace = snapshot.action_space
        self.discretizer = snapshot.discretizer
        self.live = PrecisionPolicy(
            snapshot.action_space, snapshot.discretizer,
            _live_qtable(snapshot.qtable, online_cfg.alpha, seed))
        self.learner = OnlineLearner(self.live.qtable, online_cfg)
        self.reward_cfg = reward_cfg
        self.clock = clock
        self.batcher = MicroBatcher(ir_cfg, batcher_cfg, clock)
        self.telemetry = Telemetry()
        self._rng = np.random.default_rng(seed)
        self._inflight: Dict[int, _InFlight] = {}
        # Bounded retention for poll(): oldest un-polled responses are
        # evicted past the cap, so push-style consumers that never poll
        # don't leak memory over a long-running server's lifetime.
        self._responses: Dict[int, SolveResponse] = {}
        self._max_retained = max_retained_responses
        # Optional subscriber, called with each SolveResponse in completion
        # order (the order Q-updates were applied) — push-style consumers.
        self.on_response: Optional[Callable[[SolveResponse], None]] = None

    # -- request path ------------------------------------------------------
    def select_action(self, features: np.ndarray
                      ) -> Tuple[int, int, float, bool]:
        """(state, action, eps, explore): epsilon-greedy, live policy."""
        state = self.live.state_of(features)
        eps = self.learner.epsilon.value
        explore = bool(self._rng.random() < eps)
        if explore:
            action = int(self._rng.integers(self.action_space.n_actions))
        else:
            action, _ = self.live.predict(features)
        return state, action, eps, explore

    def submit(self, system: LinearSystem) -> int:
        feats = feature_vector(system.features)
        state, action, eps, explore = self.select_action(feats)
        req_id, bucket = self.batcher.submit(
            system, self.action_space.actions[action])
        self._inflight[req_id] = _InFlight(system, state, action, eps,
                                           explore, self.clock(), bucket)
        self.telemetry.on_submit(bucket)
        self.step()          # flush any bucket this submit filled
        return req_id

    def step(self, force: bool = False) -> List[SolveResponse]:
        """Pump due micro-batches through solve -> reward -> Q-update."""
        done: List[SolveResponse] = []
        for flush in self.batcher.pump(force=force):
            self.telemetry.on_batch(flush.bucket, len(flush.req_ids),
                                    flush.n_rows)
            for req_id, rec in zip(flush.req_ids, flush.records):
                done.append(self._complete(req_id, rec))
        return done

    def drain(self) -> List[SolveResponse]:
        """Force-flush everything still queued."""
        return self.step(force=True)

    def poll(self, req_id: int) -> Optional[SolveResponse]:
        """Response for `req_id` if finished (removes it), else None."""
        return self._responses.pop(req_id, None)

    @property
    def pending(self) -> int:
        return self.batcher.pending

    # -- learn path --------------------------------------------------------
    def _complete(self, req_id: int, rec: SolveRecord) -> SolveResponse:
        info = self._inflight.pop(req_id)
        now = self.clock()
        action_row = self.action_space.actions[info.action]
        r = reward_fn(rec.ferr, rec.nbe, rec.n_gmres, rec.status,
                      action_row, info.system.features["kappa_est"],
                      self.reward_cfg)
        upd = self.learner.update(info.state, info.action, r,
                                  explore=info.explore)
        self.telemetry.on_update(abs(upd.rpe), upd.drift)
        resp = SolveResponse(
            request_id=req_id, action=info.action,
            action_names=self.action_space.names(info.action),
            record=rec, reward=r, state=info.state, eps=info.eps,
            policy_version=self.policy_version, bucket=info.bucket,
            latency_s=now - info.submitted_at, drift=upd.drift)
        self.telemetry.on_response(resp.latency_s, resp.action_names,
                                   resp.action, r, now)
        self._responses[req_id] = resp
        while len(self._responses) > self._max_retained:
            self._responses.pop(next(iter(self._responses)))
        if self.on_response is not None:
            self.on_response(resp)
        return resp

    # -- snapshotting ------------------------------------------------------
    def snapshot(self, note: str = "online snapshot") -> str:
        """Publish + promote the live policy as a new registry version."""
        if self.registry is None:
            raise RuntimeError("server was built without a registry")
        version = self.registry.publish(
            self.live, note=note,
            extra_meta={"online_updates": self.telemetry.updates,
                        "drift_events": self.telemetry.drift_events})
        self.registry.promote(version)
        self.policy_version = version
        return version
