"""Per-bucket micro-batcher for streaming solve requests.

Requests are identity-padded to their size bucket on submit and queued per
bucket. A bucket flushes when it holds `max_batch` requests (full batch) or
when its oldest request has waited `max_wait_s` (partial batch, padded by
repeating row 0 — see `core.batching.solve_fixed_batch`). Every flush for a
given bucket therefore has the identical (max_batch, n_pad, n_pad) shape,
so XLA compiles one `gmres_ir_batch` executable per bucket per process and
every subsequent flush is compile-free.

Single-threaded by design: `pump()` is driven by the server's event loop
(or a test), and the clock is injectable so flush-by-timeout is exactly
testable without sleeping.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.batching import SolveRecord, bucket_of, solve_fixed_batch
from repro.data.matrices import LinearSystem, pad_system
from repro.solvers.ir import IRConfig


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 8          # rows per compiled batch (flush when full)
    max_wait_s: float = 0.05    # oldest-request deadline for partial flush
    bucket_step: int = 128
    min_bucket: int = 128


@dataclasses.dataclass
class _Pending:
    req_id: int
    A: np.ndarray               # padded rows
    b: np.ndarray
    x: np.ndarray
    action_row: np.ndarray
    enqueued_at: float
    bucket: int


@dataclasses.dataclass
class FlushResult:
    bucket: int
    req_ids: List[int]
    records: List[SolveRecord]
    n_rows: int                 # rows solved (== max_batch, incl. padding)


class MicroBatcher:
    def __init__(self, ir_cfg: IRConfig,
                 cfg: BatcherConfig = BatcherConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.ir_cfg = ir_cfg
        self.cfg = cfg
        self.clock = clock
        self._queues: Dict[int, List[_Pending]] = {}
        self._ids = itertools.count()

    # -- enqueue -----------------------------------------------------------
    def submit(self, system: LinearSystem, action_row: np.ndarray,
               req_id: Optional[int] = None) -> Tuple[int, int]:
        """Queue one (system, action) solve; returns (request id, bucket)."""
        if req_id is None:
            req_id = next(self._ids)
        bucket = bucket_of(system.n, self.cfg.bucket_step,
                           self.cfg.min_bucket)
        A, b, x = pad_system(system, bucket)
        self._queues.setdefault(bucket, []).append(
            _Pending(req_id, A, b, x, np.asarray(action_row, np.int32),
                     self.clock(), bucket))
        return req_id, bucket

    # -- flush -------------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _flush_bucket(self, bucket: int, entries: List[_Pending]
                      ) -> FlushResult:
        records = solve_fixed_batch(
            [e.A for e in entries], [e.b for e in entries],
            [e.x for e in entries], [e.action_row for e in entries],
            self.ir_cfg, self.cfg.max_batch)
        return FlushResult(bucket, [e.req_id for e in entries], records,
                           self.cfg.max_batch)

    def pump(self, force: bool = False) -> List[FlushResult]:
        """Flush every due bucket; with force=True, flush everything."""
        now = self.clock()
        out: List[FlushResult] = []
        for bucket in sorted(self._queues):
            q = self._queues[bucket]
            # Full batches always go.
            while len(q) >= self.cfg.max_batch:
                out.append(self._flush_bucket(
                    bucket, q[:self.cfg.max_batch]))
                del q[:self.cfg.max_batch]
            # Partial batch goes on deadline (or force).
            if q and (force or
                      now - q[0].enqueued_at >= self.cfg.max_wait_s):
                out.append(self._flush_bucket(bucket, q))
                q.clear()
        self._queues = {b: q for b, q in self._queues.items() if q}
        return out

    def flush_all(self) -> List[FlushResult]:
        return self.pump(force=True)
