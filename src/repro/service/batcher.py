"""Per-bucket micro-batcher for streaming solve requests, task-agnostic.

Requests are prepared (e.g. identity-padded to their size bucket) by the
task on submit and queued per bucket key. A bucket flushes when it holds
a full batch or when its oldest request has waited `max_wait_s` (partial
batch, padded to the fixed shape by the task's `solve_rows`). The flush
target is not the raw `max_batch` but the task executor's
`preferred_chunk(max_batch, bucket)` (DESIGN.md §7): a mesh-sharded
executor rounds it up to a multiple of its data-axis width, so flush
size tracks mesh width and every device carries the same number of
rows. Every flush for a given bucket therefore has an identical
compiled shape, so XLA compiles one executable per (task, bucket,
executor) per process and every subsequent flush is compile-free.

The batcher knows nothing about any solver: all shape/batch semantics
flow through the `TunableTask` hooks (`bucket_key`, `prepare`,
`solve_rows`). Passing a legacy `IRConfig` (or `CGConfig`) instead of a
task still works — `core.task.coerce_task` wraps it, honoring this
batcher's `bucket_step`/`min_bucket`; a real task uses its own bucket
configuration.

Single-threaded by design: `pump()` is driven by the server's event loop
(or a test), and the clock is injectable so flush-by-timeout is exactly
testable without sleeping.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import faults
from repro.core.executor import resolve_executor
from repro.core.task import Outcome, TunableTask, coerce_task


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 8          # rows per compiled batch (flush when full;
                                # rounded up to the executor's granularity)
    max_wait_s: float = 0.05    # oldest-request deadline for partial flush
    bucket_step: int = 128      # used when adapting a legacy solver config
    min_bucket: int = 128
    # Hard per-request deadline (None = no deadline): a request still
    # queued this long after submit is expired by `expire_overdue()`
    # instead of solved — the server answers it with a terminal FAILED
    # response (no Q-update), so a wedged or glacial bucket cannot hold
    # requests hostage (DESIGN.md §11.2).
    request_deadline_s: Optional[float] = None


@dataclasses.dataclass
class _Pending:
    req_id: int
    rows: object                # task-prepared (padded) row data
    action_row: np.ndarray
    enqueued_at: float
    bucket: int


@dataclasses.dataclass
class FlushResult:
    bucket: int
    req_ids: List[int]
    records: List[Outcome]
    n_rows: int                 # rows solved (== flush target, incl. padding)
    # Observability stamps (server clock): the tracer turns these into
    # per-request queue_wait / solve spans, and `solve_s` (real wall
    # seconds, independent of an injected test clock) feeds the
    # repro_service_solve_batch_seconds histogram.
    t_solve_start: float = 0.0
    t_solve_end: float = 0.0
    solve_s: float = 0.0


class MicroBatcher:
    def __init__(self, task: TunableTask,
                 cfg: BatcherConfig = BatcherConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.task = coerce_task(task, bucket_step=cfg.bucket_step,
                                min_bucket=cfg.min_bucket)
        # The task's executor sets the dispatch granularity; tasks
        # without one (custom TunableTasks) get the process default.
        self.executor = resolve_executor(
            getattr(self.task, "executor", None))
        self.cfg = cfg
        self.clock = clock
        self._queues: Dict[int, List[_Pending]] = {}
        self._ids = itertools.count()

    def flush_target(self, bucket: int) -> int:
        """Rows per flush for `bucket`: `max_batch` rounded up to the
        executor's dispatch granularity (mesh width)."""
        return self.executor.preferred_chunk(self.cfg.max_batch, bucket)

    # -- enqueue -----------------------------------------------------------
    def submit(self, instance, action_row: np.ndarray,
               req_id: Optional[int] = None) -> Tuple[int, int]:
        """Queue one (instance, action) solve; returns (request id,
        bucket)."""
        if req_id is None:
            req_id = next(self._ids)
        bucket = self.task.bucket_key(instance)
        rows = self.task.prepare(instance)
        self._queues.setdefault(bucket, []).append(
            _Pending(req_id, rows, np.asarray(action_row, np.int32),
                     self.clock(), bucket))
        return req_id, bucket

    # -- flush -------------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _flush_bucket(self, bucket: int, entries: List[_Pending]
                      ) -> FlushResult:
        target = self.flush_target(bucket)
        t0, w0 = self.clock(), time.perf_counter()
        # Fault site: a raise here leaves the entries queued (pump()
        # only dequeues after a successful flush), so the flush is
        # retried by the next pump — the supervised HTTP flush loop
        # counts the restart and carries on.
        faults.maybe_raise("batcher.flush", bucket=bucket,
                           n_entries=len(entries))
        records = self.task.solve_rows(
            [e.rows for e in entries], [e.action_row for e in entries],
            target)
        # Fault site: corrupt solved outcomes (NaN / divergence) after
        # the real solve — the poisoned-reward path the breaker and
        # Q-update quarantine defend against.
        records = [
            faults.corrupt_outcome("solver.outcome", rec, bucket=bucket,
                                   action_row=e.action_row)
            for e, rec in zip(entries, records)]
        return FlushResult(bucket, [e.req_id for e in entries], records,
                           target, t_solve_start=t0,
                           t_solve_end=self.clock(),
                           solve_s=time.perf_counter() - w0)

    def expire_overdue(self, now: Optional[float] = None) -> List[_Pending]:
        """Remove and return every queued entry older than
        `request_deadline_s` (no-op when the deadline is unset). The
        server turns each into a terminal FAILED response."""
        if self.cfg.request_deadline_s is None:
            return []
        now = self.clock() if now is None else now
        expired: List[_Pending] = []
        for bucket in list(self._queues):
            q = self._queues[bucket]
            keep = []
            for e in q:
                if now - e.enqueued_at >= self.cfg.request_deadline_s:
                    expired.append(e)
                else:
                    keep.append(e)
            if keep:
                self._queues[bucket] = keep
            else:
                del self._queues[bucket]
        return expired

    def pump(self, force: bool = False) -> List[FlushResult]:
        """Flush every due bucket; with force=True, flush everything."""
        now = self.clock()
        out: List[FlushResult] = []
        for bucket in sorted(self._queues):
            q = self._queues[bucket]
            target = self.flush_target(bucket)
            # Full batches always go.
            while len(q) >= target:
                out.append(self._flush_bucket(bucket, q[:target]))
                del q[:target]
            # Partial batch goes on deadline (or force).
            if q and (force or
                      now - q[0].enqueued_at >= self.cfg.max_wait_s):
                out.append(self._flush_bucket(bucket, q))
                q.clear()
        self._queues = {b: q for b, q in self._queues.items() if q}
        return out

    def flush_all(self) -> List[FlushResult]:
        return self.pump(force=True)
