"""Request/response models for the async HTTP front door.

Wire format (JSON over HTTP/1.1):

  POST /v1/solve, /v1/solve:sync  —  body::

      {"A": [[...], ...],        # (n, n) matrix, finite floats
       "b": [...],               # length-n right-hand side
       "x_true": [...],          # optional reference solution: without it
                                 # the solve still runs, but ferr-based
                                 # reward/convergence is meaningless and
                                 # the response carries has_x_true=false
       "request_id": "..."}      # optional client id, echoed back

Validation is strict and cheap (shape, finiteness, size cap) and runs
before admission control; the expensive part — the Hager–Higham
condition estimate inside `system_features` — runs on the worker thread
after the request is admitted, so an overload burst is shed before any
O(n^3) work.

Responses carry the full `SolveResponse` surface: the action (per-step
precision formats), reward, outcome metrics, the policy version that
decided, and the server-measured submit-to-response latency.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np

from repro.core.features import system_features
from repro.data.matrices import LinearSystem
from repro.service.server import SolveResponse


class ValidationError(ValueError):
    """Bad request payload; maps to HTTP 400."""

    def __init__(self, message: str):
        super().__init__(message)
        self.status = 400


def _as_float_array(obj, name: str, ndim: int) -> np.ndarray:
    try:
        arr = np.asarray(obj, dtype=np.float64)
    except (TypeError, ValueError):
        raise ValidationError(f"{name!r} must be a numeric array")
    if arr.ndim != ndim:
        raise ValidationError(f"{name!r} must be {ndim}-dimensional, "
                              f"got shape {arr.shape}")
    if arr.size == 0:
        raise ValidationError(f"{name!r} must be non-empty")
    if not np.all(np.isfinite(arr)):
        raise ValidationError(f"{name!r} must contain only finite values")
    return arr


@dataclasses.dataclass
class SolveRequest:
    """Validated solve request; `to_instance()` builds the task instance
    (features computed there — keep it off the event loop)."""

    A: np.ndarray
    b: np.ndarray
    x_true: Optional[np.ndarray]
    client_request_id: Optional[str]

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @classmethod
    def from_payload(cls, payload, max_n: int) -> "SolveRequest":
        if not isinstance(payload, dict):
            raise ValidationError("request body must be a JSON object")
        unknown = set(payload) - {"A", "b", "x_true", "request_id"}
        if unknown:
            raise ValidationError(
                f"unknown fields: {sorted(unknown)}")
        if "A" not in payload or "b" not in payload:
            raise ValidationError("fields 'A' and 'b' are required")
        A = _as_float_array(payload["A"], "A", ndim=2)
        if A.shape[0] != A.shape[1]:
            raise ValidationError(f"'A' must be square, got {A.shape}")
        n = A.shape[0]
        if n > max_n:
            raise ValidationError(f"system size {n} exceeds the "
                                  f"server limit of {max_n}")
        b = _as_float_array(payload["b"], "b", ndim=1)
        if b.shape[0] != n:
            raise ValidationError(
                f"'b' length {b.shape[0]} does not match A ({n}x{n})")
        x_true = None
        if payload.get("x_true") is not None:
            x_true = _as_float_array(payload["x_true"], "x_true", ndim=1)
            if x_true.shape[0] != n:
                raise ValidationError(
                    f"'x_true' length {x_true.shape[0]} does not match "
                    f"A ({n}x{n})")
        cid = payload.get("request_id")
        if cid is not None and not isinstance(cid, str):
            raise ValidationError("'request_id' must be a string")
        if cid is not None and len(cid) > 256:
            raise ValidationError("'request_id' exceeds 256 characters")
        return cls(A=A, b=b, x_true=x_true, client_request_id=cid)

    def to_instance(self) -> LinearSystem:
        """Build the `LinearSystem` the task consumes. O(n^3): the
        Hager–Higham condest LU-factorizes A."""
        feats = system_features(self.A)
        x = self.x_true if self.x_true is not None \
            else np.zeros(self.n, dtype=np.float64)
        return LinearSystem(self.A, self.b, x, feats["kappa_est"],
                            feats, "dense")


def accepted_payload(req_id: int, bucket: int,
                     client_id: Optional[str]) -> dict:
    out = {"request_id": req_id, "bucket": bucket, "status": "queued"}
    if client_id is not None:
        out["client_request_id"] = client_id
    return out


def result_payload(resp: SolveResponse, client_id: Optional[str] = None,
                   has_x_true: bool = True) -> dict:
    """JSON-ready view of a completed `SolveResponse`."""
    rec = resp.record
    out = {
        "request_id": resp.request_id,
        # "expired" marks a request whose batcher deadline passed before
        # a solve ran (terminal: the outcome is a synthetic FAILED).
        "status": "expired" if resp.expired else "done",
        "bucket": int(resp.bucket),
        "action": int(resp.action),
        "action_names": list(resp.action_names),
        "reward": float(resp.reward),
        "state": int(resp.state),
        "eps": float(resp.eps),
        "policy_version": resp.policy_version,
        "latency_s": float(resp.latency_s),
        "drift": bool(resp.drift),
        "has_x_true": bool(has_x_true),
        "outcome": {"status": int(rec.status),
                    "cost": float(rec.cost),
                    **{k: (float(v) if np.isscalar(v) else v)
                       for k, v in rec.metrics.items()}},
    }
    if client_id is not None:
        out["client_request_id"] = client_id
    return out


# ---------------------------------------------------------------------------
# Client-side backoff (the polite half of the 429 + Retry-After contract)
# ---------------------------------------------------------------------------

def parse_retry_after(value) -> Optional[float]:
    """Seconds from a ``Retry-After`` header value (delta-seconds form
    only — the HTTP-date form is not worth a date parser here); None
    when absent/unparseable."""
    if value is None:
        return None
    try:
        return max(float(str(value).strip()), 0.0)
    except ValueError:
        return None


def retry_delay(attempt: int, retry_after=None, *, base_s: float = 0.1,
                cap_s: float = 30.0, jitter: float = 0.5,
                rng=None) -> float:
    """Jittered exponential backoff honoring ``Retry-After`` as a floor.

    ``base_s * 2**attempt`` capped at ``cap_s``, stretched by a uniform
    factor in ``[1, 1 + jitter]`` (simultaneous client retries are the
    thundering herd the jitter breaks), and never below what the server
    asked for via ``Retry-After`` (raw header values are accepted —
    `parse_retry_after` is applied). ``rng`` is any object with
    ``random()`` (e.g. ``random.Random(seed)``) for deterministic
    tests; default is the module-level `random`.
    """
    if rng is None:
        rng = random
    delay = min(float(base_s) * (2.0 ** max(int(attempt), 0)),
                float(cap_s))
    delay *= 1.0 + max(float(jitter), 0.0) * rng.random()
    floor = retry_after if isinstance(retry_after, (int, float)) \
        else parse_retry_after(retry_after)
    if floor is not None:
        delay = max(delay, float(floor))
    return delay
