"""Async HTTP front door for the autotuning service (DESIGN.md §9).

Stdlib-only asyncio subsystem: request/response models with validation
(`models`), and the front door itself (`app`) — bounded per-bucket
admission with 429 backpressure, a background flush loop replacing
caller-driven `step()`, graceful drain on shutdown, and a sync facade
(`serve_http`) that runs the event loop on a daemon thread.
"""
from repro.service.http.app import HttpConfig, HttpFrontDoor, serve_http
from repro.service.http.models import (SolveRequest, ValidationError,
                                       parse_retry_after, result_payload,
                                       retry_delay)

__all__ = [
    "HttpConfig", "HttpFrontDoor", "SolveRequest", "ValidationError",
    "parse_retry_after", "result_payload", "retry_delay", "serve_http",
]
