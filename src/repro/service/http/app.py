"""Async HTTP front door over `AutotuneServer` / `ShadowServer`.

Stdlib-only (asyncio + a hand-rolled HTTP/1.1 exchange, like `obs/`
uses http.server): the request path of the production front door
(DESIGN.md §9). Endpoints:

  * ``POST /v1/solve``       validate → admit → 202 with the request id
    (fire-and-poll); the client's optional ``request_id`` is echoed.
  * ``GET  /v1/result/<id>`` 200 + full result exactly once (retrieval
    evicts), 202 while pending, 404 for unknown/already-claimed ids.
  * ``POST /v1/solve:sync``  admit, then await completion inline; 504
    on timeout (the result stays retrievable via ``/v1/result``).
  * ``GET  /v1/policy``      registry versions/current/history, the live
    policy version, and rollout-controller state when fronting a
    `ShadowServer`.

Concurrency model — three rules, no locks:

  1. The serving stack stays single-threaded by design: every
     `submit()`/`step()`/`drain()` call runs on ONE worker thread (a
     single-slot ThreadPoolExecutor). The front door forces
     ``server.auto_step = False`` and replaces caller-driven stepping
     with a background flush loop that pumps the micro-batcher on that
     worker.
  2. All admission/bookkeeping state (per-bucket depth, pending map,
     done store) lives on the event loop thread; completions cross back
     via ``loop.call_soon_threadsafe``.
  3. Backpressure is explicit: a request whose size bucket already has
     ``max_queue_depth`` admitted-but-unanswered requests is refused
     with 429 + ``Retry-After`` *before* any O(n^3) feature work, so an
     overload burst costs validation only. Shutdown drains: the
     listener closes first, admitted requests are force-flushed and
     answered, then the loop stops.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

import numpy as np

from repro import faults
from repro.core.task import bucket_of
from repro.service.http.models import (SolveRequest, ValidationError,
                                       accepted_payload, result_payload)

_SERVER_NAME = "repro-autotune"


@dataclasses.dataclass(frozen=True)
class HttpConfig:
    max_queue_depth: int = 64     # per-bucket admitted-but-unanswered cap
    retry_after_s: float = 1.0    # advertised backoff on 429
    flush_interval_s: float = 0.005   # background flush-loop tick
    sync_timeout_s: float = 30.0  # /v1/solve:sync wait bound
    max_body_bytes: int = 64 << 20
    max_n: int = 2048             # request validation size cap
    drain_timeout_s: float = 10.0
    conn_idle_s: float = 30.0     # keep-alive idle timeout
    max_done: int = 4096          # unclaimed-result retention (front door)


@dataclasses.dataclass
class _PendingEntry:
    bucket: int
    client_id: Optional[str]
    has_x_true: bool
    future: Optional[asyncio.Future] = None   # set for /v1/solve:sync


def _json_default(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class HttpFrontDoor:
    """Async HTTP API over one server (AutotuneServer or ShadowServer)."""

    def __init__(self, server, host: str = "127.0.0.1", port: int = 0,
                 cfg: HttpConfig = HttpConfig()):
        self.server = server
        self.cfg = cfg
        self._req_host, self._req_port = host, port
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        # Rule 1: one worker thread owns every server call.
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-http-worker")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._asyncio_server: Optional[asyncio.AbstractServer] = None
        self._flush_task: Optional[asyncio.Task] = None
        self._draining = False
        self._depth: Dict[int, int] = {}
        self._pending: Dict[int, _PendingEntry] = {}
        self._early: Dict[int, object] = {}       # completed pre-register
        self._done: "OrderedDict[int, dict]" = OrderedDict()
        self.results_evicted = 0
        self.flush_restarts = 0
        server.auto_step = False    # the flush loop is the only pump
        server.on_response = self._on_response_worker

    # -- lifecycle (async API) ----------------------------------------------
    async def astart(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._asyncio_server = await asyncio.start_server(
            self._handle_conn, self._req_host, self._req_port)
        sock = self._asyncio_server.sockets[0]
        self.host, self.port = sock.getsockname()[:2]
        self._flush_task = asyncio.ensure_future(self._flush_loop())

    async def aclose(self) -> None:
        """Graceful drain: stop accepting, flush and answer everything
        admitted; whatever is still unanswered at ``drain_timeout_s``
        gets a *terminal failure* response (sync callers see it
        immediately, fire-and-poll callers via GET /v1/result) — no
        request is left hanging forever."""
        self._draining = True
        if self._asyncio_server is not None:
            self._asyncio_server.close()
            await self._asyncio_server.wait_closed()
        deadline = self._loop.time() + self.cfg.drain_timeout_s
        while self._pending and self._loop.time() < deadline:
            try:
                await self._loop.run_in_executor(self._exec,
                                                 self.server.drain)
            except Exception:
                self._count_error()
            await asyncio.sleep(0.005)
        if self._flush_task is not None:
            self._flush_task.cancel()
            try:
                await self._flush_task
            except asyncio.CancelledError:
                pass
        for rid, entry in list(self._pending.items()):
            self._fail_pending(rid, entry,
                               "server shut down before this request "
                               "was solved")
        self._exec.shutdown(wait=False)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # -- lifecycle (sync facade, mirrors ObsHTTPServer ergonomics) ----------
    def start(self) -> "HttpFrontDoor":
        loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=loop.run_forever,
                                        name="repro-http", daemon=True)
        self._loop = loop
        self._thread.start()
        asyncio.run_coroutine_threadsafe(self.astart(), loop).result(30)
        return self

    def close(self) -> None:
        if self._loop is None or self._thread is None:
            return
        asyncio.run_coroutine_threadsafe(self.aclose(), self._loop).result(
            self.cfg.drain_timeout_s + 30)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=10)
        self._loop.close()
        self._loop = self._thread = None

    # -- completion path -----------------------------------------------------
    def _on_response_worker(self, resp) -> None:
        """Runs on the worker thread inside step(); claims the response
        off the server's retention store and hands it to the loop."""
        if resp.request_id < 0:
            return                   # shadow arm, never client-visible
        self.server.poll(resp.request_id)
        try:
            self._loop.call_soon_threadsafe(self._deliver, resp)
        except RuntimeError:
            pass                     # loop already closed (shutdown race)

    def _deliver(self, resp) -> None:
        rid = resp.request_id
        entry = self._pending.get(rid)
        if entry is None:
            # Completed before the submitting coroutine registered it;
            # finish when registration happens.
            self._early[rid] = resp
            return
        self._finish(rid, entry, resp)

    def _finish(self, rid: int, entry: _PendingEntry, resp) -> None:
        del self._pending[rid]
        self._depth[entry.bucket] = \
            max(self._depth.get(entry.bucket, 1) - 1, 0)
        payload = result_payload(resp, client_id=entry.client_id,
                                 has_x_true=entry.has_x_true)
        if entry.future is not None and not entry.future.done():
            entry.future.set_result(payload)
            return
        self._done[rid] = payload
        while len(self._done) > self.cfg.max_done:
            self._done.popitem(last=False)
            self.results_evicted += 1

    def _fail_pending(self, rid: int, entry: _PendingEntry,
                      reason: str) -> None:
        """Answer one admitted-but-unsolved request with a terminal
        failure payload (drain deadline expiry)."""
        del self._pending[rid]
        self._depth[entry.bucket] = \
            max(self._depth.get(entry.bucket, 1) - 1, 0)
        payload = {"request_id": rid, "status": "failed", "error": reason}
        if entry.client_id is not None:
            payload["client_request_id"] = entry.client_id
        if entry.future is not None and not entry.future.done():
            entry.future.set_result(payload)
            return
        self._done[rid] = payload
        while len(self._done) > self.cfg.max_done:
            self._done.popitem(last=False)
            self.results_evicted += 1

    def _register(self, rid: int, entry: _PendingEntry) -> None:
        self._pending[rid] = entry
        resp = self._early.pop(rid, None)
        if resp is not None:
            self._finish(rid, entry, resp)

    # -- flush loop ----------------------------------------------------------
    async def _flush_loop(self) -> None:
        """Supervisor: restart the pump whenever it crashes
        (DESIGN.md §11). A fault inside step() — an injected
        ``batcher.flush`` raise, a transient solver error — kills one
        pump iteration, not the front door: the batcher only dequeues
        entries after a successful flush, so the restarted pump retries
        them. Restarts are counted in
        ``repro_http_flush_restarts_total``."""
        while True:
            try:
                await self._flush_loop_inner()
            except asyncio.CancelledError:
                raise
            except Exception:
                self._count_error()
                self._count_flush_restart()
                if self._draining:
                    return
                await asyncio.sleep(self.cfg.flush_interval_s)

    async def _flush_loop_inner(self) -> None:
        while True:
            if self.server.pending:
                await self._loop.run_in_executor(
                    self._exec, self.server.step)
            await asyncio.sleep(self.cfg.flush_interval_s)

    # -- HTTP plumbing ---------------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    head = await asyncio.wait_for(
                        reader.readuntil(b"\r\n\r\n"),
                        timeout=self.cfg.conn_idle_s)
                except (asyncio.IncompleteReadError, asyncio.TimeoutError,
                        ConnectionResetError):
                    return
                except asyncio.LimitOverrunError:
                    await self._send(writer, 431,
                                     {"error": "headers too large"})
                    return
                try:
                    method, path, headers = self._parse_head(head)
                except ValueError:
                    await self._send(writer, 400,
                                     {"error": "malformed request"})
                    return
                clen = int(headers.get("content-length", "0") or "0")
                if clen > self.cfg.max_body_bytes:
                    await self._send(writer, 413,
                                     {"error": "body too large"})
                    return
                body = await reader.readexactly(clen) if clen else b""
                code, payload, extra = await self._dispatch(method, path,
                                                            body)
                keep = (headers.get("connection", "keep-alive").lower()
                        != "close")
                await self._send(writer, code, payload, extra,
                                 keep_alive=keep)
                if not keep:
                    return
        except (ConnectionResetError, BrokenPipeError):
            pass
        except Exception:
            self._count_error()
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    @staticmethod
    def _parse_head(head: bytes):
        lines = head.decode("latin-1").split("\r\n")
        method, path, proto = lines[0].split(" ", 2)
        if not proto.startswith("HTTP/1."):
            raise ValueError(proto)
        headers = {}
        for ln in lines[1:]:
            if not ln:
                continue
            k, _, v = ln.partition(":")
            headers[k.strip().lower()] = v.strip()
        return method.upper(), path.split("?", 1)[0], headers

    async def _send(self, writer, code: int, payload: dict,
                    extra_headers=(), keep_alive: bool = False) -> None:
        reasons = {200: "OK", 202: "Accepted", 400: "Bad Request",
                   404: "Not Found", 405: "Method Not Allowed",
                   413: "Payload Too Large", 429: "Too Many Requests",
                   431: "Request Header Fields Too Large",
                   500: "Internal Server Error",
                   503: "Service Unavailable", 504: "Gateway Timeout"}
        body = json.dumps(payload, default=_json_default).encode("utf-8")
        lines = [f"HTTP/1.1 {code} {reasons.get(code, 'Unknown')}",
                 f"Server: {_SERVER_NAME}",
                 "Content-Type: application/json",
                 f"Content-Length: {len(body)}",
                 "Connection: " + ("keep-alive" if keep_alive
                                   else "close")]
        lines += [f"{k}: {v}" for k, v in extra_headers]
        writer.write(("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")
                     + body)
        await writer.drain()

    # -- routing ---------------------------------------------------------------
    async def _dispatch(self, method: str, path: str, body: bytes):
        try:
            # Fault site: an injected raise here surfaces as a clean
            # 500 (below) and an injected delay as a slow response —
            # the chaos suite drives client-visible failure modes
            # through the same handler the real ones would take.
            faults.maybe_raise("http.request", method=method, path=path)
            if path in ("/v1/solve", "/v1/solve:sync"):
                if method != "POST":
                    return 405, {"error": "POST required"}, ()
                return await self._solve(body, sync=path.endswith(":sync"))
            if path.startswith("/v1/result/"):
                if method != "GET":
                    return 405, {"error": "GET required"}, ()
                return self._result(path[len("/v1/result/"):])
            if path == "/v1/policy":
                if method != "GET":
                    return 405, {"error": "GET required"}, ()
                return self._policy()
            return 404, {"error": "not found", "path": path}, ()
        except ValidationError as e:
            self._count_request(path, 400)
            return 400, {"error": str(e)}, ()
        except Exception:
            self._count_error()
            self._count_request(path, 500)
            return 500, {"error": "internal error"}, ()

    async def _solve(self, body: bytes, sync: bool):
        route = "/v1/solve:sync" if sync else "/v1/solve"
        if self._draining:
            self._count_request(route, 503)
            return 503, {"error": "server is draining"}, ()
        try:
            payload = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            raise ValidationError("body must be valid JSON")
        sreq = SolveRequest.from_payload(payload, max_n=self.cfg.max_n)
        bucket = self._bucket_for(sreq.n)
        # Rule 3: shed load before the O(n^3) feature work.
        if self._depth.get(bucket, 0) >= self.cfg.max_queue_depth:
            self._count_request(route, 429)
            retry = max(1, int(-(-self.cfg.retry_after_s // 1)))
            return (429,
                    {"error": "bucket queue full", "bucket": bucket,
                     "retry_after_s": self.cfg.retry_after_s},
                    (("Retry-After", str(retry)),))
        self._depth[bucket] = self._depth.get(bucket, 0) + 1
        try:
            rid = await self._loop.run_in_executor(
                self._exec, self._build_and_submit, sreq)
        except BaseException:
            self._depth[bucket] = max(self._depth.get(bucket, 1) - 1, 0)
            raise
        entry = _PendingEntry(bucket=bucket,
                              client_id=sreq.client_request_id,
                              has_x_true=sreq.x_true is not None)
        extra = ()
        if sreq.client_request_id is not None:
            extra = (("X-Request-Id", sreq.client_request_id),)
        if not sync:
            self._register(rid, entry)
            self._count_request(route, 202)
            return (202, accepted_payload(rid, bucket,
                                          sreq.client_request_id), extra)
        entry.future = self._loop.create_future()
        self._register(rid, entry)
        try:
            result = await asyncio.wait_for(entry.future,
                                            self.cfg.sync_timeout_s)
        except asyncio.TimeoutError:
            # Detach: the result lands in the done-store when it arrives
            # and stays retrievable via GET /v1/result/<id>.
            entry.future = None
            self._count_request(route, 504)
            return (504, {"error": "solve timed out", "request_id": rid,
                          "status": "pending"}, extra)
        if result.get("status") == "failed":
            # Terminal failure from the drain deadline: the request was
            # admitted but the server shut down before solving it.
            self._count_request(route, 503)
            return 503, result, extra
        self._count_request(route, 200)
        return 200, result, extra

    def _build_and_submit(self, sreq: SolveRequest) -> int:
        return self.server.submit(sreq.to_instance())

    def _result(self, raw_id: str):
        route = "/v1/result"
        try:
            rid = int(raw_id)
        except ValueError:
            self._count_request(route, 400)
            return 400, {"error": f"bad request id {raw_id!r}"}, ()
        payload = self._done.pop(rid, None)
        if payload is not None:
            self._count_request(route, 200)
            return 200, payload, ()
        if rid in self._pending:
            self._count_request(route, 202)
            return 202, {"request_id": rid, "status": "pending"}, ()
        self._count_request(route, 404)
        return 404, {"error": "unknown or already-claimed request id",
                     "request_id": rid}, ()

    def _policy(self):
        reg = getattr(self.server, "registry", None)
        out = {"policy_version": self.server.policy_version,
               "current": reg.current_version() if reg else None,
               "versions": reg.versions() if reg else [],
               "history": reg.history() if reg else []}
        state_fn = getattr(self.server, "rollout_state", None)
        if state_fn is not None:
            out["rollout"] = state_fn()
        self._count_request("/v1/policy", 200)
        return 200, out, ()

    # -- helpers ----------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        task = self.server.task
        step = getattr(task, "bucket_step", 128)
        minimum = getattr(task, "min_bucket", step)
        return bucket_of(n, step, minimum)

    def queue_depth(self, bucket: int) -> int:
        return self._depth.get(bucket, 0)

    def _registry(self):
        obs = getattr(self.server, "obs", None)
        if obs is not None:
            return obs.registry
        from repro.obs.metrics import default_registry
        return default_registry()

    def _count_request(self, route: str, code: int) -> None:
        try:
            self._registry().counter(
                "repro_http_requests_total",
                "HTTP front-door requests, by route and status code.",
                ("route", "code")).labels(route=route,
                                          code=str(code)).inc()
        except Exception:
            pass

    def _count_error(self) -> None:
        try:
            self._registry().count_error()
        except Exception:
            pass

    def _count_flush_restart(self) -> None:
        self.flush_restarts += 1
        try:
            self._registry().counter(
                "repro_http_flush_restarts_total",
                "Background flush-loop crashes survived by the "
                "supervisor (the pump was restarted).").inc()
        except Exception:
            pass


def serve_http(server, host: str = "127.0.0.1", port: int = 0,
               cfg: HttpConfig = HttpConfig()) -> HttpFrontDoor:
    """Start the front door on a background event-loop thread; returns
    the running `HttpFrontDoor` (read ``.url``, call ``.close()``)."""
    return HttpFrontDoor(server, host=host, port=port, cfg=cfg).start()
