"""Shadow/canary policy rollout: automated promote/rollback rails.

`ShadowServer` fronts two `AutotuneServer`s — the *primary* serving the
promoted snapshot and, while a rollout is in flight, a *candidate*
serving a challenger snapshot. Traffic is split deterministically:

  * a configurable **canary slice** (``canary_frac``) is answered by the
    candidate (client-visible — its responses carry the candidate's
    ``policy_version``);
  * every primary-slice request is optionally **mirrored** into the
    candidate as shadow evaluation: the candidate solves and learns from
    it, but the shadow response is discarded and never answers a client.

Promotion is staged through the registry: `start_rollout` promotes the
candidate version immediately (CURRENT flips — which is exactly what
makes `PolicyRegistry.rollback()` the degradation path), while the
primary keeps serving the prior snapshot to the non-canary slice. Every
``decision_window`` candidate responses the gate runs against hard
floors whose baselines come from the *baseline snapshot's meta*
(embedded there by ``AutotuneServer.snapshot()``; live primary
telemetry is the fallback for warm-start versions without evidence):

  * minimum candidate sample count (hold until reached);
  * candidate reward EWMA within ``reward_margin`` of the baseline's;
  * ferr/nbe pass rate (fraction of CONVERGED outcomes) above
    ``pass_rate_floor`` (and within ``pass_rate_margin`` of baseline);
  * per-bucket p99 latency within ``p99_bound`` × the baseline's.

Any gate failure rolls back immediately (`registry.rollback()` restores
the prior version, the candidate is drained and retired); a sustained
pass over ``promote_windows`` consecutive windows confirms the
promotion and the candidate takes all traffic. Every decision is
counted in ``repro_rollout_decisions_total{outcome}`` and appended to a
decision-trail JSONL when ``decision_log_path`` is set.

With ``ope_gate=True`` a candidate must additionally clear an
*off-policy* gate before `start_rollout` admits it at all (DESIGN.md
§10.3): its doubly-robust reward estimate over the logged trajectory
stream (`eval.ope`, propensities reconstructed from the logged
epsilon/explore fields) must have a lower confidence bound no worse
than the incumbent's estimate minus ``ope_margin``. A refused
candidate never takes a canary slice: `start_rollout` raises
`OPEGateRejected`, the refusal is appended to the decision trail and
counted as ``outcome="ope_reject"``, and the verdict (estimates, CIs)
is annotated into the candidate version's registry meta.

Single-threaded like everything in `service/`: routing, gating, and
promotion all run on the caller's thread (the HTTP front door serializes
through its worker).
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.rewards import RewardConfig
from repro.obs import Observability, TrajectoryLog
from repro.service.batcher import BatcherConfig
from repro.service.instrument import RolloutInstruments
from repro.service.online import OnlineConfig
from repro.service.registry import PolicyRegistry
from repro.service.server import AutotuneServer, SolveResponse


@dataclasses.dataclass(frozen=True)
class RolloutConfig:
    canary_frac: float = 0.25     # client traffic slice answered by the
                                  # candidate
    shadow: bool = True           # mirror primary-slice traffic into the
                                  # candidate (evaluation only)
    decision_window: int = 32     # candidate responses between gate runs
    min_samples: int = 16         # hard floor: hold until this many
    promote_windows: int = 2      # consecutive passing windows to confirm
    reward_margin: float = 0.5    # candidate reward EWMA may trail the
                                  # baseline by at most this
    pass_rate_floor: float = 0.75  # absolute ferr/nbe pass-rate floor
    pass_rate_margin: float = 0.25  # allowed pass-rate drop vs baseline
    p99_bound: float = 3.0        # per-bucket p99 <= bound * baseline p99
    min_bucket_samples: int = 8   # p99 compared only for buckets with
                                  # this many candidate samples
    seed: int = 0                 # routing rng (deterministic slices)
    # -- off-policy evaluation gate (eval.ope, DESIGN.md §10.3) --------
    ope_gate: bool = False        # score candidates on the trajectory
                                  # log before any canary traffic
    ope_margin: float = 0.5       # candidate DR LCB must reach
                                  # incumbent DR estimate - margin
    ope_min_records: int = 64     # below this many logged records the
                                  # gate abstains (canary gates rule)
    ope_bootstrap: int = 200      # bootstrap resamples for the CI
    ope_ci: float = 0.90          # two-sided CI coverage
    ope_weight_clip: float = 100.0  # IPS/DR importance-weight cap


@dataclasses.dataclass
class RolloutDecision:
    outcome: str                  # "hold" | "promote" | "rollback"
                                  # | "ope_accept" | "ope_reject"
    responses: int                # candidate responses at decision time
    windows_passed: int
    failures: List[str]
    evidence: Dict[str, object]
    candidate_version: str
    baseline_version: Optional[str]


class OPEGateRejected(RuntimeError):
    """Candidate refused a canary slice by the off-policy gate.

    Carries the full `OPEGateReport` so callers (and the HTTP front
    door's error payloads) can show the numbers the refusal rests on."""

    def __init__(self, report):
        self.report = report
        lcb = (report.candidate["dr"].ci_lo
               if report.candidate else None)
        super().__init__(
            f"candidate refused by OPE gate ({report.reason}): "
            f"DR lower confidence bound {lcb} < floor {report.floor}")


class ShadowServer:
    """Canary router + rollout controller over two `AutotuneServer`s."""

    def __init__(self,
                 registry: PolicyRegistry,
                 task=None,
                 reward_cfg: RewardConfig = RewardConfig(),
                 batcher_cfg: BatcherConfig = BatcherConfig(),
                 online_cfg: OnlineConfig = OnlineConfig(),
                 rollout_cfg: RolloutConfig = RolloutConfig(),
                 clock: Callable[[], float] = _time.monotonic,
                 seed: int = 0,
                 executor=None,
                 obs=None,
                 decision_log_path: Optional[str] = None,
                 warmup: Optional[str] = None,
                 warmup_buckets: Optional[List[int]] = None,
                 compile_cache_dir: Optional[str] = None):
        self.registry = registry
        self.rollout_cfg = rollout_cfg
        self.clock = clock
        self.seed = seed
        self._task_arg = task
        self._reward_cfg = reward_cfg
        self._batcher_cfg = batcher_cfg
        self._online_cfg = online_cfg
        self._executor = executor
        # AOT warmup / compile-cache wiring (DESIGN.md §12) applies to
        # the primary only: candidate servers are built in the same
        # process later, when the executable grid is already warm —
        # the per-shape caches in `core.executor` are process-wide.
        self.primary = AutotuneServer(
            registry, task=task, reward_cfg=reward_cfg,
            batcher_cfg=batcher_cfg, online_cfg=online_cfg, clock=clock,
            seed=seed, executor=executor, obs=obs, warmup=warmup,
            warmup_buckets=warmup_buckets,
            compile_cache_dir=compile_cache_dir)
        self.candidate: Optional[AutotuneServer] = None
        self.phase = "idle"       # idle|canary|promoted|rolled_back
        self.candidate_version: Optional[str] = None
        self.baseline_version: Optional[str] = None
        self.windows_passed = 0
        self.decisions: List[RolloutDecision] = []
        self._decision_counts: Dict[str, int] = {}
        self._baseline_tel: Optional[dict] = None
        self._route_rng = np.random.default_rng(rollout_cfg.seed)
        self._ids = 0             # client-visible ids (>= 0)
        self._shadow_ids = -1     # mirrored ids (< 0, never client-visible)
        self._owner: Dict[int, AutotuneServer] = {}
        self._last_window_at = 0  # candidate responses at last gate run
        self._decision_due = False
        self._instr = (RolloutInstruments(
            self.primary.obs, getattr(self.primary.task, "name", "unknown"))
            if self.primary.obs is not None else None)
        self._decision_log = (TrajectoryLog(decision_log_path)
                              if decision_log_path else None)
        # Push-style subscriber for client-visible responses (primary +
        # canary slices, never shadow), mirroring AutotuneServer.
        self.on_response: Optional[Callable[[SolveResponse], None]] = None
        self.primary.on_response = self._on_primary_response

    # -- delegation ---------------------------------------------------------
    @property
    def task(self):
        return self.primary.task

    @property
    def obs(self):
        return self.primary.obs

    @property
    def telemetry(self):
        return self.primary.telemetry

    @property
    def policy_version(self) -> str:
        return self.primary.policy_version

    @property
    def pending(self) -> int:
        n = self.primary.pending
        if self.candidate is not None:
            n += self.candidate.pending
        return n

    @property
    def ready(self) -> bool:
        return self.primary.ready

    @property
    def breakers(self):
        return self.primary.breakers

    @property
    def last_recovery(self):
        return self.primary.last_recovery

    def degradation_state(self) -> dict:
        return self.primary.degradation_state()

    @property
    def auto_step(self) -> bool:
        return self.primary.auto_step

    @auto_step.setter
    def auto_step(self, value: bool) -> None:
        self.primary.auto_step = value
        if self.candidate is not None:
            self.candidate.auto_step = value

    # -- rollout lifecycle --------------------------------------------------
    def start_rollout(self, version: str,
                      trajectories: Optional[List[dict]] = None) -> None:
        """Promote `version` as the canary candidate and start routing a
        traffic slice to it; the prior CURRENT becomes the rollback
        target and its snapshot meta the gate baseline.

        With ``rollout_cfg.ope_gate`` the candidate is first scored
        off-policy against the incumbent on `trajectories` (default:
        this server's own trajectory log) and refused — no promotion,
        no canary traffic — with `OPEGateRejected` if its DR lower
        confidence bound misses the floor (DESIGN.md §10.3)."""
        if self.phase == "canary":
            raise RuntimeError("a rollout is already in flight")
        baseline = self.registry.current_version()
        if self.rollout_cfg.ope_gate:
            self._run_ope_gate(version, baseline, trajectories)
        policy = self.registry.load(version)
        self.registry.promote(version)      # rollback() now restores prior
        cand = AutotuneServer(
            policy, task=self._task_arg, reward_cfg=self._reward_cfg,
            batcher_cfg=self._batcher_cfg, online_cfg=self._online_cfg,
            clock=self.clock, seed=self.seed + 1, executor=self._executor,
            obs=False)
        cand.registry = self.registry
        cand.policy_version = version
        cand.auto_step = self.primary.auto_step
        cand.on_response = self._on_candidate_response
        self.candidate = cand
        self.candidate_version = version
        self.baseline_version = baseline
        self._baseline_tel = None
        if baseline is not None:
            try:
                self._baseline_tel = self.registry.meta(baseline).get(
                    "telemetry")
            except (OSError, ValueError, KeyError):
                self._baseline_tel = None
        self.phase = "canary"
        self.windows_passed = 0
        self._last_window_at = 0
        if self._instr is not None:
            self._instr.on_state(True, 0, 0)
        self._log_event({"event": "start", "candidate": version,
                         "baseline": baseline,
                         "canary_frac": self.rollout_cfg.canary_frac,
                         "shadow": self.rollout_cfg.shadow})

    # -- off-policy gate ----------------------------------------------------
    def _logged_trajectories(self) -> List[dict]:
        """Complete OPE-schema records from the primary's own trajectory
        log (all live segments). Empty when the server runs without a
        trajectory log — the gate then abstains via its
        insufficient-records rule."""
        obs = self.primary.obs
        if obs is None or obs.trajlog is None:
            return []
        try:
            return TrajectoryLog.read_complete(
                obs.trajlog.path,
                task=getattr(self.primary.task, "name", None))
        except OSError:
            return []

    def _run_ope_gate(self, version: str, baseline: Optional[str],
                      trajectories: Optional[List[dict]]) -> None:
        """Score the candidate off-policy and raise `OPEGateRejected`
        on refusal. Runs before `registry.promote`, so a refused
        candidate never becomes CURRENT and never sees traffic."""
        from repro.eval.ope import OPEConfig, SnapshotCandidate, ope_gate
        cfg = self.rollout_cfg
        records = (list(trajectories) if trajectories is not None
                   else self._logged_trajectories())
        cand = SnapshotCandidate.from_registry(self.registry, version)
        inc = (SnapshotCandidate.from_registry(self.registry, baseline)
               if baseline is not None else None)
        report = ope_gate(
            records, inc, cand, n_actions=cand.n_actions,
            margin=cfg.ope_margin, min_records=cfg.ope_min_records,
            cfg=OPEConfig(n_bootstrap=cfg.ope_bootstrap, ci=cfg.ope_ci,
                          seed=cfg.seed, weight_clip=cfg.ope_weight_clip))
        outcome = "ope_accept" if report.accept else "ope_reject"
        event = report.to_event()
        decision = RolloutDecision(
            outcome=outcome, responses=0, windows_passed=0,
            failures=([] if report.accept else [report.reason]),
            evidence=event, candidate_version=version,
            baseline_version=baseline)
        self.decisions.append(decision)
        self._decision_counts[outcome] = \
            self._decision_counts.get(outcome, 0) + 1
        if self._instr is not None:
            self._instr.on_decision(outcome)
        self._log_event({"event": "ope_gate", "outcome": outcome,
                         "candidate": version, "baseline": baseline,
                         "reason": report.reason, "gate": event})
        try:                        # audit trail in the version's meta
            self.registry.annotate(version, "ope_gate", event)
        except Exception:
            pass                    # fail-open: evidence, not control flow
        if not report.accept:
            raise OPEGateRejected(report)

    # -- request path -------------------------------------------------------
    def submit(self, instance) -> int:
        rid = self._ids
        self._ids += 1
        cfg = self.rollout_cfg
        canary = (self.phase == "canary"
                  and float(self._route_rng.random()) < cfg.canary_frac)
        if canary:
            self._owner[rid] = self.candidate
            self.candidate.submit(instance, req_id=rid)
            if self._instr is not None:
                self._instr.on_route("candidate")
        else:
            self._owner[rid] = self.primary
            self.primary.submit(instance, req_id=rid)
            if self._instr is not None:
                self._instr.on_route("primary")
            if self.phase == "canary" and cfg.shadow:
                sid = self._shadow_ids
                self._shadow_ids -= 1
                self.candidate.submit(instance, req_id=sid)
                if self._instr is not None:
                    self._instr.on_route("shadow")
        self._maybe_decide()
        return rid

    def step(self, force: bool = False) -> List[SolveResponse]:
        done = self.primary.step(force=force)
        if self.candidate is not None:
            done += [r for r in self.candidate.step(force=force)
                     if r.request_id >= 0]
        self._maybe_decide()
        return done

    def drain(self) -> List[SolveResponse]:
        return self.step(force=True)

    def poll(self, req_id: int) -> Optional[SolveResponse]:
        server = self._owner.get(req_id)
        if server is None:
            return None
        resp = server.poll(req_id)
        if resp is not None:
            del self._owner[req_id]
        return resp

    # -- completion hooks ---------------------------------------------------
    def _on_primary_response(self, resp: SolveResponse) -> None:
        if resp.request_id < 0:             # defensively drop shadow ids
            self.primary.poll(resp.request_id)
            return
        if self.on_response is not None:
            self.on_response(resp)

    def _on_candidate_response(self, resp: SolveResponse) -> None:
        cand = self.candidate
        if resp.request_id < 0:
            if cand is not None:
                cand.poll(resp.request_id)  # discard: shadow, never answered
        elif self.on_response is not None:
            self.on_response(resp)
        if (self.phase == "canary" and cand is not None
                and cand.telemetry.responses - self._last_window_at
                >= self.rollout_cfg.decision_window):
            self._decision_due = True
        if self._instr is not None and cand is not None:
            self._instr.on_state(self.phase == "canary",
                                 self.windows_passed,
                                 cand.telemetry.responses)

    # -- gating -------------------------------------------------------------
    def _maybe_decide(self) -> Optional[RolloutDecision]:
        """Run the gate if a decision window elapsed. Deferred out of the
        completion hook so promote/rollback never tear a server down
        mid-`step()`."""
        if not self._decision_due or self.phase != "canary":
            self._decision_due = False
            return None
        self._decision_due = False
        self._last_window_at = self.candidate.telemetry.responses
        decision = self._evaluate_gates()
        self._record(decision)
        if decision.outcome == "rollback":
            self._rollback()
        elif decision.outcome == "promote":
            self._promote()
        return decision

    def _evaluate_gates(self) -> RolloutDecision:
        cfg = self.rollout_cfg
        tel = self.candidate.telemetry
        n = tel.responses
        failures: List[str] = []
        evidence: Dict[str, object] = {"responses": n}
        base = self._baseline_tel or {}
        if not base and self.primary.telemetry.responses:
            # Warm-start versions carry no telemetry evidence; fall back
            # to the live primary arm observed on the same stream.
            ptel = self.primary.telemetry
            base = {"reward_ewma": ptel.reward_ewma.value,
                    "converged_frac": ptel.converged_frac,
                    "latency_s_per_bucket":
                        {str(b): p for b, p in
                         ptel.latency_percentiles_per_bucket().items()}}
            evidence["baseline_source"] = "primary_live"
        else:
            evidence["baseline_source"] = ("snapshot_meta" if base
                                           else "none")
        if n < cfg.min_samples:
            evidence["min_samples"] = cfg.min_samples
            return self._decision("hold", failures + ["min_samples"],
                                  evidence)
        base_reward = base.get("reward_ewma")
        cand_reward = tel.reward_ewma.value
        evidence["reward_ewma"] = {"candidate": cand_reward,
                                   "baseline": base_reward,
                                   "margin": cfg.reward_margin}
        if (base_reward is not None
                and cand_reward < base_reward - cfg.reward_margin):
            failures.append("reward_ewma")
        pass_floor = cfg.pass_rate_floor
        base_pass = base.get("converged_frac")
        if base_pass is not None:
            pass_floor = max(pass_floor, base_pass - cfg.pass_rate_margin)
        evidence["pass_rate"] = {"candidate": tel.converged_frac,
                                 "baseline": base_pass,
                                 "floor": pass_floor}
        if tel.converged_frac < pass_floor:
            failures.append("pass_rate")
        base_p99 = base.get("latency_s_per_bucket") or {}
        cand_p99 = tel.latency_percentiles_per_bucket()
        p99_ev = {}
        for bucket, pct in cand_p99.items():
            res = tel._latencies_per_bucket.get(bucket)
            if res is None or len(res) < cfg.min_bucket_samples:
                continue
            bp = base_p99.get(str(bucket), {}).get("p99")
            if bp is None or bp <= 0:
                continue
            p99_ev[str(bucket)] = {"candidate": pct["p99"],
                                   "baseline": bp,
                                   "bound": cfg.p99_bound}
            if pct["p99"] > cfg.p99_bound * bp:
                failures.append(f"p99_bucket_{bucket}")
        evidence["p99_per_bucket"] = p99_ev
        if failures:
            return self._decision("rollback", failures, evidence)
        windows = self.windows_passed + 1
        if windows >= cfg.promote_windows:
            return self._decision("promote", [], evidence,
                                  windows_passed=windows)
        return self._decision("hold", [], evidence, windows_passed=windows)

    def _decision(self, outcome: str, failures: List[str],
                  evidence: Dict[str, object],
                  windows_passed: Optional[int] = None) -> RolloutDecision:
        return RolloutDecision(
            outcome=outcome,
            responses=self.candidate.telemetry.responses,
            windows_passed=(self.windows_passed if windows_passed is None
                            else windows_passed),
            failures=failures, evidence=evidence,
            candidate_version=self.candidate_version,
            baseline_version=self.baseline_version)

    def _record(self, decision: RolloutDecision) -> None:
        self.windows_passed = decision.windows_passed
        self.decisions.append(decision)
        self._decision_counts[decision.outcome] = \
            self._decision_counts.get(decision.outcome, 0) + 1
        if self._instr is not None:
            self._instr.on_decision(decision.outcome)
        self._log_event({"event": "decision",
                         "outcome": decision.outcome,
                         "responses": decision.responses,
                         "windows_passed": decision.windows_passed,
                         "failures": decision.failures,
                         "evidence": decision.evidence,
                         "candidate": decision.candidate_version,
                         "baseline": decision.baseline_version})

    # -- transitions --------------------------------------------------------
    def _rollback(self) -> None:
        """Degraded candidate: restore the prior version and retire the
        candidate (drained so in-flight canary requests still answer)."""
        restored = self.registry.rollback()
        cand, self.candidate = self.candidate, None
        cand.drain()
        self.phase = "rolled_back"
        if self._instr is not None:
            self._instr.on_state(False, self.windows_passed, 0)
        self._log_event({"event": "rollback", "restored": restored,
                         "candidate": self.candidate_version})

    def _promote(self) -> None:
        """Confirmed candidate: it takes all traffic (the registry CURRENT
        already points at it since `start_rollout`)."""
        # Drain both arms before the swap so leftover shadow requests are
        # discarded by the candidate hook and the primary slice's
        # in-flight requests answer under the old policy they selected.
        self.candidate.drain()
        old = self.primary
        old.drain()
        self.primary, self.candidate = self.candidate, None
        self.primary.on_response = self._on_primary_response
        self.phase = "promoted"
        if self._instr is not None:
            self._instr.on_state(False, self.windows_passed,
                                 self.primary.telemetry.responses)
        self._log_event({"event": "promote",
                         "candidate": self.candidate_version,
                         "baseline": self.baseline_version})

    # -- reporting ----------------------------------------------------------
    def rollout_state(self) -> dict:
        cand = self.candidate
        return {
            "phase": self.phase,
            "active": self.phase == "canary",
            "candidate_version": self.candidate_version,
            "baseline_version": self.baseline_version,
            "current_version": self.registry.current_version(),
            "canary_frac": self.rollout_cfg.canary_frac,
            "shadow": self.rollout_cfg.shadow,
            "candidate_responses": (cand.telemetry.responses
                                    if cand is not None else 0),
            "windows_passed": self.windows_passed,
            "decision_counts": dict(self._decision_counts),
            "last_decision": (dataclasses.asdict(self.decisions[-1])
                              if self.decisions else None),
        }

    def serve_obs(self, host: str = "127.0.0.1", port: int = 0):
        """Observability surface with rollout state: `/telemetry` gains a
        ``rollout`` key and `/rollout` serves the controller state."""
        if self.obs is None:
            raise RuntimeError("server was built with obs=False")
        return self.obs.serve(host=host, port=port,
                              ready_fn=lambda: self.ready,
                              telemetry_fn=self.telemetry.snapshot,
                              rollout_fn=self.rollout_state,
                              health_fn=self.degradation_state)

    def close(self) -> None:
        if self._decision_log is not None:
            self._decision_log.close()

    def _log_event(self, rec: dict) -> None:
        if self._decision_log is None:
            return
        try:
            self._decision_log.append({"ts": _time.time(), **rec})
        except Exception:
            pass                    # fail-open, like everything in obs
