"""Off-policy evaluation of candidate policies from logged trajectories.

Scores a candidate precision policy on the service's logged decision
stream *without* serving it: the JSONL trajectory log (`obs.trajlog`)
records, per decision, everything an importance-weighted estimator
needs — features, discretized state, the action taken, the epsilon in
force, whether the epsilon coin fired, and the observed reward.

Propensity contract (DESIGN.md §10.1). The behavior policy is the
server's ε-greedy: with probability ``eps`` the action is uniform over
the ``K`` arms, otherwise it is the live greedy arm. The logged
``explore`` flag is the realized coin, so the behavior propensity of
the logged action is reconstructed exactly from logged fields:

  * ``explore=False`` — the action *is* the greedy arm, which the
    uniform branch could also have drawn:  p = (1 - eps) + eps / K;
  * ``explore=True``  — the action came from the uniform draw:
    p = eps / K.  (A uniform draw that happens to coincide with the
    greedy arm — probability eps/K per decision — is still assigned
    the exploration branch's propensity; the resulting conservative
    over-weighting is bounded by ``weight_clip`` and surfaced in
    ``clipped_frac``.)

Estimators: inverse propensity scoring (IPS, self-normalized per
bucket stratum), the direct method (DM) over an empirical per-(state,
action) reward model with a *pessimistic* fallback for logged-support
holes, and doubly robust (DR) combining both. Confidence intervals are
stratified bootstrap percentiles. The reward-model fallback is the
worst observed reward by design: an action the log never tried must
not be scored optimistically by extrapolation — that is exactly the
candidate the canary slice (not OPE) exists to vet.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np


# ---------------------------------------------------------------------------
# Logged steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoggedStep:
    """One behavior-policy decision, normalized from a trajectory
    record (`TrajectoryLog.FIELDS`)."""
    features: np.ndarray
    state: int
    action: int
    eps: float
    explore: bool
    reward: float
    bucket: int

    @classmethod
    def from_record(cls, rec: dict) -> "LoggedStep":
        return cls(features=np.asarray(rec["features"], dtype=np.float64),
                   state=int(rec["state"]),
                   action=int(rec["action"]),
                   eps=float(rec["eps"]),
                   explore=bool(rec["explore"]),
                   reward=float(rec["reward"]),
                   bucket=int(rec.get("bucket", 0)))


def steps_from_records(records: Iterable[dict],
                       n_actions: int) -> List[LoggedStep]:
    """Coerce raw trajectory records, dropping rows OPE cannot use:
    missing required fields, out-of-range actions, epsilon outside
    (0, 1], or a non-finite reward. Forgiving by design — the log is
    shared with decision-trail events and tolerates torn writes."""
    steps: List[LoggedStep] = []
    for rec in records:
        try:
            st = LoggedStep.from_record(rec)
        except (KeyError, TypeError, ValueError):
            continue
        if not (0 <= st.action < n_actions):
            continue
        if not (0.0 < st.eps <= 1.0) and not (st.eps == 0.0
                                              and not st.explore):
            continue
        if not np.isfinite(st.reward):
            continue
        steps.append(st)
    return steps


def behavior_propensity(eps: float, explore: bool, n_actions: int) -> float:
    """Exact behavior propensity of the logged action (module
    docstring contract)."""
    eps = float(eps)
    if explore:
        return eps / n_actions
    return (1.0 - eps) + eps / n_actions


# ---------------------------------------------------------------------------
# Candidate policies
# ---------------------------------------------------------------------------

@runtime_checkable
class PolicyCandidate(Protocol):
    """A scoreable policy: deterministic state→action map over logged
    contexts. Both registry Q-table snapshots (`SnapshotCandidate`)
    and arbitrary callables (`CallableCandidate`) satisfy it.

    Implementations may additionally expose
    ``prob_of(features, state, action) -> float`` for stochastic
    policies; absent that, the candidate is treated as deterministic
    (probability is the indicator of ``action_of``).
    """

    name: str

    def action_of(self, features: np.ndarray, state: int) -> int:
        """Action index the candidate would take in this context."""
        ...


class SnapshotCandidate:
    """A registry Q-table snapshot as a candidate: greedy actions via
    `PrecisionPolicy.predict` (nearest-visited-bin fallback included,
    so the scored policy is exactly the one the server would serve)."""

    def __init__(self, policy, name: str = "snapshot"):
        self.policy = policy
        self.name = str(name)

    @classmethod
    def from_registry(cls, registry, version: str) -> "SnapshotCandidate":
        return cls(registry.load(version), name=str(version))

    @property
    def n_actions(self) -> int:
        return int(self.policy.qtable.n_actions)

    def action_of(self, features: np.ndarray, state: int) -> int:
        a, _ = self.policy.predict(np.asarray(features))
        return int(a)


class CallableCandidate:
    """Any ``fn(features, state) -> action index`` as a candidate."""

    def __init__(self, fn: Callable[[np.ndarray, int], int],
                 name: str = "callable"):
        self._fn = fn
        self.name = str(name)

    def action_of(self, features: np.ndarray, state: int) -> int:
        return int(self._fn(features, state))


def as_candidate(obj, name: Optional[str] = None):
    """Coerce a `PolicyCandidate`, a `PrecisionPolicy`, or a bare
    callable into a candidate."""
    if isinstance(obj, (SnapshotCandidate, CallableCandidate)):
        return obj
    if callable(getattr(obj, "action_of", None)):
        return obj
    if hasattr(obj, "predict") and hasattr(obj, "qtable"):
        return SnapshotCandidate(obj, name=name or "policy")
    if callable(obj):
        return CallableCandidate(obj, name=name or "callable")
    raise TypeError(f"cannot interpret {type(obj).__name__} as a "
                    "PolicyCandidate")


def _prob_of(candidate, step: LoggedStep) -> float:
    """P(candidate takes the logged action); indicator for
    deterministic candidates."""
    prob = getattr(candidate, "prob_of", None)
    if prob is not None:
        return float(prob(step.features, step.state, step.action))
    return 1.0 if int(candidate.action_of(step.features,
                                          step.state)) == step.action \
        else 0.0


# ---------------------------------------------------------------------------
# Reward model (direct method)
# ---------------------------------------------------------------------------

class EmpiricalRewardModel:
    """Q̂(s, a): empirical mean logged reward per (state, action).

    Pairs the log never observed fall back to the *worst observed
    reward* — a deliberately pessimistic prior. DR's correction term
    only de-biases the model where the log has support; everywhere
    else the model's word is final, and scoring unexplored actions at
    the observed floor is what makes the OPE gate conservative instead
    of credulous (DESIGN.md §10.2)."""

    def __init__(self):
        self._mean: Dict[Tuple[int, int], float] = {}
        self.floor = 0.0

    def fit(self, steps: Sequence[LoggedStep]) -> "EmpiricalRewardModel":
        tot: Dict[Tuple[int, int], float] = {}
        cnt: Dict[Tuple[int, int], int] = {}
        for st in steps:
            key = (st.state, st.action)
            tot[key] = tot.get(key, 0.0) + st.reward
            cnt[key] = cnt.get(key, 0) + 1
        self._mean = {k: tot[k] / cnt[k] for k in tot}
        self.floor = min((st.reward for st in steps), default=0.0)
        return self

    def supported(self, state: int, action: int) -> bool:
        return (int(state), int(action)) in self._mean

    def predict(self, state: int, action: int) -> float:
        return self._mean.get((int(state), int(action)), self.floor)


# ---------------------------------------------------------------------------
# Estimation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OPEConfig:
    n_bootstrap: int = 200       # bootstrap resamples for the CI
    ci: float = 0.90             # two-sided CI coverage
    seed: int = 0                # bootstrap rng
    weight_clip: Optional[float] = 100.0   # IPS/DR weight cap
    self_normalized: bool = True  # Hájek IPS (per stratum)


@dataclasses.dataclass
class OPEEstimate:
    estimator: str               # "ips" | "dm" | "dr"
    value: float                 # point estimate (bucket-stratified)
    ci_lo: float                 # bootstrap percentile interval
    ci_hi: float
    n: int                       # logged decisions scored
    ess: float                   # effective sample size of the weights
    clipped_frac: float          # nonzero weights that hit weight_clip
    support: float               # frac of candidate actions with logged
    #                              support at their state (DM coverage)
    per_bucket: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"estimator": self.estimator, "value": self.value,
                "ci": [self.ci_lo, self.ci_hi], "n": self.n,
                "ess": self.ess, "clipped_frac": self.clipped_frac,
                "support": self.support, "per_bucket": self.per_bucket}


class _Scored:
    """Per-step arrays for one candidate, reused across bootstrap
    resamples (the candidate's actions and weights don't change —
    only the resampled index set does)."""

    def __init__(self, steps: Sequence[LoggedStep], candidate,
                 model: EmpiricalRewardModel, cfg: OPEConfig):
        n = len(steps)
        self.rewards = np.array([s.reward for s in steps])
        self.buckets = np.array([s.bucket for s in steps])
        self.weights = np.zeros(n)
        self.q_logged = np.zeros(n)    # Q̂(s_i, a_i)  (logged action)
        self.q_target = np.zeros(n)    # Q̂(s_i, π(s_i)) (candidate action)
        self.supported = np.zeros(n, dtype=bool)
        clipped = 0
        k = candidate_n_actions(candidate)
        for i, st in enumerate(steps):
            p = behavior_propensity(st.eps, st.explore, k)
            w = _prob_of(candidate, st) / p
            if cfg.weight_clip is not None and w > cfg.weight_clip:
                w = cfg.weight_clip
                clipped += 1
            self.weights[i] = w
            a_c = int(candidate.action_of(st.features, st.state))
            self.q_logged[i] = model.predict(st.state, st.action)
            self.q_target[i] = model.predict(st.state, a_c)
            self.supported[i] = model.supported(st.state, a_c)
        nz = int(np.count_nonzero(self.weights))
        self.clipped_frac = clipped / max(nz, 1)
        sw, sw2 = self.weights.sum(), (self.weights ** 2).sum()
        self.ess = float(sw * sw / sw2) if sw2 > 0 else 0.0
        self.support = float(self.supported.mean()) if n else 0.0


def candidate_n_actions(candidate) -> int:
    """Action-space size K for the propensity denominator. Snapshot
    candidates know it; otherwise it must be attached by the caller
    (``evaluate_policy(..., n_actions=...)`` does this)."""
    k = getattr(candidate, "n_actions", None)
    if k is None:
        raise ValueError("candidate carries no n_actions; pass "
                         "n_actions= to evaluate_policy/ope_gate")
    return int(k)


def _estimate_on(idx: np.ndarray, sc: _Scored, estimator: str,
                 cfg: OPEConfig) -> float:
    """One estimator over the (resampled) index set, stratified by
    bucket: V̂ = Σ_b (n_b / n) V̂_b. For mean-style estimators (DM,
    DR) this equals the plain mean; for self-normalized IPS the
    stratification is real — each bucket's weights renormalize among
    themselves, so a heavy bucket cannot starve a light one."""
    total, n = 0.0, len(idx)
    for b in np.unique(sc.buckets[idx]):
        sub = idx[sc.buckets[idx] == b]
        w, r = sc.weights[sub], sc.rewards[sub]
        if estimator == "ips":
            sw = w.sum()
            if cfg.self_normalized and sw > 0:
                v = float((w * r).sum() / sw)
            else:
                v = float((w * r).mean())
        elif estimator == "dm":
            v = float(sc.q_target[sub].mean())
        else:   # dr
            v = float((sc.q_target[sub]
                       + w * (r - sc.q_logged[sub])).mean())
        total += (len(sub) / n) * v
    return total


def _bootstrap_ci(sc: _Scored, estimator: str,
                  cfg: OPEConfig) -> Tuple[float, float]:
    """Stratified bootstrap percentile interval: resample within each
    bucket (counts preserved) so the strata the point estimate uses
    survive the resampling."""
    n = len(sc.rewards)
    if n == 0 or cfg.n_bootstrap <= 0:
        return float("nan"), float("nan")
    rng = np.random.default_rng(cfg.seed)
    by_bucket = [np.flatnonzero(sc.buckets == b)
                 for b in np.unique(sc.buckets)]
    vals = np.empty(cfg.n_bootstrap)
    for t in range(cfg.n_bootstrap):
        idx = np.concatenate([sub[rng.integers(0, len(sub), len(sub))]
                              for sub in by_bucket])
        vals[t] = _estimate_on(idx, sc, estimator, cfg)
    alpha = (1.0 - cfg.ci) / 2.0
    return (float(np.quantile(vals, alpha)),
            float(np.quantile(vals, 1.0 - alpha)))


def evaluate_policy(records: Iterable[dict], candidate,
                    n_actions: Optional[int] = None,
                    cfg: OPEConfig = OPEConfig(),
                    model: Optional[EmpiricalRewardModel] = None
                    ) -> Dict[str, OPEEstimate]:
    """Score `candidate` on logged records: {"ips", "dm", "dr"} →
    `OPEEstimate`. `records` may be raw trajectory dicts or
    `LoggedStep`s; `n_actions` is required unless the candidate
    carries it (snapshot candidates do)."""
    candidate = as_candidate(candidate)
    if n_actions is not None:
        k = int(n_actions)
        have = getattr(candidate, "n_actions", None)
        if have is None:
            candidate.n_actions = k
        elif int(have) != k:
            raise ValueError(f"candidate n_actions={have} != logged "
                             f"action-space size {k}")
    records = list(records)
    if records and isinstance(records[0], LoggedStep):
        steps = records
    else:
        steps = steps_from_records(records,
                                   candidate_n_actions(candidate))
    model = (model if model is not None
             else EmpiricalRewardModel().fit(steps))
    sc = _Scored(steps, candidate, model, cfg)
    out: Dict[str, OPEEstimate] = {}
    idx = np.arange(len(steps))
    for est in ("ips", "dm", "dr"):
        value = (_estimate_on(idx, sc, est, cfg)
                 if len(steps) else float("nan"))
        lo, hi = _bootstrap_ci(sc, est, cfg)
        per_bucket = {}
        for b in np.unique(sc.buckets) if len(steps) else []:
            sub = idx[sc.buckets == b]
            per_bucket[str(int(b))] = _estimate_on(sub, sc, est, cfg)
        out[est] = OPEEstimate(
            estimator=est, value=value, ci_lo=lo, ci_hi=hi,
            n=len(steps), ess=sc.ess, clipped_frac=sc.clipped_frac,
            support=sc.support, per_bucket=per_bucket)
    return out


# ---------------------------------------------------------------------------
# The rollout gate
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OPEGateReport:
    """Verdict + evidence of one OPE gate run (appended to the
    decision-trail JSONL and into the candidate version's meta)."""
    accept: bool
    reason: str                  # "cleared" | "lcb_below_floor" |
    #                              "insufficient_records" | "no_incumbent"
    n_records: int
    floor: Optional[float]       # incumbent DR value - margin
    margin: float
    candidate: Optional[Dict[str, OPEEstimate]]
    incumbent: Optional[Dict[str, OPEEstimate]]

    def to_event(self) -> dict:
        ev = {"accept": bool(self.accept), "reason": self.reason,
              "n_records": int(self.n_records), "floor": self.floor,
              "margin": self.margin}
        for side in ("candidate", "incumbent"):
            ests = getattr(self, side)
            if ests is not None:
                ev[side] = {k: v.to_dict() for k, v in ests.items()}
        return ev


def ope_gate(records: Sequence[dict], incumbent, candidate,
             n_actions: Optional[int] = None, *,
             margin: float = 0.5, min_records: int = 64,
             cfg: OPEConfig = OPEConfig()) -> OPEGateReport:
    """Gate a candidate on logged evidence before it takes a canary.

    Accepts iff the candidate's doubly-robust *lower confidence bound*
    clears the incumbent's DR point estimate minus `margin`. Degenerate
    inputs fail open with an explicit reason: too few logged records
    (the canary's telemetry gates are then the only rail — exactly the
    pre-OPE status quo) or no incumbent to compare against.
    """
    candidate = as_candidate(candidate, name="candidate")
    records = list(records)
    if n_actions is None:
        n_actions = candidate_n_actions(candidate)
    steps = steps_from_records(records, int(n_actions))
    if len(steps) < int(min_records):
        return OPEGateReport(True, "insufficient_records", len(steps),
                             None, margin, None, None)
    if incumbent is None:
        return OPEGateReport(True, "no_incumbent", len(steps), None,
                             margin, None, None)
    incumbent = as_candidate(incumbent, name="incumbent")
    model = EmpiricalRewardModel().fit(steps)
    cand = evaluate_policy(steps, candidate, n_actions, cfg, model=model)
    inc = evaluate_policy(steps, incumbent, n_actions, cfg, model=model)
    floor = inc["dr"].value - float(margin)
    accept = bool(cand["dr"].ci_lo >= floor)
    return OPEGateReport(accept,
                         "cleared" if accept else "lcb_below_floor",
                         len(steps), floor, float(margin), cand, inc)
