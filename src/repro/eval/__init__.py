"""Off-policy evaluation + deterministic trajectory replay (DESIGN.md §10).

The service tier logs one JSONL record per served decision
(`obs.trajlog`): context features, discretized state, the action taken,
the epsilon in force and whether the epsilon coin fired, the observed
reward, and the policy version. This package turns that log into the
safety rail the ROADMAP's "Beyond ε-greedy" workstream calls for:

  * `eval.ope`    — inverse-propensity-scoring and doubly-robust
    estimators that score a *candidate* policy on the logged stream
    before it ever takes a canary slice, with propensities
    reconstructed exactly from the logged (eps, explore, action)
    fields of the ε-greedy behavior policy, per-bucket stratification,
    and bootstrap confidence intervals;
  * `eval.replay` — a deterministic replay engine that re-feeds logged
    (instance, action) pairs through `AutotuneEngine` and asserts
    bit-identical outcomes against the logged rewards/statuses, so any
    production trajectory segment doubles as a regression fixture.

`service.rollout.ShadowServer` wires `ope.ope_gate` in front of
`start_rollout`: a candidate must clear a reward
lower-confidence-bound floor vs the incumbent or it is refused the
canary slice outright (counted as ``outcome="ope_reject"``).
"""
from repro.eval.ope import (CallableCandidate, EmpiricalRewardModel,
                            LoggedStep, OPEConfig, OPEEstimate,
                            OPEGateReport, PolicyCandidate,
                            SnapshotCandidate, as_candidate,
                            behavior_propensity, evaluate_policy,
                            ope_gate, steps_from_records)
from repro.eval.replay import (ReplayMismatch, ReplayReport,
                               assert_replay_ok, replay_records)

__all__ = [
    "CallableCandidate", "EmpiricalRewardModel", "LoggedStep",
    "OPEConfig", "OPEEstimate", "OPEGateReport", "PolicyCandidate",
    "ReplayMismatch", "ReplayReport", "SnapshotCandidate",
    "as_candidate", "assert_replay_ok", "behavior_propensity",
    "evaluate_policy", "ope_gate", "replay_records",
    "steps_from_records",
]
