"""Deterministic trajectory replay: logged service decisions become
regression fixtures.

Every record in the JSONL trajectory log names an action and the
outcome/reward it produced. Because the whole serving stack is
deterministic — identity padding, fixed compiled shapes, bit-exact
backends (DESIGN.md §6), row-independent batched solves — re-applying
the logged action to the same instance must reproduce the logged
outcome *bit-identically*, regardless of how requests were micro-
batched the first time. `replay_records` asserts exactly that: it
re-feeds logged (instance, action) pairs through `AutotuneEngine`'s
ad-hoc solve cache (`solve_adhoc`, batched per bucket), recomputes the
reward through the task's reward hook, and diffs every compared field
against the log.

What replay needs that the log does not carry is the instance itself
(the trajectory log records features, not matrices); callers supply an
``instance_of`` mapping from ``request_id`` to instance — trivially
available wherever the request stream is reproducible (a seeded test
stream, a saved request corpus, a capture buffer).

A clean `ReplayReport` is the determinism proof the OPE layer leans
on: if replay reproduces logged rewards bit-for-bit, the logged stream
is a faithful sample of the live reward function, not an artifact of
batching or compile-cache state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, List, Mapping

import numpy as np

from repro.core.engine import AutotuneEngine


@dataclasses.dataclass
class ReplayMismatch:
    request_id: int
    field: str
    logged: object
    replayed: object

    def __str__(self) -> str:
        return (f"request {self.request_id}: {self.field} logged="
                f"{self.logged!r} replayed={self.replayed!r}")


@dataclasses.dataclass
class ReplayReport:
    n_records: int               # records offered
    n_replayed: int              # records with an instance, re-solved
    n_skipped: int               # no instance mapping / malformed
    mismatches: List[ReplayMismatch]

    @property
    def ok(self) -> bool:
        return self.n_replayed > 0 and not self.mismatches

    def summary(self) -> str:
        head = (f"replayed {self.n_replayed}/{self.n_records} records "
                f"({self.n_skipped} skipped): ")
        if not self.mismatches:
            return head + "bit-identical"
        lines = [str(m) for m in self.mismatches[:10]]
        if len(self.mismatches) > 10:
            lines.append(f"... and {len(self.mismatches) - 10} more")
        return head + f"{len(self.mismatches)} mismatches\n  " \
            + "\n  ".join(lines)


def _bit_equal(a, b) -> bool:
    """Float equality with non-finite values compared by class (the
    JSON round-trip preserves finite floats exactly; NaN == NaN here)."""
    fa, fb = float(a), float(b)
    if math.isnan(fa) or math.isnan(fb):
        return math.isnan(fa) and math.isnan(fb)
    return fa == fb


def replay_records(engine: AutotuneEngine,
                   records: Iterable[dict],
                   instance_of,
                   reward_cfg=None,
                   check_metrics: bool = True) -> ReplayReport:
    """Re-solve every logged record and diff against the log.

    Parameters
    ----------
    engine : AutotuneEngine
        Hosts the task to replay through. Its action space must be the
        one the log was produced under (action indices are compared by
        position).
    records : iterable of trajectory-log dicts
        E.g. ``TrajectoryLog.read(path, task=...)``.
    instance_of : mapping or callable
        ``request_id -> instance``; records without an instance are
        skipped (counted in ``n_skipped``).
    reward_cfg : optional
        Reward config override; defaults to the engine's.
    check_metrics : bool
        Also compare every scalar in the logged ``outcome`` dict
        (ferr, nbe, iteration counts, ...) bit-identically.
    """
    if isinstance(instance_of, Mapping):
        lookup: Callable[[int], object] = instance_of.get
    else:
        lookup = instance_of
    todo: List[tuple] = []        # (record, instance)
    n_records = n_skipped = 0
    for rec in records:
        n_records += 1
        try:
            rid = int(rec["request_id"])
            inst = lookup(rid)
        except (KeyError, TypeError, ValueError):
            inst = None
        if inst is None:
            n_skipped += 1
            continue
        todo.append((rec, inst))
    # One batched pass per bucket through the ad-hoc solve cache: the
    # replay cost profile matches serving (fixed chunks, one compiled
    # executable per bucket), not one-solve-per-record.
    outs = engine.solve_adhoc([(inst, int(rec["action"]))
                               for rec, inst in todo])
    mismatches: List[ReplayMismatch] = []

    def diff(rid: int, field: str, logged, replayed) -> None:
        if not _bit_equal(logged, replayed):
            mismatches.append(ReplayMismatch(rid, field, logged, replayed))

    for (rec, inst), out in zip(todo, outs):
        rid = int(rec["request_id"])
        feats = np.asarray(engine.task.feature_of(inst), dtype=np.float64)
        logged_feats = np.asarray(rec.get("features", ()),
                                  dtype=np.float64)
        if logged_feats.shape != feats.shape or not all(
                _bit_equal(x, y) for x, y in zip(logged_feats, feats)):
            mismatches.append(ReplayMismatch(
                rid, "features", rec.get("features"), feats.tolist()))
        logged_out = rec.get("outcome", {})
        diff(rid, "status", logged_out.get("status"), int(out.status))
        r = engine.reward_for(out, int(rec["action"]), inst,
                              cfg=reward_cfg)
        diff(rid, "reward", rec.get("reward"), float(r))
        if check_metrics:
            for key, logged_v in logged_out.items():
                if key == "status":
                    continue
                # `cost` is an Outcome field, everything else a metrics
                # entry; attribute access covers both.
                have = getattr(out, key, None)
                if have is None:
                    mismatches.append(ReplayMismatch(
                        rid, f"outcome.{key}", logged_v, None))
                else:
                    diff(rid, f"outcome.{key}", logged_v, have)
    return ReplayReport(n_records=n_records, n_replayed=len(todo),
                        n_skipped=n_skipped, mismatches=mismatches)


def assert_replay_ok(report: ReplayReport,
                     min_replayed: int = 1) -> ReplayReport:
    """Raise with the full diff when replay is not bit-identical —
    the one-liner that turns a trajectory segment into a regression
    fixture: ``assert_replay_ok(replay_records(engine, recs, insts))``."""
    if report.n_replayed < min_replayed:
        raise AssertionError(
            f"replay covered {report.n_replayed} records "
            f"(< {min_replayed}); nothing was verified")
    if report.mismatches:
        raise AssertionError(report.summary())
    return report
