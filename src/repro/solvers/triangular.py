"""Chopped triangular solves (forward/backward substitution).

Strict path (below the blocking threshold) — per-row semantics: products
rounded to the target format, row-dot accumulated in the carrier, one
rounding on the subtraction and one on the division — FMA-style
op-level emulation (DESIGN.md §3.5). Roundings dispatch through the
precision backend (DESIGN.md §6); the per-row vectors are small, so
every backend routes them to the bit-identical jnp chop and the two
backends stay exact here by construction.

Division rounding is deliberately *double*: `solve_upper` computes
``chop(chop(y[i] - s) / safe)`` — the subtraction result is a stored
value (one rounding), and the division result is another stored value
(a second rounding). This is the op-level model's "one rounding per
stored operation" applied literally (DESIGN.md §3.5), matching how a
hardware FMA pipeline would materialize the numerator before a separate
divide; it is NOT a bug, and ``tests/test_blocked_lu_trisolve.py``
pins it so backends (and future refactors) cannot drift to the
single-rounding ``chop((y[i] - s) / safe)`` semantics.

Blocked path (at/above `blocking.min_n`): the whole solve dispatches to
`backend.chop_trisolve` — block-triangular substitution with fused
chopped-matvec off-diagonal tiles and strict-row-loop diagonal blocks
(kernels/trisolve; DESIGN.md §6.2/§6.4). One Pallas launch replaces the
O(n) sequential row loop on the pallas backend; the jnp backend runs
the bit-identical oracle. The branch is on the static shape, so each
size bucket keeps exactly one executable with the format id runtime.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend, tree_sum

from .blocking import resolve_blocking


def solve_unit_lower(LU: jnp.ndarray, b: jnp.ndarray, fmt_id,
                     backend=None, blocking=None) -> jnp.ndarray:
    """Solve L y = b where L is unit-lower (strict lower triangle of LU)."""
    bk = resolve_backend(backend)
    n = LU.shape[-1]
    pol = resolve_blocking(blocking)
    if pol.use_blocked(n):
        return bk.chop_trisolve(LU, b, fmt_id, lower=True,
                                block=pol.trisolve_block)
    chop = bk.chop
    idx = jnp.arange(n)
    b = chop(b, fmt_id)

    def step(i, y):
        row = jnp.take(LU, i, axis=0)
        prods = chop(row * y, fmt_id)
        s = tree_sum(jnp.where(idx < i, prods, jnp.zeros((), b.dtype)))
        yi = chop(b[i] - s, fmt_id)
        return y.at[i].set(yi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(b))


def solve_upper(LU: jnp.ndarray, y: jnp.ndarray, fmt_id,
                backend=None, blocking=None) -> jnp.ndarray:
    """Solve U x = y where U is the upper triangle (incl. diagonal) of LU."""
    bk = resolve_backend(backend)
    n = LU.shape[-1]
    pol = resolve_blocking(blocking)
    if pol.use_blocked(n):
        return bk.chop_trisolve(LU, y, fmt_id, lower=False,
                                block=pol.trisolve_block)
    chop = bk.chop
    idx = jnp.arange(n)
    y = chop(y, fmt_id)

    def step(j, x):
        i = n - 1 - j
        row = jnp.take(LU, i, axis=0)
        prods = chop(row * x, fmt_id)
        s = tree_sum(jnp.where(idx > i, prods, jnp.zeros((), y.dtype)))
        diag = row[i]
        safe = jnp.where(diag == 0, jnp.ones((), y.dtype), diag)
        # Double rounding by design: stored numerator, then stored
        # quotient (see module docstring).
        xi = chop(chop(y[i] - s, fmt_id) / safe, fmt_id)
        return x.at[i].set(xi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(y))


def lu_solve(LU: jnp.ndarray, perm: jnp.ndarray, b: jnp.ndarray,
             fmt_id, backend=None, blocking=None) -> jnp.ndarray:
    """Solve A x = b given chopped LU factors: x = U \\ (L \\ (P b))."""
    bk = resolve_backend(backend)
    pol = resolve_blocking(blocking)
    pb = b[perm]
    y = solve_unit_lower(LU, pb, fmt_id, backend=bk, blocking=pol)
    return solve_upper(LU, y, fmt_id, backend=bk, blocking=pol)
