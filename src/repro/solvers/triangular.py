"""Chopped triangular solves (forward/backward substitution).

Per-row semantics: products rounded to the target format, row-dot
accumulated in the carrier, one rounding on the subtraction and one on the
division — FMA-style op-level emulation (DESIGN.md §3.5). Roundings
dispatch through the precision backend (DESIGN.md §6); the per-row
vectors are small, so every backend routes them to the bit-identical
jnp chop and the two backends stay exact here by construction.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend


def solve_unit_lower(LU: jnp.ndarray, b: jnp.ndarray, fmt_id,
                     backend=None) -> jnp.ndarray:
    """Solve L y = b where L is unit-lower (strict lower triangle of LU)."""
    chop = resolve_backend(backend).chop
    n = LU.shape[-1]
    idx = jnp.arange(n)
    b = chop(b, fmt_id)

    def step(i, y):
        row = jnp.take(LU, i, axis=0)
        prods = chop(row * y, fmt_id)
        s = jnp.sum(jnp.where(idx < i, prods, jnp.zeros((), b.dtype)))
        yi = chop(b[i] - s, fmt_id)
        return y.at[i].set(yi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(b))


def solve_upper(LU: jnp.ndarray, y: jnp.ndarray, fmt_id,
                backend=None) -> jnp.ndarray:
    """Solve U x = y where U is the upper triangle (incl. diagonal) of LU."""
    chop = resolve_backend(backend).chop
    n = LU.shape[-1]
    idx = jnp.arange(n)
    y = chop(y, fmt_id)

    def step(j, x):
        i = n - 1 - j
        row = jnp.take(LU, i, axis=0)
        prods = chop(row * x, fmt_id)
        s = jnp.sum(jnp.where(idx > i, prods, jnp.zeros((), y.dtype)))
        diag = row[i]
        safe = jnp.where(diag == 0, jnp.ones((), y.dtype), diag)
        xi = chop(chop(y[i] - s, fmt_id) / safe, fmt_id)
        return x.at[i].set(xi)

    return lax.fori_loop(0, n, step, jnp.zeros_like(y))


def lu_solve(LU: jnp.ndarray, perm: jnp.ndarray, b: jnp.ndarray,
             fmt_id, backend=None) -> jnp.ndarray:
    """Solve A x = b given chopped LU factors: x = U \\ (L \\ (P b))."""
    bk = resolve_backend(backend)
    pb = b[perm]
    y = solve_unit_lower(LU, pb, fmt_id, backend=bk)
    return solve_upper(LU, y, fmt_id, backend=bk)
