"""Partition-invariant unrounded carrier math (DESIGN.md §6.2, §7.3).

Almost everything on the solver hot path is rounded through `chop`'s
integer-bitcast chain, which pins its bits in any program context. The
exceptions are the *unrounded* carrier reductions — the GMRES/CG
residual norms and the final Eq. 17 metrics — whose bits were at the
mercy of XLA's lowering: a multiply feeding a reduction may or may not
be FMA-contracted depending on fusion context, and the batched dot
lowers differently when a mesh shard holds a single row (batch-1 dot
!= batched dot on XLA:CPU — measured). That made solver outputs
executor-dependent and was the documented residual caveat of §6.2.

These helpers pin the schedule without changing semantics: the product
is materialized behind a value-preserving integer-bitcast barrier (the
same FMA-barrier trick `_chop_core` relies on, minus the rounding), and
the reduction is a per-row / per-vector sum — invariant to how rows are
tiled across devices (the §6.2 property the fused-matvec contract is
built on). Used by `ir.py`/`cg.py` (final metrics) and
`gmres.py`/`cg.py` (inner residual norms).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.precision import fma_barrier, tree_sum


def carrier_residual(A: jnp.ndarray, b: jnp.ndarray,
                     x: jnp.ndarray) -> jnp.ndarray:
    """b - A x with a pinned row-sum schedule (the Eq. 17 epilogue)."""
    return b - tree_sum(fma_barrier(A * x[None, :]), axis=-1)


def carrier_norm(v: jnp.ndarray) -> jnp.ndarray:
    """||v||_2 with a pinned square-then-sum schedule (replaces
    `jnp.linalg.norm` on the unrounded inner-residual path)."""
    return jnp.sqrt(tree_sum(fma_barrier(v * v)))
