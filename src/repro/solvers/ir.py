"""GMRES-based iterative refinement (paper Alg. 2) with per-step precisions.

Action a = (u_f, u, u_g, u_r) — four runtime format ids:
  u_f : LU factorization (+ its use as the GMRES preconditioner's factors)
  u   : solution update x_{i+1} = x_i + z_i
  u_g : GMRES working precision (operator, MGS, Givens)
  u_r : residual computation r_i = b - A x_i

Stopping criteria (paper Eqs. 14-16):
  converged : ||z_i||_inf / ||x_{i+1}||_inf <= max(tau, u_work(u))
  stagnated : ||z_i||_inf / ||z_{i-1}||_inf >= stag_tol
  max-iter  : i >= i_max
plus an explicit failure path (LU overflow / zero pivot / non-finite GMRES).

x0 initialization: the paper's Alg. 2 line 2 uses x0 = U\\(L\\b); its
*reported* iteration counts (exactly 2.0 outer iterations for every FP64
baseline row of Tables 2/4/6) are only consistent with x0 = 0, where the
first "refinement" performs the initial solve through the preconditioned
GMRES. We default to x0 = 0 to match the paper's accounting and provide
init="lu" for the literal Alg. 2 variant.

Everything is jit-compatible with runtime format ids and vmappable over
(systems x actions) — the bandit sweeps a whole episode in one batched call.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend, rounding_unit, tree_sum

from .blocking import DEFAULT_BLOCKING, BlockingPolicy
from .carrier import carrier_residual
from .gmres import chop_mv, gmres_precond
from .lu import lu_factor_auto
from .triangular import lu_solve


@dataclasses.dataclass(frozen=True)
class IRConfig:
    tau: float = 1e-6          # convergence tolerance (benchmark parameter)
    i_max: int = 10            # max outer (refinement) iterations
    m_max: int = 40            # max inner GMRES iterations
    tol_inner: float = 1e-4    # GMRES relative residual tolerance
    stag_tol: float = 0.9      # Eq. 15 stagnation threshold
    init: str = "zero"         # "zero" (paper accounting) | "lu" (Alg.2 l.2)
    # Blocked LU/trisolve engagement (DESIGN.md §6.4). Part of the
    # frozen config so it rides in the static jit key with the rest.
    blocking: BlockingPolicy = DEFAULT_BLOCKING


# Solver outcome status codes.
CONVERGED, STAGNATED, MAXITER, FAILED = 0, 1, 2, 3


class SolveStats(NamedTuple):
    ferr: jnp.ndarray          # normwise relative forward error (Eq. 17)
    nbe: jnp.ndarray           # normwise relative backward error (Eq. 17)
    n_outer: jnp.ndarray      # refinement iterations performed
    n_gmres: jnp.ndarray      # total inner GMRES iterations
    status: jnp.ndarray       # CONVERGED/STAGNATED/MAXITER/FAILED
    res_norm: jnp.ndarray     # final ||b - A x||_inf


def _inf_norm(v):
    return jnp.max(jnp.abs(v))


def _gmres_ir_impl(A, b, x_true, action, cfg, backend) -> SolveStats:
    dtype = A.dtype
    chop = backend.chop
    uf, u, ug, ur = action[0], action[1], action[2], action[3]

    lu = lu_factor_auto(A, uf, backend=backend, blocking=cfg.blocking)
    A_g = chop(A, ug)
    A_r = chop(A, ur)
    b_r = chop(b, ur)

    if cfg.init == "lu":
        x0 = lu_solve(lu.lu, lu.perm, b, uf, backend=backend,
                      blocking=cfg.blocking)
        x0 = jnp.where(jnp.isfinite(x0), x0, jnp.zeros_like(x0))
    else:
        x0 = jnp.zeros_like(b)

    u_work = rounding_unit(u, dtype)
    conv_tol = jnp.maximum(jnp.asarray(cfg.tau, dtype), u_work)

    def cond(state):
        *_, done = state
        return ~done

    def body(state):
        x, znorm_prev, i, n_gmres, status, done = state
        r = chop(b_r - chop_mv(A_r, x, ur, backend=backend), ur)
        gm = gmres_precond(A_g, lu.lu, lu.perm, r, ug,
                           m_max=cfg.m_max, tol=cfg.tol_inner,
                           backend=backend, blocking=cfg.blocking)
        z = chop(gm.z, u)
        x_new = chop(x + z, u)
        znorm = _inf_norm(z)
        xnorm = _inf_norm(x_new)
        i_new = i + 1

        converged = znorm <= conv_tol * xnorm
        stagnated = (i > 0) & (znorm >= cfg.stag_tol * znorm_prev)
        hit_max = i_new >= cfg.i_max
        failed = gm.fail | ~jnp.all(jnp.isfinite(x_new))

        status = jnp.where(
            failed, FAILED,
            jnp.where(converged, CONVERGED,
                      jnp.where(stagnated, STAGNATED,
                                jnp.where(hit_max, MAXITER, status))))
        done = converged | stagnated | hit_max | failed
        x_new = jnp.where(failed, x, x_new)
        return (x_new, znorm, i_new, n_gmres + gm.iters, status, done)

    init_state = (x0, jnp.asarray(jnp.inf, dtype), jnp.int32(0),
                  jnp.int32(0), jnp.int32(MAXITER), lu.fail)
    x, _, n_outer, n_gmres, status, _ = lax.while_loop(cond, body, init_state)
    status = jnp.where(lu.fail, FAILED, status)

    # Final metrics in the carrier (true fp64), Eq. 17, with the
    # executor-invariant residual schedule (see carrier_residual).
    res = carrier_residual(A, b, x)
    res_norm = _inf_norm(res)
    normA = jnp.max(tree_sum(jnp.abs(A), axis=1))
    ferr = _inf_norm(x - x_true) / _inf_norm(x_true)
    nbe = res_norm / (normA * _inf_norm(x) + _inf_norm(b))
    bad = ~jnp.isfinite(ferr)
    ferr = jnp.where(bad, jnp.asarray(jnp.inf, dtype), ferr)
    nbe = jnp.where(jnp.isfinite(nbe), nbe, jnp.asarray(jnp.inf, dtype))
    return SolveStats(ferr, nbe, n_outer, n_gmres, status, res_norm)


# The backend is resolved *before* tracing and passed as a value-hashed
# static argument: one executable per (shapes, cfg, backend), with the
# action's format ids still runtime data (DESIGN.md §3.4, §6.3). The
# jitted inner functions are module-level so tests can assert their
# compile-cache size stays at one across precision actions.
_gmres_ir_jit = partial(jax.jit, static_argnames=("cfg", "backend"))(
    _gmres_ir_impl)


@partial(jax.jit, static_argnames=("cfg", "backend"))
def _gmres_ir_batch_jit(A, b, x_true, actions, cfg, backend) -> SolveStats:
    return jax.vmap(lambda Ai, bi, xi, ai:
                    _gmres_ir_impl(Ai, bi, xi, ai, cfg, backend)
                    )(A, b, x_true, actions)


def gmres_ir(A: jnp.ndarray, b: jnp.ndarray, x_true: jnp.ndarray,
             action: jnp.ndarray, cfg: IRConfig = IRConfig(),
             backend=None) -> SolveStats:
    """Solve A x = b with GMRES-IR under precision action (u_f, u, u_g, u_r).

    A: (n, n) carrier (float64 for the paper's host experiments; the
    pallas backend coerces to its f32 TPU carrier); action: int32[4]
    runtime format ids. `backend` selects the precision backend
    (DESIGN.md §6): an instance, a registry name, or None = default.
    """
    bk = resolve_backend(backend)
    A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                             jnp.asarray(x_true))
    return _gmres_ir_jit(A, b, x_true, action, cfg, bk)


def gmres_ir_batch(A, b, x_true, actions, cfg: IRConfig = IRConfig(),
                   backend=None) -> SolveStats:
    """Batched (vmap) GMRES-IR: one episode sweep = one call."""
    bk = resolve_backend(backend)
    A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                             jnp.asarray(x_true))
    return _gmres_ir_batch_jit(A, b, x_true, actions, cfg, bk)


def gmres_ir_batch_lowerable(cfg: IRConfig = IRConfig(), backend=None):
    """`gmres_ir_batch` in `core.executor.LowerableCall` form: the same
    eager carrier coercion (`prepare`) around the same module-level
    jitted entry point, but AOT-compilable — `lower().compile()` per
    shape — and value-keyed by (entry point, cfg, backend), so every
    task and call site running this program shares one executable per
    shape (DESIGN.md §12)."""
    from repro.core.executor import LowerableCall
    bk = resolve_backend(backend)

    def prepare(A, b, x_true, actions):
        A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                                 jnp.asarray(x_true))
        return A, b, x_true, jnp.asarray(actions)

    return LowerableCall(_gmres_ir_batch_jit,
                         (("cfg", cfg), ("backend", bk)), prepare)
