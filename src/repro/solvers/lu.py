"""LU factorization with partial pivoting, in emulated precision u_f.

Strict mode (default, paper-faithful) mirrors Carson–Higham-style chopped
simulation: one rank-1 trailing update per column, with multiplication
results and subtraction results rounded to the target format; accumulation
of the (single) product happens in the carrier. The format id is runtime
data, so one compiled factorization serves every precision action.

Blocked mode (`lu_factor_blocked`) is the beyond-paper performance variant:
panels of `block` columns are factored strictly (partial pivoting restricted
to the panel), the panel's U12 row block is formed by a strict block
forward substitution, and the trailing update A22 -= L21 @ U12 is ONE
fused chopped GEMM dispatched through `backend.chop_matmul` (operands in
format, carrier accumulation — the semantics of tensor-core / MXU
mixed-precision GEMM hardware). The GEMM's lane-padded single-K-block
reduction contract keeps the jnp and pallas backends bit-identical
(DESIGN.md §6.2); everything else in the factorization is shared trace.
Sizes that are not a block multiple are identity-padded internally —
the padded tail factors trivially (L = U = I) and never couples back.

`lu_factor_auto` picks the path by size: blocked at
`blocking.min_n` and above, strict below (DESIGN.md §6.4). The outer
block loop is unrolled in Python (`n` is static at trace time), so every
panel/trailing slice is static and XLA sees O(n * block) panel work plus
one GEMM per panel instead of the strict path's O(n^2)-per-column masked
updates — this is what makes the factorization phase run at hardware
speed while the format id stays runtime data.

Failure signalling (the paper's `f_penalty` failure source): a zero pivot or
non-finite entry (overflow in a narrow format) sets `fail`; downstream code
short-circuits and the reward assigns the failure penalty.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend

from .blocking import resolve_blocking


class LUFactors(NamedTuple):
    lu: jnp.ndarray       # combined: strictly-lower L (unit diag), upper U
    perm: jnp.ndarray     # row permutation: P A = L U  with  (PA)[i] = A[perm[i]]
    fail: jnp.ndarray     # bool: zero pivot or non-finite (overflow) factor


def lu_factor(A: jnp.ndarray, fmt_id, backend=None) -> LUFactors:
    """Chopped right-looking LU with partial pivoting. A: (n, n) carrier."""
    chop = resolve_backend(backend).chop
    n = A.shape[-1]
    rows = jnp.arange(n)
    A0 = chop(A, fmt_id)

    def step(k, carry):
        A, perm, pivmin = carry
        col = jnp.take(A, k, axis=1)
        mag = jnp.where(rows >= k, jnp.abs(col), -jnp.inf)
        p = jnp.argmax(mag)
        # Swap rows k <-> p (A and the permutation record).
        rk, rp = A[k], A[p]
        A = A.at[k].set(rp).at[p].set(rk)
        ek, ep = perm[k], perm[p]
        perm = perm.at[k].set(ep).at[p].set(ek)

        pivot = A[k, k]
        pivmin = jnp.minimum(pivmin, jnp.abs(pivot))
        safe = jnp.where(pivot == 0, jnp.ones((), A.dtype), pivot)
        col = jnp.take(A, k, axis=1)
        factors = jnp.where(rows > k, chop(col / safe, fmt_id),
                            jnp.zeros((), A.dtype))
        rowk = A[k]
        prod = chop(factors[:, None] * rowk[None, :], fmt_id)
        upd = (rows[:, None] > k) & (rows[None, :] > k)
        A = jnp.where(upd, chop(A - prod, fmt_id), A)
        A = A.at[:, k].set(jnp.where(rows > k, factors, col))
        return A, perm, pivmin

    A1, perm, pivmin = lax.fori_loop(
        0, n, step, (A0, rows, jnp.asarray(jnp.inf, A.dtype)))
    fail = (pivmin == 0) | ~jnp.all(jnp.isfinite(A1))
    return LUFactors(A1, perm, fail)


def lu_factor_blocked(A: jnp.ndarray, fmt_id, block: int = 64,
                      backend=None) -> LUFactors:
    """Blocked variant: strict panel factorization + one fused chopped-GEMM
    trailing update per panel, dispatched through `backend.chop_matmul`
    (MXU semantics, bit-identical across backends — DESIGN.md §6.2/§6.4).
    Pivoting is restricted to the panel (standard blocked partial
    pivoting). Sizes that are not a block multiple are identity-padded
    internally; the returned factors are sliced back to (n, n)."""
    from repro.kernels.trisolve.ref import identity_pad

    bk = resolve_backend(backend)
    chop = bk.chop
    n = A.shape[-1]
    n_pad = -(-n // block) * block
    # Identity tail (shared convention with the blocked trisolve):
    # factors trivially (pivot 1, zero updates) and never couples back
    # into the leading n x n factorization.
    A = identity_pad(A, n_pad)
    rows = jnp.arange(n_pad)
    A0 = chop(A, fmt_id)
    carry = (A0, rows, jnp.asarray(jnp.inf, A.dtype))

    def make_panel_col(k0):
        # Strict rank-1 elimination of column k, with the update sliced
        # to the static panel window [k0, k0 + block): O(n * block) per
        # column instead of the strict path's O(n^2).
        pcols = k0 + jnp.arange(block)

        def panel_col(k, carry):
            A, perm, pivmin = carry
            col = jnp.take(A, k, axis=1)
            mag = jnp.where(rows >= k, jnp.abs(col), -jnp.inf)
            p = jnp.argmax(mag)
            rk, rp = A[k], A[p]
            A = A.at[k].set(rp).at[p].set(rk)
            ek, ep = perm[k], perm[p]
            perm = perm.at[k].set(ep).at[p].set(ek)
            pivot = A[k, k]
            pivmin = jnp.minimum(pivmin, jnp.abs(pivot))
            safe = jnp.where(pivot == 0, jnp.ones((), A.dtype), pivot)
            col = jnp.take(A, k, axis=1)
            factors = jnp.where(rows > k, chop(col / safe, fmt_id),
                                jnp.zeros((), A.dtype))
            panel = lax.slice(A, (0, k0), (n_pad, k0 + block))
            rowk = lax.dynamic_slice(panel, (k, 0), (1, block))
            prod = chop(factors[:, None] * rowk, fmt_id)
            upd = (rows[:, None] > k) & (pcols[None, :] > k)
            panel = jnp.where(upd, chop(panel - prod, fmt_id), panel)
            A = lax.dynamic_update_slice(A, panel, (0, k0))
            A = A.at[:, k].set(jnp.where(rows > k, factors, col))
            return A, perm, pivmin

        return panel_col

    # The block loop is unrolled in Python (n is static at trace time),
    # so every panel/trailing slice below is static-shaped.
    for k0 in range(0, n_pad, block):
        carry = lax.fori_loop(k0, k0 + block, make_panel_col(k0), carry)
        k1 = k0 + block
        m = n_pad - k1
        if m == 0:
            continue
        A1, perm, pivmin = carry
        tri = jnp.tril(jnp.ones((block, block), bool), -1)
        Lpan = jnp.where(tri, A1[k0:k1, k0:k1], jnp.zeros((), A1.dtype))
        A12 = A1[k0:k1, k1:]

        # U12 = (I + Lpan)^{-1} A12 by strict block forward substitution
        # (shared trace on every backend: plain jnp + bit-exact chop).
        def tri_row(i, U12):
            lrow = lax.dynamic_slice(Lpan, (i, 0), (1, block))
            acc = chop(lrow @ U12, fmt_id)
            new = chop(lax.dynamic_slice(A12, (i, 0), (1, m)) - acc,
                       fmt_id)
            return lax.dynamic_update_slice(U12, new, (i, 0))

        U12 = lax.fori_loop(0, block, tri_row,
                            jnp.zeros((block, m), A1.dtype))
        # Trailing update: A22 -= L21 @ U12 as ONE fused chopped GEMM
        # through the backend (lane-padded K contract, DESIGN.md §6.2).
        prod = bk.chop_matmul(A1[k1:, k0:k1], U12, fmt_id)
        A22 = chop(A1[k1:, k1:] - prod, fmt_id)
        A1 = A1.at[k0:k1, k1:].set(U12).at[k1:, k1:].set(A22)
        carry = (A1, perm, pivmin)

    A1, perm, pivmin = carry
    A1, perm = A1[:n, :n], perm[:n]
    fail = (pivmin == 0) | ~jnp.all(jnp.isfinite(A1))
    return LUFactors(A1, perm, fail)


def lu_factor_auto(A: jnp.ndarray, fmt_id, backend=None,
                   blocking=None) -> LUFactors:
    """Size-dispatched factorization: blocked panel LU above the policy
    threshold, the strict paper-faithful row loop below (DESIGN.md §6.4).
    The branch is on the static shape, so each size bucket still compiles
    exactly one executable with the format id as runtime data."""
    pol = resolve_blocking(blocking)
    if pol.use_blocked(A.shape[-1]):
        return lu_factor_blocked(A, fmt_id, block=pol.lu_block,
                                 backend=backend)
    return lu_factor(A, fmt_id, backend=backend)
