"""LU factorization with partial pivoting, in emulated precision u_f.

Strict mode (default, paper-faithful) mirrors Carson–Higham-style chopped
simulation: one rank-1 trailing update per column, with multiplication
results and subtraction results rounded to the target format; accumulation
of the (single) product happens in the carrier. The format id is runtime
data, so one compiled factorization serves every precision action.

Blocked mode (`block= b > 1`) is the beyond-paper performance variant used by
the §Perf hillclimb: panels are factored strictly, but the trailing update is
a single chopped GEMM (products in format, carrier accumulation) — exactly
the semantics of tensor-core / MXU mixed-precision GEMM hardware.

Failure signalling (the paper's `f_penalty` failure source): a zero pivot or
non-finite entry (overflow in a narrow format) sets `fail`; downstream code
short-circuits and the reward assigns the failure penalty.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend


class LUFactors(NamedTuple):
    lu: jnp.ndarray       # combined: strictly-lower L (unit diag), upper U
    perm: jnp.ndarray     # row permutation: P A = L U  with  (PA)[i] = A[perm[i]]
    fail: jnp.ndarray     # bool: zero pivot or non-finite (overflow) factor


def lu_factor(A: jnp.ndarray, fmt_id, backend=None) -> LUFactors:
    """Chopped right-looking LU with partial pivoting. A: (n, n) carrier."""
    chop = resolve_backend(backend).chop
    n = A.shape[-1]
    rows = jnp.arange(n)
    A0 = chop(A, fmt_id)

    def step(k, carry):
        A, perm, pivmin = carry
        col = jnp.take(A, k, axis=1)
        mag = jnp.where(rows >= k, jnp.abs(col), -jnp.inf)
        p = jnp.argmax(mag)
        # Swap rows k <-> p (A and the permutation record).
        rk, rp = A[k], A[p]
        A = A.at[k].set(rp).at[p].set(rk)
        ek, ep = perm[k], perm[p]
        perm = perm.at[k].set(ep).at[p].set(ek)

        pivot = A[k, k]
        pivmin = jnp.minimum(pivmin, jnp.abs(pivot))
        safe = jnp.where(pivot == 0, jnp.ones((), A.dtype), pivot)
        col = jnp.take(A, k, axis=1)
        factors = jnp.where(rows > k, chop(col / safe, fmt_id),
                            jnp.zeros((), A.dtype))
        rowk = A[k]
        prod = chop(factors[:, None] * rowk[None, :], fmt_id)
        upd = (rows[:, None] > k) & (rows[None, :] > k)
        A = jnp.where(upd, chop(A - prod, fmt_id), A)
        A = A.at[:, k].set(jnp.where(rows > k, factors, col))
        return A, perm, pivmin

    A1, perm, pivmin = lax.fori_loop(
        0, n, step, (A0, rows, jnp.asarray(jnp.inf, A.dtype)))
    fail = (pivmin == 0) | ~jnp.all(jnp.isfinite(A1))
    return LUFactors(A1, perm, fail)


def lu_factor_blocked(A: jnp.ndarray, fmt_id, block: int = 32,
                      backend=None) -> LUFactors:
    """Blocked variant: strict panel factorization + chopped-GEMM trailing
    update (MXU semantics). Pivoting is restricted to the panel (standard
    blocked partial pivoting). Requires n % block == 0."""
    chop = resolve_backend(backend).chop
    n = A.shape[-1]
    assert n % block == 0, "pad to a multiple of the block size"
    rows = jnp.arange(n)
    A0 = chop(A, fmt_id)

    def panel_col(k, carry):
        A, perm, pivmin = carry
        col = jnp.take(A, k, axis=1)
        mag = jnp.where(rows >= k, jnp.abs(col), -jnp.inf)
        p = jnp.argmax(mag)
        rk, rp = A[k], A[p]
        A = A.at[k].set(rp).at[p].set(rk)
        ek, ep = perm[k], perm[p]
        perm = perm.at[k].set(ep).at[p].set(ek)
        pivot = A[k, k]
        pivmin = jnp.minimum(pivmin, jnp.abs(pivot))
        safe = jnp.where(pivot == 0, jnp.ones((), A.dtype), pivot)
        col = jnp.take(A, k, axis=1)
        factors = jnp.where(rows > k, chop(col / safe, fmt_id),
                            jnp.zeros((), A.dtype))
        # Rank-1 update restricted to the panel's column range [k+1, kb+block)
        kb_end = (k // block + 1) * block
        cols = jnp.arange(n)
        rowk = A[k]
        prod = chop(factors[:, None] * rowk[None, :], fmt_id)
        upd = (rows[:, None] > k) & (cols[None, :] > k) & (cols[None, :] < kb_end)
        A = jnp.where(upd, chop(A - prod, fmt_id), A)
        A = A.at[:, k].set(jnp.where(rows > k, factors, col))
        return A, perm, pivmin

    def block_step(kb, carry):
        A, perm, pivmin = carry
        k0 = kb * block
        A, perm, pivmin = lax.fori_loop(k0, k0 + block, panel_col,
                                        (A, perm, pivmin))
        # Trailing update: A22 -= L21 @ U12 as one chopped GEMM.
        cols = jnp.arange(n)
        in_panel_c = (cols >= k0) & (cols < k0 + block)
        below = rows >= k0 + block
        right = cols >= k0 + block
        L21 = jnp.where(below[:, None] & in_panel_c[None, :], A,
                        jnp.zeros((), A.dtype))          # (n, n) masked
        # U12 rows in panel, columns right of panel. First compute
        # U12 = L11^{-1} A12 via the unit-lower panel triangle:
        in_panel_r = (rows >= k0) & (rows < k0 + block)
        Lpan = jnp.where(in_panel_r[:, None] & in_panel_c[None, :] &
                         (rows[:, None] > cols[None, :]), A,
                         jnp.zeros((), A.dtype))
        A12 = jnp.where(in_panel_r[:, None] & right[None, :], A,
                        jnp.zeros((), A.dtype))
        # Solve (I + Lpan) U12 = A12 by block forward substitution done as
        # `block` masked steps folded into a matmul-free update is O(b n^2);
        # instead use the Neumann-free exact loop:
        def tri_row(i, U12):
            r = k0 + i
            lrow = jnp.take(Lpan, r, axis=0)
            acc = chop(lrow @ U12, fmt_id)
            new = chop(jnp.take(A12, r, axis=0) - acc, fmt_id)
            return U12.at[r].set(jnp.where(right, new, U12[r]))
        U12 = lax.fori_loop(0, block, tri_row, jnp.zeros_like(A))
        prod = chop(chop(L21, fmt_id) @ chop(U12, fmt_id), fmt_id)
        A = jnp.where(below[:, None] & right[None, :], chop(A - prod, fmt_id), A)
        A = jnp.where(in_panel_r[:, None] & right[None, :], U12, A)
        return A, perm, pivmin

    A1, perm, pivmin = lax.fori_loop(
        0, n // block, block_step, (A0, rows, jnp.asarray(jnp.inf, A.dtype)))
    fail = (pivmin == 0) | ~jnp.all(jnp.isfinite(A1))
    return LUFactors(A1, perm, fail)
