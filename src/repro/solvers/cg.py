"""Preconditioned-CG iterative refinement (CG-IR) with per-step precisions.

The second instantiation of the autotuning recipe (cf. "Mixed-Precision
CG Solvers with RL-Driven Precision Tuning", arXiv 2504.14268): the same
outer iterative-refinement loop as `ir.gmres_ir`, but the correction
equation A z = r is solved by LU-preconditioned conjugate gradients in
the working precision instead of GMRES. Intended for SPD systems (the
sparse SPD generator in `data.matrices`); a breakdown of the CG
recurrence (non-positive curvature p^T A p, non-finite iterates) maps to
the explicit failure path, exactly like an overflowed LU in GMRES-IR.

Action a = (u_f, u, u_g, u_r) — four runtime format ids with the same
roles as GMRES-IR:
  u_f : LU factorization (used as the CG preconditioner M = LU)
  u   : solution update x_{i+1} = x_i + z_i
  u_g : CG working precision (matvec, preconditioner solves, dots)
  u_r : residual computation r_i = b - A x_i

Stopping criteria mirror `ir.IRConfig` (Eqs. 14-16): update-norm
convergence, stagnation, max outer iterations, explicit failure.

Everything is jit-compatible with runtime format ids and vmappable over
(systems x actions) — `cg_ir_batch` is the fixed-shape batched entry
point used by `repro.tasks.cg_ir.CGIRTask`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend, rounding_unit, tree_sum

from .blocking import DEFAULT_BLOCKING, BlockingPolicy, resolve_blocking
from .carrier import carrier_norm, carrier_residual
from .gmres import chop_mv
from .ir import CONVERGED, FAILED, MAXITER, STAGNATED
from .lu import lu_factor_auto
from .triangular import lu_solve


@dataclasses.dataclass(frozen=True)
class CGConfig:
    tau: float = 1e-6          # convergence tolerance (benchmark parameter)
    i_max: int = 10            # max outer (refinement) iterations
    m_max: int = 50            # max inner CG iterations
    tol_inner: float = 1e-4    # CG relative residual tolerance
    stag_tol: float = 0.9      # stagnation threshold on ||z_i||/||z_{i-1}||
    # Blocked LU/trisolve engagement (DESIGN.md §6.4), static jit key.
    blocking: BlockingPolicy = DEFAULT_BLOCKING


class CGStats(NamedTuple):
    ferr: jnp.ndarray          # normwise relative forward error (Eq. 17)
    nbe: jnp.ndarray           # normwise relative backward error (Eq. 17)
    n_outer: jnp.ndarray       # refinement iterations performed
    n_cg: jnp.ndarray          # total inner CG iterations
    status: jnp.ndarray        # CONVERGED/STAGNATED/MAXITER/FAILED
    res_norm: jnp.ndarray      # final ||b - A x||_inf


class PCGResult(NamedTuple):
    z: jnp.ndarray             # solution update
    iters: jnp.ndarray         # inner iterations performed
    fail: jnp.ndarray          # breakdown (non-SPD curvature / non-finite)


def _inf_norm(v):
    return jnp.max(jnp.abs(v))


def _dot(a, b, fmt_id, chop):
    """Dot product with format-rounded products, carrier accumulation
    (order pinned by the fixed pairwise tree — DESIGN.md §7.3)."""
    return chop(tree_sum(chop(a * b, fmt_id)), fmt_id)


def pcg(A_g: jnp.ndarray, LU: jnp.ndarray, perm: jnp.ndarray,
        r: jnp.ndarray, fmt_g, *, m_max: int, tol: float,
        backend=None, blocking=None) -> PCGResult:
    """LU-preconditioned CG on A z = r, entirely in precision u_g.

    A_g: the system matrix pre-chopped to u_g; LU/perm: chopped factors
    of A in u_f, used as the (fixed) preconditioner.
    """
    bk = resolve_backend(backend)
    pol = resolve_blocking(blocking)
    A_g, LU, r = bk.coerce(jnp.asarray(A_g), jnp.asarray(LU),
                           jnp.asarray(r))
    chop = bk.chop
    dtype = r.dtype
    r0 = chop(r, fmt_g)
    beta0 = carrier_norm(r0)
    ok0 = jnp.isfinite(beta0) & (beta0 > 0)
    y0 = lu_solve(LU, perm, r0, fmt_g, backend=bk, blocking=pol)
    rho0 = _dot(r0, y0, fmt_g, chop)
    z0 = jnp.zeros_like(r0)

    def cond(state):
        *_, j, done, _fail = state
        return (~done) & (j < m_max)

    def body(state):
        z, rin, p, rho, j, done, fail = state
        q = bk.chop_mv(A_g, p, fmt_g)
        pq = _dot(p, q, fmt_g, chop)
        # Non-positive curvature: A (or the chopped recurrence) stopped
        # behaving SPD — a genuine CG breakdown, not mere stagnation.
        breakdown = (pq <= 0) | ~jnp.isfinite(pq)
        pq_safe = jnp.where(breakdown, jnp.ones((), dtype), pq)
        alpha = chop(rho / pq_safe, fmt_g)
        z_new = chop(z + chop(alpha * p, fmt_g), fmt_g)
        rin_new = chop(rin - chop(alpha * q, fmt_g), fmt_g)
        res = carrier_norm(rin_new)
        y = lu_solve(LU, perm, rin_new, fmt_g, backend=bk, blocking=pol)
        rho_new = _dot(rin_new, y, fmt_g, chop)
        rho_safe = jnp.where(rho == 0, jnp.ones((), dtype), rho)
        beta = chop(rho_new / rho_safe, fmt_g)
        p_new = chop(y + chop(beta * p, fmt_g), fmt_g)

        nonfinite = ~(jnp.all(jnp.isfinite(z_new)) & jnp.isfinite(res)
                      & jnp.isfinite(rho_new))
        fail_now = breakdown | nonfinite
        converged = res <= tol * beta0
        z_new = jnp.where(fail_now, z, z_new)
        return (z_new, rin_new, p_new, rho_new, j + 1,
                fail_now | converged, fail | fail_now)

    init = (z0, r0, y0, rho0, jnp.int32(0), ~ok0, ~ok0)
    z, _, _, _, j, _, fail = lax.while_loop(cond, body, init)
    fail = fail | ~jnp.all(jnp.isfinite(z))
    z = jnp.where(fail, jnp.zeros_like(z), z)
    return PCGResult(z, j, fail)


def _cg_ir_impl(A, b, x_true, action, cfg, backend) -> CGStats:
    dtype = A.dtype
    chop = backend.chop
    uf, u, ug, ur = action[0], action[1], action[2], action[3]

    lu = lu_factor_auto(A, uf, backend=backend, blocking=cfg.blocking)
    A_g = chop(A, ug)
    A_r = chop(A, ur)
    b_r = chop(b, ur)
    x0 = jnp.zeros_like(b)

    u_work = rounding_unit(u, dtype)
    conv_tol = jnp.maximum(jnp.asarray(cfg.tau, dtype), u_work)

    def cond(state):
        *_, done = state
        return ~done

    def body(state):
        x, znorm_prev, i, n_cg, status, done = state
        r = chop(b_r - chop_mv(A_r, x, ur, backend=backend), ur)
        cg = pcg(A_g, lu.lu, lu.perm, r, ug, m_max=cfg.m_max,
                 tol=cfg.tol_inner, backend=backend,
                 blocking=cfg.blocking)
        z = chop(cg.z, u)
        x_new = chop(x + z, u)
        znorm = _inf_norm(z)
        xnorm = _inf_norm(x_new)
        i_new = i + 1

        converged = znorm <= conv_tol * xnorm
        stagnated = (i > 0) & (znorm >= cfg.stag_tol * znorm_prev)
        hit_max = i_new >= cfg.i_max
        failed = cg.fail | ~jnp.all(jnp.isfinite(x_new))

        status = jnp.where(
            failed, FAILED,
            jnp.where(converged, CONVERGED,
                      jnp.where(stagnated, STAGNATED,
                                jnp.where(hit_max, MAXITER, status))))
        done = converged | stagnated | hit_max | failed
        x_new = jnp.where(failed, x, x_new)
        return (x_new, znorm, i_new, n_cg + cg.iters, status, done)

    init_state = (x0, jnp.asarray(jnp.inf, dtype), jnp.int32(0),
                  jnp.int32(0), jnp.int32(MAXITER), lu.fail)
    x, _, n_outer, n_cg, status, _ = lax.while_loop(cond, body, init_state)
    status = jnp.where(lu.fail, FAILED, status)

    # Final metrics in the carrier (true fp64), Eq. 17, with the
    # executor-invariant residual schedule (ir.carrier_residual).
    res = carrier_residual(A, b, x)
    res_norm = _inf_norm(res)
    normA = jnp.max(tree_sum(jnp.abs(A), axis=1))
    ferr = _inf_norm(x - x_true) / _inf_norm(x_true)
    nbe = res_norm / (normA * _inf_norm(x) + _inf_norm(b))
    ferr = jnp.where(jnp.isfinite(ferr), ferr, jnp.asarray(jnp.inf, dtype))
    nbe = jnp.where(jnp.isfinite(nbe), nbe, jnp.asarray(jnp.inf, dtype))
    return CGStats(ferr, nbe, n_outer, n_cg, status, res_norm)


# Backend resolved before tracing, passed value-hashed static: one
# executable per (shapes, cfg, backend), format ids runtime data
# (DESIGN.md §3.4, §6.3). Module-level jits so tests can assert the
# compile-cache stays at one across precision actions.
_cg_ir_jit = partial(jax.jit, static_argnames=("cfg", "backend"))(
    _cg_ir_impl)


@partial(jax.jit, static_argnames=("cfg", "backend"))
def _cg_ir_batch_jit(A, b, x_true, actions, cfg, backend) -> CGStats:
    return jax.vmap(lambda Ai, bi, xi, ai:
                    _cg_ir_impl(Ai, bi, xi, ai, cfg, backend)
                    )(A, b, x_true, actions)


def cg_ir(A: jnp.ndarray, b: jnp.ndarray, x_true: jnp.ndarray,
          action: jnp.ndarray, cfg: CGConfig = CGConfig(),
          backend=None) -> CGStats:
    """Solve A x = b with CG-IR under precision action (u_f, u, u_g, u_r).

    A: (n, n) carrier (SPD; float64 on the host, f32 when the pallas
    backend coerces); action: int32[4] runtime format ids. `backend`
    selects the precision backend (DESIGN.md §6)."""
    bk = resolve_backend(backend)
    A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                             jnp.asarray(x_true))
    return _cg_ir_jit(A, b, x_true, action, cfg, bk)


def cg_ir_batch(A, b, x_true, actions, cfg: CGConfig = CGConfig(),
                backend=None) -> CGStats:
    """Batched (vmap) CG-IR: one fixed-shape chunk = one call."""
    bk = resolve_backend(backend)
    A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                             jnp.asarray(x_true))
    return _cg_ir_batch_jit(A, b, x_true, actions, cfg, bk)


def cg_ir_batch_lowerable(cfg: CGConfig = CGConfig(), backend=None):
    """`cg_ir_batch` in `core.executor.LowerableCall` form — same eager
    coercion, same jitted entry point, AOT-compilable and value-keyed
    for cross-task executable dedupe (DESIGN.md §12)."""
    from repro.core.executor import LowerableCall
    bk = resolve_backend(backend)

    def prepare(A, b, x_true, actions):
        A, b, x_true = bk.coerce(jnp.asarray(A), jnp.asarray(b),
                                 jnp.asarray(x_true))
        return A, b, x_true, jnp.asarray(actions)

    return LowerableCall(_cg_ir_batch_jit,
                         (("cfg", cfg), ("backend", bk)), prepare)


# Re-exported status codes (shared convention with ir.py / core.task).
__all__ = ["CGConfig", "CGStats", "PCGResult", "pcg", "cg_ir",
           "cg_ir_batch", "cg_ir_batch_lowerable",
           "CONVERGED", "STAGNATED", "MAXITER", "FAILED"]
