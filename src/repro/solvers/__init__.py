"""Mixed-precision linear-solver substrate (GMRES-IR and CG-IR)."""
from .block_autotune import sweep_lu_block, tuned_blocking
from .blocking import (DEFAULT_BLOCKING, STRICT_ONLY, BlockingPolicy,
                       resolve_blocking)
from .cg import (CGConfig, CGStats, PCGResult, cg_ir, cg_ir_batch,
                 cg_ir_batch_lowerable, pcg)
from .gmres import GMRESResult, chop_mv, gmres_precond
from .ir import (CONVERGED, FAILED, MAXITER, STAGNATED, IRConfig, SolveStats,
                 gmres_ir, gmres_ir_batch, gmres_ir_batch_lowerable)
from .lu import LUFactors, lu_factor, lu_factor_auto, lu_factor_blocked
from .metrics import (CONDITION_RANGES, bucket_by_condition, eps_max,
                      success_rate, summarize)
from .triangular import lu_solve, solve_unit_lower, solve_upper

__all__ = [
    "GMRESResult", "chop_mv", "gmres_precond", "IRConfig", "SolveStats",
    "gmres_ir", "gmres_ir_batch", "gmres_ir_batch_lowerable",
    "CGConfig", "CGStats", "PCGResult",
    "pcg", "cg_ir", "cg_ir_batch", "cg_ir_batch_lowerable",
    "LUFactors", "lu_factor",
    "lu_factor_auto", "lu_factor_blocked", "lu_solve",
    "solve_unit_lower", "solve_upper",
    "BlockingPolicy", "DEFAULT_BLOCKING", "STRICT_ONLY", "resolve_blocking",
    "sweep_lu_block", "tuned_blocking",
    "CONVERGED", "STAGNATED", "MAXITER", "FAILED",
    "CONDITION_RANGES", "bucket_by_condition", "eps_max", "success_rate",
    "summarize",
]
