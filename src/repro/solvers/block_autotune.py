"""Startup sweep for the blocked-LU panel width (DESIGN.md §6.4).

`BlockingPolicy(lu_block=64)` was picked by one-off CPU measurement
(PR 4); the right width depends on the size bucket and the precision
backend. This module applies the bandit's own recipe to that knob:
measure every arm once, commit to the greedy winner, cache the
decision. `tuned_blocking(n_pad, backend)` times the blocked
factorization + both triangular substitutions for each candidate panel
width on a representative bucket-sized system and returns the base
policy with `lu_block` swapped for the fastest candidate. Results are
cached per (bucket, backend, base policy, candidates), so the sweep
runs once per process — a startup cost of a few compiles per bucket.

The tuned policy still rides the static jit key inside
`IRConfig`/`CGConfig` (one executable per bucket); note that panel
width is a *semantic* config, not only a schedule: partial pivoting is
restricted to the panel, so different widths produce (legitimately)
different factorizations. Tasks therefore opt in explicitly via
`tune_blocking=True` (`tasks.base.LinearSystemTask`).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.precision import FORMAT_ID, resolve_backend

from .blocking import BlockingPolicy, resolve_blocking

DEFAULT_CANDIDATES: Tuple[int, ...] = (32, 64, 128)

# (n_pad, backend name, base policy, candidates) -> tuned policy.
_CACHE: Dict[tuple, BlockingPolicy] = {}
# Raw sweep timings, kept for introspection/benchmark reporting.
_TIMINGS: Dict[tuple, Dict[int, float]] = {}


def _pipeline(A, b, fmt_id, block: int, trisolve_block: int, backend):
    """The factorization hot path a panel width governs: blocked LU +
    the two blocked triangular substitutions of one preconditioner
    application."""
    from .lu import lu_factor_blocked
    from .triangular import lu_solve
    pol = BlockingPolicy(min_n=0, lu_block=block,
                         trisolve_block=trisolve_block)
    f = lu_factor_blocked(A, fmt_id, block=block, backend=backend)
    return lu_solve(f.lu, f.perm, b, fmt_id, backend=backend, blocking=pol)


def sweep_lu_block(n_pad: int, backend=None,
                   candidates: Sequence[int] = DEFAULT_CANDIDATES,
                   trisolve_block: int = 128, repeats: int = 3,
                   seed: int = 0) -> Dict[int, float]:
    """Wall-time per candidate panel width (seconds, best of `repeats`)
    for an `n_pad`-sized factorization + solve on `backend`. Compile
    time is excluded (one warmup call per candidate)."""
    bk = resolve_backend(backend)
    rng = np.random.default_rng(seed)
    # Diagonally dominant representative system: pivoting stays busy but
    # the factorization never hits the failure path mid-measurement.
    A = rng.standard_normal((n_pad, n_pad)) + n_pad * np.eye(n_pad)
    b = rng.standard_normal(n_pad)
    A, b = bk.coerce(*(jax.numpy.asarray(v) for v in (A, b)))
    fmt = FORMAT_ID["fp32"]
    times: Dict[int, float] = {}
    for block in candidates:
        if block > n_pad:        # wider than the matrix: pure waste
            continue
        fn = jax.jit(partial(_pipeline, block=int(block),
                             trisolve_block=int(trisolve_block),
                             backend=bk))
        fn(A, b, fmt).block_until_ready()          # compile outside timing
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(A, b, fmt).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        times[int(block)] = float(best)
    return times


def tuned_blocking(n_pad: int, backend=None,
                   base: Optional[BlockingPolicy] = None,
                   candidates: Sequence[int] = DEFAULT_CANDIDATES
                   ) -> BlockingPolicy:
    """`base` with `lu_block` replaced by the sweep winner for
    (`n_pad`, `backend`). Below the base policy's threshold (or with
    blocking disabled) the sweep is skipped — the strict path runs and
    the panel width is irrelevant."""
    pol = resolve_blocking(base)
    if not pol.use_blocked(n_pad):
        return pol
    bk = resolve_backend(backend)
    key = (int(n_pad), bk.name, pol, tuple(int(c) for c in candidates))
    if key not in _CACHE:
        times = sweep_lu_block(n_pad, backend=bk, candidates=candidates,
                               trisolve_block=pol.trisolve_block)
        _TIMINGS[key] = times
        if not times:
            _CACHE[key] = pol
        else:
            best = min(times, key=times.get)       # greedy over measured arms
            _CACHE[key] = dataclasses.replace(pol, lu_block=best)
    return _CACHE[key]


def sweep_timings() -> Dict[tuple, Dict[int, float]]:
    """Raw timings of every sweep this process ran (for reporting)."""
    return dict(_TIMINGS)
