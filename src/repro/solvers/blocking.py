"""Blocking policy for the factorization/substitution hot path.

The strict row-loop LU and triangular solves are paper-faithful but
O(n) sequential; above a size threshold the solvers switch to the
blocked variants (panel-pivoted LU with a chopped-GEMM trailing update,
block-triangular substitution with fused chopped-matvec off-diagonal
tiles — DESIGN.md §6.4). The policy is a tiny frozen dataclass so it
hashes by value and rides inside `IRConfig`/`CGConfig` as part of the
static jit key: changing thresholds or block sizes compiles a new
executable, while the format id stays runtime data (DESIGN.md §3.4).

Defaults: sizes are bucketed to multiples of 128 by `core.batching`, so
`trisolve_block=128` divides every bucketed size that crosses the
`min_n=256` threshold and `lu_block=64` keeps the panel cheap while the
trailing GEMM (lane-padded K, DESIGN.md §6.2) does the O(n^3) work.
Non-multiple sizes still take the blocked path — both blocked kernels
identity-pad to the next block multiple internally.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BlockingPolicy:
    """When and how the blocked factorization/substitution path engages.

    min_n: systems with n >= min_n take the blocked path (strict below).
    lu_block: LU panel width (strict panel, chopped-GEMM trailing update).
    trisolve_block: block-triangular substitution tile size.
    enabled: False forces the strict row-loop path at every size.
    """

    min_n: int = 256
    lu_block: int = 64
    trisolve_block: int = 128
    enabled: bool = True

    def use_blocked(self, n: int) -> bool:
        return self.enabled and n >= self.min_n


DEFAULT_BLOCKING = BlockingPolicy()
STRICT_ONLY = BlockingPolicy(enabled=False)


def resolve_blocking(blocking) -> BlockingPolicy:
    """None -> the default policy (mirrors `precision.resolve_backend`)."""
    return DEFAULT_BLOCKING if blocking is None else blocking
