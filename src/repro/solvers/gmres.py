"""Left-preconditioned MGS-GMRES in emulated precision u_g.

Solves M^{-1} A z = M^{-1} r with M = LU (chopped factors from lu.py),
entirely in precision u_g: the operator application (matvec + two triangular
solves), the modified Gram-Schmidt orthogonalization, and the Givens
least-squares recurrence are all executed with op-level rounding to the
runtime format id. Accumulations happen in the carrier dtype (MXU-style),
see DESIGN.md §3.5.

The hot-path rounding ops dispatch through a precision backend
(DESIGN.md §6): `chop_mv` is the backend's fused chopped matvec
(kernels/qmatmul on the pallas backend) and standalone roundings go
through `backend.chop` (kernels/chop for large arrays). The backend is
resolved before tracing and is a value-hashed static, so format ids stay
runtime data and precision actions never recompile (DESIGN.md §3.4).

Non-restarted, with a while_loop bounded by m_max; the residual estimate is
the standard |g_{j+1}| Givens recurrence, relative to the preconditioned
initial residual norm beta.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.precision import resolve_backend, tree_sum

from .blocking import resolve_blocking
from .carrier import carrier_norm
from .triangular import solve_unit_lower, solve_upper


class GMRESResult(NamedTuple):
    z: jnp.ndarray        # solution update
    iters: jnp.ndarray    # inner iterations performed
    res_rel: jnp.ndarray  # final relative (preconditioned) residual estimate
    fail: jnp.ndarray     # non-finite breakdown


def chop_mv(A: jnp.ndarray, v: jnp.ndarray, fmt_id,
            backend=None) -> jnp.ndarray:
    """Fused chopped matvec: operands rounded to the format, accumulation
    in the carrier, result rounded (FMA/MXU semantics — the matvec
    instantiation of kernels/qmatmul; see DESIGN.md §6.2). Operands are
    coerced to the backend's carrier dtype (no-op on the jnp oracle and
    on pre-coerced arrays)."""
    bk = resolve_backend(backend)
    A, v = bk.coerce(jnp.asarray(A), jnp.asarray(v))
    return bk.chop_mv(A, v, fmt_id)


def _precond(LU, perm, v, fmt_id, backend, blocking=None):
    # Preconditioner application M^{-1} v: the two triangular solves
    # take the blocked `chop_trisolve` path above the size threshold
    # (DESIGN.md §6.4) — this pair dominates GMRES-IR wall time.
    y = solve_unit_lower(LU, v[perm], fmt_id, backend=backend,
                         blocking=blocking)
    return solve_upper(LU, y, fmt_id, backend=backend, blocking=blocking)


def gmres_precond(A_g: jnp.ndarray, LU: jnp.ndarray, perm: jnp.ndarray,
                  r: jnp.ndarray, fmt_g, *, m_max: int,
                  tol: float, backend=None,
                  blocking=None) -> GMRESResult:
    """A_g: the system matrix pre-chopped to u_g. r: outer residual."""
    bk = resolve_backend(backend)
    pol = resolve_blocking(blocking)
    A_g, LU, r = bk.coerce(jnp.asarray(A_g), jnp.asarray(LU),
                           jnp.asarray(r))
    chop = bk.chop
    n = r.shape[-1]
    dtype = r.dtype
    zero = jnp.zeros((), dtype)

    def apply_op(v):
        return _precond(LU, perm, bk.chop_mv(A_g, v, fmt_g), fmt_g, bk,
                        pol)

    rhat = _precond(LU, perm, chop(r, fmt_g), fmt_g, bk, pol)
    # Unrounded carrier norms take the pinned square-then-sum schedule
    # (solvers/carrier.py) so their bits are executor-invariant.
    beta = carrier_norm(rhat)
    ok0 = jnp.isfinite(beta) & (beta > 0)
    beta_safe = jnp.where(ok0, beta, jnp.ones((), dtype))
    v0 = chop(rhat / beta_safe, fmt_g)

    V = jnp.zeros((m_max + 1, n), dtype).at[0].set(jnp.where(ok0, v0, zero))
    R = jnp.zeros((m_max + 1, m_max), dtype)
    cs = jnp.zeros((m_max,), dtype)
    sn = jnp.zeros((m_max,), dtype)
    g = jnp.zeros((m_max + 1,), dtype).at[0].set(beta)

    def cond(state):
        *_, j, done = state
        return (~done) & (j < m_max)

    def body(state):
        V, R, cs, sn, g, res_prev, j, done = state
        w = apply_op(V[j])

        def mgs(i, carry):
            w, h = carry
            vi = V[i]
            hij = chop(tree_sum(chop(w * vi, fmt_g)), fmt_g)
            w = chop(w - chop(hij * vi, fmt_g), fmt_g)
            return w, h.at[i].set(hij)

        w, h = lax.fori_loop(0, j + 1, mgs,
                             (w, jnp.zeros((m_max + 1,), dtype)))
        hn = carrier_norm(w)
        happy = hn <= jnp.asarray(1e-300 if dtype == jnp.float64 else 1e-30,
                                  dtype)
        hn_safe = jnp.where(happy, jnp.ones((), dtype), hn)
        V = V.at[j + 1].set(jnp.where(happy, jnp.zeros_like(w),
                                      chop(w / hn_safe, fmt_g)))
        h = h.at[j + 1].set(hn)

        def rot(i, h):
            hi, hi1 = h[i], h[i + 1]
            h = h.at[i].set(chop(cs[i] * hi + sn[i] * hi1, fmt_g))
            return h.at[i + 1].set(chop(-sn[i] * hi + cs[i] * hi1, fmt_g))

        h = lax.fori_loop(0, j, rot, h)
        hj, hj1 = h[j], h[j + 1]
        denom = jnp.sqrt(hj * hj + hj1 * hj1)
        dsafe = jnp.where(denom == 0, jnp.ones((), dtype), denom)
        c, s = hj / dsafe, hj1 / dsafe
        cs = cs.at[j].set(c)
        sn = sn.at[j].set(s)
        h = h.at[j].set(chop(denom, fmt_g)).at[j + 1].set(zero)
        R = R.at[:, j].set(h)
        gj = g[j]
        g = g.at[j].set(chop(c * gj, fmt_g)).at[j + 1].set(chop(-s * gj, fmt_g))

        res = jnp.abs(g[j + 1])
        fin = jnp.isfinite(res) & jnp.all(jnp.isfinite(h))
        # Stall cut: a useless preconditioner (e.g. overflowed low-precision
        # LU on an ill-conditioned system) makes the residual plateau; give
        # up once per-iteration reduction falls under 5% past a warmup.
        stalled = (j >= 4) & (res > 0.95 * res_prev)
        done = happy | (res <= tol * beta) | stalled | ~fin
        return V, R, cs, sn, g, res, j + 1, done

    init = (V, R, cs, sn, g, jnp.asarray(jnp.inf, dtype), jnp.int32(0), ~ok0)
    V, R, cs, sn, g, _, j, done = lax.while_loop(cond, body, init)

    # Back-substitute R y = g on the leading j x j block.
    def back(i, y):
        row = m_max - 1 - i
        rrow = R[row]
        prods = chop(rrow * y, fmt_g)
        mask = jnp.arange(m_max) > row
        ssum = tree_sum(jnp.where(mask, prods, zero))
        diag = rrow[row]
        dsafe = jnp.where(diag == 0, jnp.ones((), dtype), diag)
        yi = chop(chop(g[row] - ssum, fmt_g) / dsafe, fmt_g)
        return y.at[row].set(jnp.where(row < j, yi, zero))

    y = lax.fori_loop(0, m_max, back, jnp.zeros((m_max,), dtype))
    z = chop(tree_sum(chop(V[:m_max] * y[:, None], fmt_g), axis=0), fmt_g)

    res_rel = jnp.abs(g[j]) / beta_safe
    fail = ~ok0 | ~jnp.all(jnp.isfinite(z))
    z = jnp.where(fail, jnp.zeros_like(z), z)
    return GMRESResult(z, j, res_rel, fail)
