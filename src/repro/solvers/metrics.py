"""Evaluation metrics: Eq. 17 errors and the Eq. 28-30 success rate."""
from __future__ import annotations

from typing import Sequence

import numpy as np

# Condition ranges used throughout the paper's Section 5.
CONDITION_RANGES = {
    "low": (1e0, 1e3),
    "medium": (1e3, 1e6),
    "high": (1e6, 1e9),
}


def eps_max(ferr: np.ndarray, nbe: np.ndarray) -> np.ndarray:
    """eps_max(P, a) = max(ferr, nbe)."""
    return np.maximum(ferr, nbe)


def success_rate(ferr: np.ndarray, nbe: np.ndarray, kappa: np.ndarray,
                 tau_base: float) -> float:
    """Eq. 28-30: threshold tau_j = tau_base * median(kappa in range);
    success iff eps_max < tau_j. Computed over the provided (range-filtered)
    sample set."""
    if len(ferr) == 0:
        return float("nan")
    tau_j = tau_base * float(np.median(kappa))
    return float(np.mean(eps_max(ferr, nbe) < tau_j))


def bucket_by_condition(kappa: np.ndarray,
                        ranges=CONDITION_RANGES) -> dict:
    """Index sets per condition range."""
    out = {}
    for name, (lo, hi) in ranges.items():
        out[name] = np.where((kappa >= lo) & (kappa < hi))[0]
    return out


def summarize(ferr, nbe, n_outer, n_gmres, kappa, tau_base,
              ranges=CONDITION_RANGES) -> dict:
    """Per-condition-range summary matching the paper's table columns."""
    rows = {}
    for name, idx in bucket_by_condition(np.asarray(kappa), ranges).items():
        if len(idx) == 0:
            continue
        rows[name] = {
            "n": int(len(idx)),
            "xi": success_rate(np.asarray(ferr)[idx], np.asarray(nbe)[idx],
                               np.asarray(kappa)[idx], tau_base),
            "avg_ferr": float(np.mean(np.asarray(ferr)[idx])),
            "avg_nbe": float(np.mean(np.asarray(nbe)[idx])),
            "avg_iter": float(np.mean(np.asarray(n_outer)[idx])),
            "avg_gmres_iter": float(np.mean(np.asarray(n_gmres)[idx])),
        }
    return rows
