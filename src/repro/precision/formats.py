"""Floating-point format descriptors (paper Table 1, plus ML fp8 formats).

Each format is described by:
  t     — number of significand bits including the implicit leading bit
  emin  — exponent of the smallest positive normalized number
  emax  — exponent of the largest finite number
  xmax  — largest finite value (may deviate from (2-2^(1-t))·2^emax, e.g. OCP e4m3)
  saturate — on overflow, clamp to ±xmax instead of rounding to ±inf

Formats are addressable two ways:
  * statically, by name / FloatFormat object (compile-time specialization);
  * dynamically, by integer format id indexing the runtime tables below
    (precision-as-runtime-data: a single compiled program can apply any
    format, which is what lets the bandit explore actions without recompiles).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    name: str
    t: int          # significand bits incl. implicit bit
    emin: int
    emax: int
    xmax: float
    saturate: bool = False
    native_dtype: Optional[str] = None  # jnp dtype name when the host/TPU has it

    @property
    def unit_roundoff(self) -> float:
        return 2.0 ** (-self.t)

    @property
    def xmin(self) -> float:
        """Smallest positive normalized value."""
        return 2.0 ** self.emin

    @property
    def xmin_sub(self) -> float:
        """Smallest positive subnormal value."""
        return 2.0 ** (self.emin - (self.t - 1))

    @property
    def significand_bits(self) -> int:
        return self.t


def _ieee_xmax(t: int, emax: int) -> float:
    return float((2.0 - 2.0 ** (1 - t)) * 2.0 ** emax)


# ---------------------------------------------------------------------------
# Registry. Order defines the integer format id AND the precision ordering
# used by the paper's action-space reduction (Eq. 11): ids are sorted by
# increasing significand bits within the solver ladder.
# ---------------------------------------------------------------------------

E4M3 = FloatFormat("e4m3", t=4, emin=-6, emax=8, xmax=448.0, saturate=True)
E5M2 = FloatFormat("e5m2", t=3, emin=-14, emax=15, xmax=_ieee_xmax(3, 15), saturate=True)
BF16 = FloatFormat("bf16", t=8, emin=-126, emax=127, xmax=_ieee_xmax(8, 127),
                   native_dtype="bfloat16")
FP16 = FloatFormat("fp16", t=11, emin=-14, emax=15, xmax=_ieee_xmax(11, 15),
                   native_dtype="float16")
TF32 = FloatFormat("tf32", t=11, emin=-126, emax=127, xmax=_ieee_xmax(11, 127))
FP32 = FloatFormat("fp32", t=24, emin=-126, emax=127, xmax=_ieee_xmax(24, 127),
                   native_dtype="float32")
FP64 = FloatFormat("fp64", t=53, emin=-1022, emax=1023,
                   xmax=_ieee_xmax(53, 1023), native_dtype="float64")

# Id order: increasing significand bits (ties broken by range).
FORMAT_LIST: List[FloatFormat] = [E5M2, E4M3, BF16, FP16, TF32, FP32, FP64]
FORMATS: Dict[str, FloatFormat] = {f.name: f for f in FORMAT_LIST}
FORMAT_ID: Dict[str, int] = {f.name: i for i, f in enumerate(FORMAT_LIST)}

# The paper's solver precision ladder (Section 5.1), ordered by increasing
# significand bits — the ordering relation of Eq. 11.
SOLVER_LADDER: List[str] = ["bf16", "tf32", "fp32", "fp64"]
# The fp8-extended solver ladder: the ML fp8 formats prepended below the
# paper's four rungs (still ordered by significand bits — e5m2 t=3,
# e4m3 t=4). Their saturating overflow (clamp to +-xmax instead of inf)
# is what makes u_f = fp8 a *viable* arm on well-conditioned systems:
# an overflowed LU clamps rather than poisoning the factors with inf,
# so the refinement loop can still converge and the bandit can learn
# where the cheap factorization pays off.
SOLVER_LADDER_FP8: List[str] = ["e5m2", "e4m3"] + SOLVER_LADDER
# The TPU-native ladder used by the LM-framework integration (DESIGN.md §3.3).
TPU_LADDER: List[str] = ["e4m3", "bf16", "fp32"]


def get_format(fmt: Union[str, FloatFormat, int]) -> FloatFormat:
    if isinstance(fmt, FloatFormat):
        return fmt
    if isinstance(fmt, (int, np.integer)):
        return FORMAT_LIST[int(fmt)]
    return FORMATS[fmt]


def format_id(fmt: Union[str, FloatFormat, int]) -> int:
    if isinstance(fmt, (int, np.integer)):
        return int(fmt)
    return FORMAT_ID[get_format(fmt).name]


# ---------------------------------------------------------------------------
# Runtime tables (numpy; converted to jnp constants inside traced functions).
# Indexed by format id. These make `chop(x, fmt_id)` a single jittable
# program over all formats.
# ---------------------------------------------------------------------------

FMT_T = np.array([f.t for f in FORMAT_LIST], dtype=np.int32)
FMT_EMIN = np.array([f.emin for f in FORMAT_LIST], dtype=np.int32)
FMT_EMAX = np.array([f.emax for f in FORMAT_LIST], dtype=np.int32)
FMT_XMAX = np.array([f.xmax for f in FORMAT_LIST], dtype=np.float64)
FMT_SATURATE = np.array([f.saturate for f in FORMAT_LIST], dtype=np.bool_)
FMT_UNIT_ROUNDOFF = np.array([f.unit_roundoff for f in FORMAT_LIST],
                             dtype=np.float64)


def runtime_tables(dtype=jnp.float32):
    """Format parameter tables as jnp arrays for traced lookups."""
    return (
        jnp.asarray(FMT_T),
        jnp.asarray(FMT_EMIN),
        jnp.asarray(FMT_EMAX),
        jnp.asarray(FMT_XMAX, dtype=dtype),
        jnp.asarray(FMT_SATURATE),
    )
