"""Round-to-format emulation ("pychop in JAX").

Emulates storage in a reduced floating-point format while computing in a
wider *carrier* dtype (float32 on TPU, float64 on host for the paper's FP64
experiments). Rounding is round-to-nearest, ties-to-even (RNE), with correct
handling of subnormals (of both the target format and the carrier),
underflow-to-zero, overflow (to inf, or saturation for fp8 formats), signed
zeros, infs and NaNs.

The implementation is **pure integer bit manipulation** on the carrier's IEEE
representation. This is deliberate:
  * XLA:CPU runs with DAZ/FTZ, so float arithmetic cannot even observe
    carrier-subnormal values (x != 0 is False for subnormal x!);
  * jnp.frexp / jnp.ldexp / jnp.exp2 are approximate or subnormal-broken;
  * the identical integer algorithm is the body of the Pallas TPU kernel
    (kernels/chop), making this module its bit-exact oracle.

Two entry points:
  chop_static(x, fmt)   — format fixed at trace time.
  chop(x, fmt_id)       — format id is runtime data (traced integer). A single
                          compiled program serves every precision action,
                          which is what makes bandit exploration
                          recompile-free (DESIGN.md §3.4).

Algorithm (elementwise, on bit patterns):
  decompose |x| = M · 2^(Eeff - BIAS - MBITS)   (M includes the implicit bit)
  e      = floor(log2 |x|) = msb(M) + Eeff - BIAS - MBITS
  q      = max(e, emin) - (t - 1)               (target quantum exponent)
  s      = number of low bits of M below the quantum
  Mr     = RNE(M >> s)                          (add half-1 + lsb, shift)
  y      = Mr · 2^q, reassembled into carrier bits (normal or subnormal)
  y      = ±inf (or ±xmax for saturating formats) where |y| > xmax
  0, ±inf, NaN pass through; exact values (s <= 0) pass through.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .formats import (FMT_EMAX, FMT_EMIN, FMT_SATURATE, FMT_T, FMT_XMAX,
                      FORMAT_LIST, FloatFormat, get_format)

# Carrier descriptions: (uint dtype, word bits, mantissa bits, exp bias,
# max exponent field).
_CARRIERS = {
    jnp.dtype(jnp.float32): (jnp.uint32, 32, 23, 127, 255),
    jnp.dtype(jnp.float64): (jnp.uint64, 64, 52, 1023, 2047),
}

# xmax bit patterns per format, per carrier (positive magnitude patterns).
_F32_MAX = float(np.finfo(np.float32).max)
FMT_XMAX_BITS32 = np.array(
    [np.float32(min(f.xmax, _F32_MAX)).view(np.uint32)
     for f in FORMAT_LIST], dtype=np.uint32)
FMT_XMAX_BITS64 = np.array(
    [np.float64(f.xmax).view(np.uint64) for f in FORMAT_LIST],
    dtype=np.uint64)


def _chop_core(x: jnp.ndarray, t, emin, emax, xmax_bits, saturate) -> jnp.ndarray:
    """Elementwise round-to-format on the carrier's bit patterns.

    t/emin/emax are python ints or traced int32 scalars; xmax_bits is the bit
    pattern of the format's xmax in the carrier's uint type; saturate is
    bool-like."""
    dtype = x.dtype
    if dtype not in _CARRIERS:
        raise TypeError(f"unsupported carrier dtype {dtype}")
    UINT, W, MBITS, BIAS, EFMAX = _CARRIERS[dtype]
    one = jnp.asarray(1, UINT)
    sign_mask = one << (W - 1)
    frac_mask = (one << MBITS) - 1
    inf_bits = jnp.asarray(EFMAX, UINT) << MBITS

    t = jnp.asarray(t, jnp.int32)
    emin = jnp.asarray(emin, jnp.int32)
    xmax_bits = jnp.asarray(xmax_bits, UINT)

    bits = lax.bitcast_convert_type(x, UINT)
    sign = bits & sign_mask
    mag = bits & ~sign_mask
    E = (mag >> MBITS).astype(jnp.int32)
    frac = mag & frac_mask

    special = E == EFMAX          # inf / nan
    zero = mag == 0
    is_sub = E == 0

    M = jnp.where(is_sub, frac, frac | (one << MBITS))
    Eeff = jnp.where(is_sub, 1, E)
    base = Eeff - (BIAS + MBITS)                       # |x| = M * 2^base
    Mg = jnp.where(M == 0, one, M)                     # guard clz for zeros
    msb = (W - 1) - lax.clz(Mg).astype(jnp.int32)
    e_x = msb + base

    q = jnp.maximum(e_x, emin) - (t - 1)
    s = q - base                                       # bits to round off
    sc = jnp.clip(s, 0, W - 1).astype(UINT)
    scm1 = jnp.clip(s - 1, 0, W - 1).astype(UINT)
    lsb = (Mg >> sc) & one
    round_add = jnp.where(s > 0, ((one << scm1) - 1) + lsb, 0)
    Mr = (Mg + round_add) >> sc
    # Full underflow: s >= W would be clipped by sc; |x| < 2^(q-1) there, so
    # the correctly-rounded result is zero.
    Mr = jnp.where(s > W - 1, jnp.zeros((), UINT), Mr)
    exact = s <= 0                                     # already representable

    # --- reassemble Mr * 2^q into carrier bits -----------------------------
    zero_r = Mr == 0
    Mr_g = jnp.where(zero_r, one, Mr)
    msb_r = (W - 1) - lax.clz(Mr_g).astype(jnp.int32)
    new_e = msb_r + q
    emin_car = 1 - BIAS
    sub_res = new_e < emin_car

    shift_n = MBITS - msb_r                            # in [-1, MBITS]
    left = jnp.clip(shift_n, 0, W - 1).astype(UINT)
    right = jnp.clip(-shift_n, 0, W - 1).astype(UINT)
    frac_n = ((Mr_g << left) >> right) & frac_mask
    bits_n = ((new_e + BIAS).astype(UINT) << MBITS) | frac_n

    k_sub = jnp.clip(q - (emin_car - MBITS), 0, W - 1).astype(UINT)
    bits_s = Mr_g << k_sub                             # exponent field 0

    out_mag = jnp.where(sub_res, bits_s, bits_n)
    out_mag = jnp.where(zero_r, jnp.zeros((), UINT), out_mag)

    over = out_mag > xmax_bits
    sat_mag = jnp.where(jnp.asarray(saturate, bool), xmax_bits, inf_bits)
    out_mag = jnp.where(over, sat_mag, out_mag)

    out_bits = jnp.where(special | zero | exact, bits, sign | out_mag)
    return lax.bitcast_convert_type(out_bits, dtype)


def fma_barrier(x: jnp.ndarray) -> jnp.ndarray:
    """Identity on values, opaque to FMA contraction (DESIGN.md §6.2).

    `_chop_core`'s integer-bitcast chain is what pins the bits of every
    *chopped* intermediate; this applies the same chain to values that
    must stay unrounded (carrier accumulations) by rounding to the
    carrier's OWN format — RNE of an f64 to 53 significand bits (or an
    f32 to 24) is exact, so the value is untouched while the product is
    materialized through real, data-dependent integer arithmetic that
    no simplifier can cancel. Without it, XLA may contract the
    producing multiply into a following add/reduction as an FMA
    depending on each program's fusion context, shifting the
    accumulated bits (measured). Weaker barriers do not survive
    compilation: a bitcast round trip is cancelled by the algebraic
    simplifier, and `lax.optimization_barrier` is elided before fusion
    on XLA:CPU, after which the emitter contracts anyway (both
    measured — a padded and an unpadded solve of the same system
    disagreed in the final residual only under jit).
    """
    x = jnp.asarray(x)
    if x.dtype not in _CARRIERS:
        raise TypeError(f"unsupported carrier dtype {x.dtype}")
    _, _, MBITS, _, _ = _CARRIERS[x.dtype]
    f = get_format("fp64" if x.dtype == jnp.dtype(jnp.float64) else "fp32")
    assert f.t == MBITS + 1     # carrier-exact: rounding is the identity
    return _chop_core(x, f.t, f.emin, f.emax, _fmt_xmax_bits(f, x.dtype),
                      False)


def tree_sum(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Sum along `axis` with a FIXED pairwise reduction tree.

    `jnp.sum` lowers to an XLA reduce whose accumulation order is
    implementation-defined — and it *varies with the compilation
    context* (plain jit vs a shard_map body, measured on XLA:CPU), so
    two programs tracing identical ops can disagree in the low bits of
    a carrier accumulation. Floating-point adds are not associative and
    XLA never re-associates *explicit* adds, so a halving tree of
    explicit adds pins the order in any context: fold the upper half
    onto the lower half, log2(n) times. Odd widths park their last
    element in a running tail accumulator added once at the end — no
    `concatenate`, deliberately, since this also runs inside the Pallas
    qmv kernel body and sub-lane concatenates are a Mosaic lowering
    risk. Every unrounded carrier reduction on the solver hot path goes
    through this (DESIGN.md §6.2, §7.3)."""
    x = jnp.asarray(x)
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    if x.shape[-1] == 0:
        return jnp.zeros(x.shape[:-1], x.dtype)
    tail = None
    while x.shape[-1] > 1:
        n = x.shape[-1]
        m = n // 2
        if n % 2:
            last = x[..., n - 1]
            tail = last if tail is None else tail + last
        x = x[..., :m] + x[..., m:2 * m]
    out = x[..., 0]
    return out if tail is None else out + tail


def _fmt_xmax_bits(f: FloatFormat, dtype) -> int:
    if dtype == jnp.dtype(jnp.float64):
        return int(np.float64(f.xmax).view(np.uint64))
    return int(np.float32(min(f.xmax, _F32_MAX)).view(np.uint32))


def chop_static(x: jnp.ndarray, fmt: Union[str, FloatFormat]) -> jnp.ndarray:
    """Round `x` (carrier float array) to `fmt`, format fixed at trace time."""
    f = get_format(fmt)
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise TypeError(f"chop expects float carrier, got {x.dtype}")
    if jnp.finfo(x.dtype).nmant + 1 <= f.t and f.name in ("fp32", "fp64"):
        return x  # identity fast-path: carrier no wider than target
    return _chop_core(x, f.t, f.emin, f.emax, _fmt_xmax_bits(f, x.dtype),
                      f.saturate)


def chop(x: jnp.ndarray, fmt_id) -> jnp.ndarray:
    """Round `x` to the format selected by the (possibly traced) integer id."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise TypeError(f"chop expects float carrier, got {x.dtype}")
    fmt_id = jnp.asarray(fmt_id, jnp.int32)
    t = jnp.asarray(FMT_T)[fmt_id]
    emin = jnp.asarray(FMT_EMIN)[fmt_id]
    emax = jnp.asarray(FMT_EMAX)[fmt_id]
    if x.dtype == jnp.dtype(jnp.float64):
        xmax_bits = jnp.asarray(FMT_XMAX_BITS64)[fmt_id]
    else:
        xmax_bits = jnp.asarray(FMT_XMAX_BITS32)[fmt_id]
    saturate = jnp.asarray(FMT_SATURATE)[fmt_id]
    return _chop_core(x, t, emin, emax, xmax_bits, saturate)


def chop_stochastic(x: jnp.ndarray, fmt_id, key) -> jnp.ndarray:
    """Stochastic rounding to the format (beyond-paper: unbiased rounding
    for gradient compression / accumulation — E[chop_sr(x)] == x).

    Integer formulation: with s bits to drop, add U ~ uniform[0, 2^s) before
    truncating — exactly SR. Carrier-subnormal/overflow handling matches
    the RNE path."""
    x = jnp.asarray(x)
    if x.dtype != jnp.dtype(jnp.float32):
        raise TypeError("chop_stochastic targets the f32 carrier")
    fmt_id = jnp.asarray(fmt_id, jnp.int32)
    t = jnp.asarray(FMT_T)[fmt_id]
    emin = jnp.asarray(FMT_EMIN)[fmt_id]
    xmax_bits = jnp.asarray(FMT_XMAX_BITS32)[fmt_id]
    saturate = jnp.asarray(FMT_SATURATE)[fmt_id]

    UINT, W, MBITS, BIAS, EFMAX = _CARRIERS[x.dtype]
    one = jnp.asarray(1, UINT)
    bits = lax.bitcast_convert_type(x, UINT)
    sign_mask = one << (W - 1)
    frac_mask = (one << MBITS) - 1
    sign = bits & sign_mask
    mag = bits & ~sign_mask
    E = (mag >> MBITS).astype(jnp.int32)
    frac = mag & frac_mask
    special = E == EFMAX
    zero = mag == 0
    is_sub = E == 0
    M = jnp.where(is_sub, frac, frac | (one << MBITS))
    Eeff = jnp.where(is_sub, 1, E)
    base = Eeff - (BIAS + MBITS)
    Mg = jnp.where(M == 0, one, M)
    msb = (W - 1) - lax.clz(Mg).astype(jnp.int32)
    q = jnp.maximum(msb + base, emin) - (t - 1)
    s = q - base
    sc = jnp.clip(s, 0, W - 1).astype(UINT)
    u = jax.random.bits(key, x.shape, UINT) & ((one << sc) - 1)
    Mr = (Mg + u) >> sc
    Mr = jnp.where(s > W - 1, jnp.zeros((), UINT), Mr)  # deep underflow
    exact = s <= 0
    # Reassemble via the shared path: reuse _chop_core's tail by building a
    # float from Mr * 2^q with overflow/saturation checks.
    zero_r = Mr == 0
    Mr_g = jnp.where(zero_r, one, Mr)
    msb_r = (W - 1) - lax.clz(Mr_g).astype(jnp.int32)
    new_e = msb_r + q
    emin_car = 1 - BIAS
    sub_res = new_e < emin_car
    shift_n = MBITS - msb_r
    left = jnp.clip(shift_n, 0, W - 1).astype(UINT)
    right = jnp.clip(-shift_n, 0, W - 1).astype(UINT)
    frac_n = ((Mr_g << left) >> right) & frac_mask
    bits_n = ((new_e + BIAS).astype(UINT) << MBITS) | frac_n
    k_sub = jnp.clip(q - (emin_car - MBITS), 0, W - 1).astype(UINT)
    bits_s = Mr_g << k_sub
    out_mag = jnp.where(sub_res, bits_s, bits_n)
    out_mag = jnp.where(zero_r, jnp.zeros((), UINT), out_mag)
    inf_bits = jnp.asarray(EFMAX, UINT) << MBITS
    over = out_mag > xmax_bits
    out_mag = jnp.where(over, jnp.where(saturate, xmax_bits, inf_bits),
                        out_mag)
    out_bits = jnp.where(special | zero | exact, bits, sign | out_mag)
    return lax.bitcast_convert_type(out_bits, x.dtype)


def chop_tree(tree, fmt_id):
    """Apply `chop` to every float leaf of a pytree (runtime format id)."""
    def _leaf(v):
        v = jnp.asarray(v)
        if jnp.issubdtype(v.dtype, jnp.floating):
            return chop(v, fmt_id)
        return v
    return jax.tree_util.tree_map(_leaf, tree)


def rounding_unit(fmt_id, dtype=jnp.float32) -> jnp.ndarray:
    """Unit roundoff 2^-t for a (possibly traced) format id."""
    t = jnp.asarray(FMT_T)[jnp.asarray(fmt_id, jnp.int32)]
    # 2^-t for t in [3, 53]: exact via integer exponent assembly.
    if dtype == jnp.dtype(jnp.float64):
        bits = (1023 - t.astype(jnp.int64)) << 52
        return lax.bitcast_convert_type(bits, jnp.float64)
    bits = (127 - t) << 23
    return lax.bitcast_convert_type(bits, jnp.float32)


def chop_matmul(a: jnp.ndarray, b: jnp.ndarray, fmt_id,
                chop_inputs: bool = True,
                chop_output: bool = True) -> jnp.ndarray:
    """Matmul with operands (and result) stored in the emulated format;
    accumulation happens in the carrier dtype — matching MXU semantics
    (bf16 x bf16 -> fp32 accumulate) and FMA-style simulation.

    This is the pure-jnp counterpart of kernels/qmatmul.
    """
    if chop_inputs:
        a = chop(a, fmt_id)
        b = chop(b, fmt_id)
    out = a @ b
    if chop_output:
        out = chop(out, fmt_id)
    return out


def simulate_dtype(x: jnp.ndarray, fmt: Union[str, FloatFormat]) -> jnp.ndarray:
    """Bit-exact native cast when the host has the dtype, else chop_static.

    Used by tests to cross-validate chop against XLA's native casts.
    """
    f = get_format(fmt)
    if f.native_dtype is not None:
        native = jnp.dtype(f.native_dtype)
        if jnp.finfo(native).bits <= jnp.finfo(x.dtype).bits:
            return x.astype(native).astype(x.dtype)
    return chop_static(x, f)
