"""Precision substrate: format descriptors, round-to-format emulation,
and the backend dispatch layer (DESIGN.md §6)."""
from .backend import (JnpBackend, PallasBackend, PrecisionBackend,
                      available_backends, default_backend, register_backend,
                      resolve_backend, set_default_backend)
from .chop import (chop, chop_matmul, chop_static, chop_stochastic,
                   chop_tree, fma_barrier, rounding_unit, simulate_dtype,
                   tree_sum)
from .formats import (BF16, E4M3, E5M2, FORMAT_ID, FORMAT_LIST, FORMATS, FP16,
                      FP32, FP64, SOLVER_LADDER, SOLVER_LADDER_FP8, TF32,
                      TPU_LADDER, FloatFormat, format_id, get_format,
                      runtime_tables)

__all__ = [
    "chop", "chop_matmul", "chop_static", "chop_stochastic", "chop_tree",
    "fma_barrier", "tree_sum", "rounding_unit",
    "simulate_dtype", "FloatFormat", "get_format", "format_id",
    "FORMATS", "FORMAT_LIST", "FORMAT_ID", "SOLVER_LADDER",
    "SOLVER_LADDER_FP8", "TPU_LADDER",
    "BF16", "FP16", "TF32", "FP32", "FP64", "E4M3", "E5M2", "runtime_tables",
    "PrecisionBackend", "JnpBackend", "PallasBackend", "resolve_backend",
    "default_backend", "set_default_backend", "register_backend",
    "available_backends",
]
