"""Precision backend dispatch: one signature, two implementations
(DESIGN.md §6).

Every precision action the bandit selects is *applied* by four ops on
the solver hot path: an elementwise round-to-format (`chop`), a fused
chopped matvec (`chop_mv`), a fused chopped matmul (`chop_matmul` — the
blocked LU trailing update), and a blocked triangular substitution
(`chop_trisolve`). This module gives those ops a backend-agnostic home:

  * ``"jnp"``   — the pure-jnp oracle (`repro.precision.chop`), valid on
    any float carrier (f64 for the paper's host experiments);
  * ``"pallas"``— the Pallas TPU kernels (`kernels/chop`,
    `kernels/qmatmul`, `kernels/trisolve`), f32 carrier, VMEM-resident
    rounding with no extra HBM round trips. Off-TPU, selecting ``"pallas"`` falls back
    to ``"jnp"`` (the interpreter is a correctness tool, not a fast
    path); ``"pallas-interpret"`` forces the kernels through the Pallas
    interpreter for CPU bit-exactness testing.

Backends are small frozen dataclasses, so they hash by value and can be
passed as **static jit arguments**: the solvers compile once per
(shapes, config, backend) while the format id stays runtime data —
switching precision actions never recompiles (DESIGN.md §3.4), and
switching backends costs exactly one extra executable.

Bit-exactness contract (DESIGN.md §6.2): for a shared f32 carrier, both
backends produce bit-identical results for `chop` (same integer RNE
algorithm elementwise), `chop_mv` (shared lane-padded row-sum reduction
shape), `chop_matmul` (shared lane-padded K and a single-K-block dot,
whose reduction is M/N-tile-invariant — measured), and `chop_trisolve`
(the kernel body and the oracle are the same `_trisolve_core`
function). The multi-K-tile MXU schedule lives on as
`kernels/qmatmul.qmatmul_op` outside the backend contract.

Selection order: explicit argument > `set_default_backend` >
``REPRO_PRECISION_BACKEND`` env var > ``"jnp"``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from . import chop as _chop

ENV_VAR = "REPRO_PRECISION_BACKEND"

# Arrays smaller than this bypass the pallas chop kernel: the O(n) glue
# vectors inside solver loops are launch-overhead-bound, and the two
# implementations are bit-identical, so routing is a pure perf choice.
DEFAULT_CHOP_MIN_ELEMS = 4096


class PrecisionBackend:
    """Interface shared by all precision backends (duck-typed; this base
    class only documents the contract and hosts shared helpers).

    `carrier_dtype` is the float dtype the backend's solver entry points
    coerce operands to (None = keep the caller's carrier)."""

    name: str = "abstract"
    carrier_dtype: Optional[str] = None

    def chop(self, x: jnp.ndarray, fmt_id) -> jnp.ndarray:
        raise NotImplementedError

    def chop_mv(self, A: jnp.ndarray, v: jnp.ndarray, fmt_id, *,
                chop_output: bool = True) -> jnp.ndarray:
        raise NotImplementedError

    def chop_matmul(self, a: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
                    chop_inputs: bool = True,
                    chop_output: bool = True) -> jnp.ndarray:
        raise NotImplementedError

    def chop_trisolve(self, Lu: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
                      lower: bool, block: int = 128) -> jnp.ndarray:
        """Blocked triangular substitution on the combined LU matrix
        (strictly-lower + unit diagonal when `lower`, upper triangle
        including the diagonal otherwise) — DESIGN.md §6.2/§6.4."""
        raise NotImplementedError

    def coerce(self, *arrays: jnp.ndarray):
        """Cast float arrays to this backend's carrier dtype (no-op when
        `carrier_dtype` is None)."""
        if self.carrier_dtype is None:
            return arrays if len(arrays) != 1 else arrays[0]
        dt = jnp.dtype(self.carrier_dtype)
        out = tuple(a.astype(dt) if jnp.issubdtype(jnp.asarray(a).dtype,
                                                   jnp.floating) else a
                    for a in arrays)
        return out if len(out) != 1 else out[0]


@dataclasses.dataclass(frozen=True)
class JnpBackend(PrecisionBackend):
    """Pure-jnp oracle backend: the paper-faithful reference semantics on
    any float carrier. This is the default and the ground truth the
    pallas backend is bit-validated against."""

    name: str = dataclasses.field(default="jnp", init=False)
    carrier_dtype: Optional[str] = None

    def chop(self, x, fmt_id):
        return _chop.chop(x, fmt_id)

    def chop_mv(self, A, v, fmt_id, *, chop_output: bool = True):
        # Same reduction shape as kernels/qmatmul.qmv_op: lane-padded
        # row-sum (see ref.qmv_ref; the import is deferred so that
        # importing repro.precision never pulls in pallas).
        from repro.kernels.qmatmul.ref import qmv_ref
        return qmv_ref(A, v, fmt_id, chop_out=chop_output)

    def chop_matmul(self, a, b, fmt_id, *, chop_inputs: bool = True,
                    chop_output: bool = True):
        # Pinned tiled-reduction contract shared with the pallas kernel:
        # lane-padded K, single carrier dot (DESIGN.md §6.2).
        from repro.kernels.qmatmul.ref import qgemm_ref
        return qgemm_ref(a, b, fmt_id, chop_out=chop_output,
                         chop_inputs=chop_inputs)

    def chop_trisolve(self, Lu, b, fmt_id, *, lower: bool,
                      block: int = 128):
        from repro.kernels.trisolve.ref import trisolve_ref
        return trisolve_ref(Lu, b, fmt_id, lower=lower, block=block)


@dataclasses.dataclass(frozen=True)
class PallasBackend(PrecisionBackend):
    """Pallas TPU fast path: `kernels/chop` for standalone roundings,
    `kernels/qmatmul` for the fused matvec/matmul. f32 carrier only —
    solver entry points coerce operands via `carrier_dtype`.

    `interpret=None` auto-selects the Pallas interpreter off-TPU (the
    compiled path on TPU); `chop_min_elems` routes small glue arrays to
    the bit-identical jnp chop to avoid kernel launch overhead."""

    name: str = dataclasses.field(default="pallas", init=False)
    carrier_dtype: Optional[str] = "float32"
    interpret: Optional[bool] = None
    chop_min_elems: int = DEFAULT_CHOP_MIN_ELEMS

    def chop(self, x, fmt_id):
        x = jnp.asarray(x)
        if x.dtype != jnp.float32 or x.size < self.chop_min_elems:
            return _chop.chop(x, fmt_id)
        from repro.kernels.chop import chop_op
        return chop_op(x, fmt_id, interpret=self.interpret)

    def chop_mv(self, A, v, fmt_id, *, chop_output: bool = True):
        from repro.kernels.qmatmul import qmv_op
        return qmv_op(A, v, fmt_id, chop_out=chop_output,
                      interpret=self.interpret)

    def chop_matmul(self, a, b, fmt_id, *, chop_inputs: bool = True,
                    chop_output: bool = True):
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if (not chop_inputs or a.dtype != jnp.float32
                or b.dtype != jnp.float32):
            # The fused kernel always rounds its operands in VMEM and is
            # f32-only; the oracle shares the pinned reduction contract,
            # so routing there is bit-transparent (DESIGN.md §6.2).
            from repro.kernels.qmatmul.ref import qgemm_ref
            return qgemm_ref(a, b, fmt_id, chop_out=chop_output,
                             chop_inputs=chop_inputs)
        from repro.kernels.qmatmul import qgemm_op
        return qgemm_op(a, b, fmt_id, chop_out=chop_output,
                        interpret=self.interpret)

    def chop_trisolve(self, Lu, b, fmt_id, *, lower: bool,
                      block: int = 128):
        Lu = jnp.asarray(Lu)
        b = jnp.asarray(b)
        if Lu.dtype != jnp.float32 or b.dtype != jnp.float32:
            # Non-f32 carriers only occur outside the coerced solver
            # entry points; the oracle IS the kernel body, so this
            # routing is bit-transparent (DESIGN.md §6.2).
            from repro.kernels.trisolve.ref import trisolve_ref
            return trisolve_ref(Lu, b, fmt_id, lower=lower, block=block)
        from repro.kernels.trisolve import trisolve_op
        return trisolve_op(Lu, b, fmt_id, lower=lower, block=block,
                           interpret=self.interpret)


# ---------------------------------------------------------------------------
# Registry + selection
# ---------------------------------------------------------------------------

BackendLike = Union[None, str, PrecisionBackend]

_REGISTRY: Dict[str, Callable[[], PrecisionBackend]] = {
    "jnp": JnpBackend,
    "pallas": PallasBackend,
    "pallas-interpret": lambda: PallasBackend(interpret=True),
}
_DEFAULT: Optional[PrecisionBackend] = None
_WARNED_FALLBACK = False


def register_backend(name: str,
                     factory: Callable[[], PrecisionBackend]) -> None:
    """Register a backend factory under `name` (overwrites allowed)."""
    _REGISTRY[name] = factory


def available_backends():
    return sorted(_REGISTRY)


def _from_name(name: str) -> PrecisionBackend:
    global _WARNED_FALLBACK
    if name not in _REGISTRY:
        raise KeyError(f"unknown precision backend {name!r}; "
                       f"available: {available_backends()}")
    backend = _REGISTRY[name]()
    if (name == "pallas" and backend.interpret is None
            and jax.default_backend() != "tpu"):
        # Fast path requested without TPU hardware: interpret mode would
        # be orders of magnitude slower than jnp, so serve jnp instead.
        # The silent downgrade is exactly what a dashboard must see, so
        # count it (fail-open) in the default metrics registry.
        try:
            from repro.obs.metrics import default_registry
            default_registry().counter(
                "repro_backend_fallbacks_total",
                "Precision-backend downgrades (requested backend "
                "unavailable on this host).",
                ("requested", "served")).labels(
                    requested="pallas", served="jnp").inc()
        except Exception:
            pass
        if not _WARNED_FALLBACK:
            warnings.warn(
                "precision backend 'pallas' requested off-TPU; falling "
                "back to 'jnp' (use 'pallas-interpret' to force the "
                "Pallas interpreter, e.g. for bit-exactness tests)",
                stacklevel=3)
            _WARNED_FALLBACK = True
        return _REGISTRY["jnp"]()
    return backend


def set_default_backend(backend: BackendLike) -> Optional[PrecisionBackend]:
    """Set the process-wide default backend (None restores env/'jnp'
    resolution). Returns the previous override, for save/restore."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = (resolve_backend(backend)
                if backend is not None else None)
    return prev


def default_backend() -> PrecisionBackend:
    if _DEFAULT is not None:
        return _DEFAULT
    return _from_name(os.environ.get(ENV_VAR, "jnp"))


def resolve_backend(backend: BackendLike = None) -> PrecisionBackend:
    """Coerce a backend spec (instance | name | None=default) into a
    backend instance. Pure Python — call before tracing so the result
    can be a static jit argument."""
    if backend is None:
        return default_backend()
    if isinstance(backend, str):
        return _from_name(backend)
    return backend
