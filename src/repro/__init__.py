"""repro: Precision autotuning for linear solvers via contextual-bandit RL
(Carson & Chen, 2026) as a multi-pod JAX training/inference framework.

Subpackages:
  core         — the paper's contribution: bandit, action space, rewards,
                 discretizer, GMRES-IR environment, train/evaluate
  precision    — round-to-format emulation (runtime-switchable format ids)
  solvers      — chopped LU / triangular / GMRES / GMRES-IR
  kernels      — Pallas TPU kernels (chop, qmatmul, flash_attention)
  models       — 10 assigned LM architectures (GQA/MLA/MoE/SSM/hybrid)
  train, serve — optimizer, precision controller, decode loops
  data         — problem generators + token pipeline
  distributed  — FSDP x TP x EP x SP sharding rules
  checkpoint   — atomic fault-tolerant checkpointing
  launch       — production mesh, multi-pod dry-run, train/serve CLIs
  configs      — ArchConfig registry (--arch <id>)
"""

__version__ = "0.1.0"
