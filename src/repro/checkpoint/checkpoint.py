"""Atomic, versioned checkpointing for fault-tolerant training.

Saves params, optimizer state (incl. int8 QTensors), step, RNG, data-
pipeline cursor, AND the bandit Q-table — the autotuner state survives
restarts and topology changes (it is tiny and replicated; DESIGN.md §5).

Layout:  <dir>/step_<N>/{arrays.npz, meta.json}, plus <dir>/LATEST written
last (atomic rename), so a crash mid-save never corrupts the restore path.
Multi-host: only process 0 writes (arrays are fully-addressable on host for
the scales we train here; sharded async checkpointing would slot in at the
save_arrays boundary)."""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.train.quantize import QTensor


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, QTensor):
        out[prefix + "/__qcodes"] = np.asarray(tree.codes)
        out[prefix + "/__qscales"] = np.asarray(tree.scales)
        return out
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
        return out
    if isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/[{i}]"))
        return out
    if hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}/{k}"))
        return out
    out[prefix] = np.asarray(tree)
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, QTensor):
        return QTensor(jax.numpy.asarray(flat[prefix + "/__qcodes"]),
                       jax.numpy.asarray(flat[prefix + "/__qscales"]))
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}/{k}")
                for k in template}
    if isinstance(template, (list, tuple)) and not hasattr(template,
                                                           "_fields"):
        vals = [_unflatten_into(v, flat, f"{prefix}/[{i}]")
                for i, v in enumerate(template)]
        return type(template)(vals)
    if hasattr(template, "_fields"):
        vals = {k: _unflatten_into(getattr(template, k), flat,
                                   f"{prefix}/{k}")
                for k in template._fields}
        return type(template)(**vals)
    arr = flat[prefix]
    return jax.numpy.asarray(arr)


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra_meta: Optional[Dict] = None) -> str:
    """Atomic save. `state` is any pytree (dicts/lists/NamedTuples/QTensor)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(extra_meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # LATEST pointer written last, atomically.
    ptr = os.path.join(ckpt_dir, "LATEST")
    with tempfile.NamedTemporaryFile("w", dir=ckpt_dir, delete=False) as f:
        f.write(os.path.basename(final))
        tmp_ptr = f.name
    os.replace(tmp_ptr, ptr)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `template`. Returns (state, meta)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    z = np.load(os.path.join(path, "arrays.npz"))
    flat = {k.replace("|", "/"): z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return _unflatten_into(template, flat), meta
