"""llama4-scout-17b-16e [moe]: 48L d=5120 40H (kv 8) d_ff=8192 vocab=202048,
16 routed experts top-1 + 1 shared, chunked local attention (8192) with a
NoPE global layer every 4th (iRoPE) — sub-quadratic => long_500k runs.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("chunked",),
    attn_chunk=8192,
    nope_every=4,
    rope_theta=500000.0,
    n_experts=16,
    n_shared_experts=1,
    top_k=1,
    d_ff_expert=8192,
    moe_every=1,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, d_ff_expert=128, vocab_size=512, n_experts=4, top_k=1,
        attn_chunk=32, nope_every=4)
