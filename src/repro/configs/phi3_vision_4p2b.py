"""phi-3-vision-4.2b [vlm]: phi3-mini backbone (32L d=3072 32H MHA d_ff=8192
vocab=32064) + CLIP frontend STUB: input_specs() supplies precomputed patch
embeddings (B, 256, d_model) injected over the first 256 positions
(transformer.forward prefix_embeds). Pure global attention => long_500k
skipped. [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision_stub",
    n_prefix_embeds=256,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, n_prefix_embeds=8)
