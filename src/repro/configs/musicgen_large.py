"""musicgen-large [audio]: 48L d=2048 32H (kv 32 = MHA) d_ff=8192 vocab=2048
decoder-only over EnCodec tokens. The EnCodec frontend is a STUB:
input_specs() supplies the audio-token ids directly (the backbone is a
standard LM over the 2048-entry codebook). Pure global attention =>
long_500k skipped. [arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio_stub",
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128)
