"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from . import (deepseek_v2_236b, falcon_mamba_7b, gemma2_9b, gemma_2b,
               granite_3_2b, jamba_v0_1_52b, llama4_scout_17b,
               musicgen_large, phi3_vision_4p2b, phi4_mini_3p8b)
from .base import ArchConfig

_MODULES = {
    "llama4-scout-17b-16e": llama4_scout_17b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "gemma2-9b": gemma2_9b,
    "phi4-mini-3.8b": phi4_mini_3p8b,
    "granite-3-2b": granite_3_2b,
    "gemma-2b": gemma_2b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "musicgen-large": musicgen_large,
    "phi-3-vision-4.2b": phi3_vision_4p2b,
}

ARCHS: Dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return _MODULES[name].smoke_config()


def all_archs():
    return dict(ARCHS)
