from .base import (ArchConfig, ShapeConfig, SHAPES, supports_long_context,
                   valid_cells)
from .registry import ARCHS, all_archs, get_arch, get_smoke

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "supports_long_context",
           "valid_cells", "ARCHS", "all_archs", "get_arch", "get_smoke"]
