"""phi4-mini-3.8b [dense]: 32L d=3072 24H (kv 8) d_ff=8192 vocab=200064,
RoPE + SwiGLU + GQA, tied embeddings. Pure global attention => long_500k
skipped (DESIGN.md §4). [arXiv:2412.08905; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
        d_ff=96, vocab_size=512)
