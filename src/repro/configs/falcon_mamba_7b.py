"""falcon-mamba-7b [ssm]: 64L d=4096 attention-free mamba1, ssm_state=16,
vocab=65024. SSM => long_500k runs. [arXiv:2410.05355; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    layer_pattern=("mamba",),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=False,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=512, ssm_state=4)
