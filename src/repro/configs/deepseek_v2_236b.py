"""deepseek-v2-236b [moe]: 60L d=5120 128H, MLA (kv_lora=512, q_lora=1536,
rope head 64), 2 shared + 160 routed experts top-6 (d_ff_expert=1536),
first layer dense (d_ff=12288). Pure (latent) global attention => long_500k
skipped (DESIGN.md §4). [arXiv:2405.04434; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,            # per-head nope dim
    d_ff=12288,              # dense (first) layer FFN
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    moe_every=1,
    first_dense=1,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, kv_lora_rank=32, q_lora_rank=48,
        rope_head_dim=8, v_head_dim=16, n_experts=8, n_shared_experts=1,
        top_k=2, d_ff_expert=32, first_dense=1)
