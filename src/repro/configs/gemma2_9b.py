"""gemma2-9b [dense]: 42L d=3584 16H (kv 8, head_dim 256) d_ff=14336
vocab=256000, GeGLU, alternating local(4096)/global attention, attn softcap
50 and final logit softcap 30, pre+post norms, tied + scaled embeddings.
Hybrid-local => long_500k runs (global half carries the 512k KV, sharded).
[arXiv:2408.00118; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    act="gelu",
    layer_pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    embed_scale=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, window=16)
