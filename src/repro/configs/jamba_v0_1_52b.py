"""jamba-v0.1-52b [hybrid]: 32L d=4096, mamba:attn 7:1 (attention at offset 4
of each 8-layer block), 32H (kv 8) on attention layers, d_ff=14336, MoE 16
experts top-2 on every other layer, vocab=65536, ssm_state=16.
Hybrid => long_500k runs. [arXiv:2403.19887; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    d_ff_expert=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, d_ff_expert=128, vocab_size=512, n_experts=4, top_k=2,
        ssm_state=4)
