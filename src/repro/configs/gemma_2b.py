"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1, head_dim 256) d_ff=16384
vocab=256000, GeGLU, tied + scaled embeddings. Pure global attention =>
long_500k skipped. [arXiv:2403.08295; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512)
