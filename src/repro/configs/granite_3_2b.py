"""granite-3-2b [dense]: 40L d=2048 32H (kv 8) d_ff=8192 vocab=49155, GQA,
tied embeddings. Pure global attention => long_500k skipped.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512)
