"""Architecture + shape configuration system.

Every assigned architecture is a frozen `ArchConfig`; the launcher selects
one with ``--arch <id>`` (see repro/configs/registry.py). Shapes are the
assignment's four input-shape cells; `long_500k` is only valid for archs
with sub-quadratic attention structure (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    post_norms: bool = False       # gemma2 pre+post norm sandwich
    embed_scale: bool = False      # gemma: embeddings scaled by sqrt(d)

    # Per-layer structure: `layer_pattern` is cycled over the depth. Entries:
    # "attn" (global), "local" (windowed), "chunked" (llama4-style chunks),
    # "mamba" (SSM block).
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                # local-attention window
    attn_chunk: int = 0            # chunked-attention chunk length
    nope_every: int = 0            # every Nth layer: global + no RoPE (iRoPE)
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1             # MoE on layers where (l % moe_every)==moe_offset
    moe_offset: int = 0
    first_dense: int = 0           # leading dense layers
    capacity_factor: float = 1.25
    router_dtype: str = "float32"  # router numerics pinned high (DESIGN §4)

    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 => ceil(d_model/16)

    # Modality frontend stub
    frontend: str = "none"         # none | audio_stub | vision_stub
    n_prefix_embeds: int = 0       # vision stub: precomputed patch embeds

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def layer_kind(self, layer: int) -> str:
        kind = self.layer_pattern[layer % len(self.layer_pattern)]
        if kind in ("attn", "local", "chunked") and self.nope_every and \
                (layer + 1) % self.nope_every == 0:
            return "attn"          # iRoPE global layer
        return kind

    def is_moe_layer(self, layer: int) -> bool:
        """MoE replaces the FFN on matching layers — including mamba layers
        (Jamba's blocks are mixer + MLP, with MoE on every other layer)."""
        if self.n_experts == 0:
            return False
        if layer < self.first_dense:
            return False
        return (layer % self.moe_every) == self.moe_offset

    @property
    def pattern_len(self) -> int:
        """Length of the repeating block for scan-over-layers (lcm of the
        attention pattern, the MoE cycle, and the iRoPE cycle)."""
        import math
        p = len(self.layer_pattern)
        if self.n_experts:
            p = math.lcm(p, self.moe_every)
        if self.nope_every:
            p = math.lcm(p, self.nope_every)
        return p

    # -- analytic parameter counts (for 6ND roofline bookkeeping) ----------
    def params_per_layer(self, layer: int) -> int:
        d = self.d_model
        kind = self.layer_kind(layer)
        n = 2 * d                                   # norms
        if kind == "mamba":
            di, ds, dtr = self.d_inner, self.ssm_state, self.dt_rank
            n += d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * ds)
            n += dtr * di + di * ds + di + di * d   # dt_proj, A, D, out
            # fall through to the FFN/MoE accounting (Jamba-style blocks);
            # pure-SSM archs have d_ff == 0 and add nothing.
        elif self.use_mla:
            r, rk = self.kv_lora_rank, self.rope_head_dim
            qd = self.head_dim + rk
            vd = self.v_head_dim or self.head_dim
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qd
            else:
                n += d * self.n_heads * qd
            n += d * (r + rk)                       # kv down + k_rope
            n += r * self.n_heads * (self.head_dim + vd)
            n += self.n_heads * vd * d
        else:
            hd = self.head_dim
            n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            n += self.n_heads * hd * d
        # ffn / moe
        if self.is_moe_layer(layer):
            dff = self.d_ff_expert or self.d_ff
            n += self.n_experts * 3 * d * dff
            n += self.n_shared_experts * 3 * d * dff
            n += d * self.n_experts                 # router
        else:
            n += 3 * d * self.d_ff if self.d_ff else 0
        return n

    def params_total(self) -> int:
        n = self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model     # lm head
        n += self.d_model                           # final norm
        n += sum(self.params_per_layer(l) for l in range(self.n_layers))
        return n

    def params_active(self) -> int:
        """Active (per-token) parameters — the MoE 6ND denominator."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.d_model
        for l in range(self.n_layers):
            if self.is_moe_layer(l):
                d = self.d_model
                dff = self.d_ff_expert or self.d_ff
                full = self.params_per_layer(l)
                routed = self.n_experts * 3 * d * dff
                active = self.top_k * 3 * d * dff
                n += full - routed + active
            else:
                n += self.params_per_layer(l)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_long_context(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic attention structures
    (SSM / hybrid / windowed / chunked); pure global attention is skipped
    with a DESIGN.md §4 note."""
    kinds = {cfg.layer_kind(l) for l in range(cfg.n_layers)}
    if kinds == {"attn"}:
        return False
    return True


def valid_cells(cfg: ArchConfig):
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if supports_long_context(cfg):
        names.append("long_500k")
    return [SHAPES[n] for n in names]
