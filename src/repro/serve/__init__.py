from .decode import ServeConfig, generate, prefill

__all__ = ["ServeConfig", "generate", "prefill"]
