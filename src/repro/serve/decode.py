"""Batched serving loop: prefill + greedy/temperature decode with caches.

KV-cache storage format is a precision knob (bf16 / fp8-emulated / int8
would plug in via cache_fmt — the bandit's serve-side action)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, forward, init_caches


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    compute_dtype: Any = jnp.bfloat16
    cache_fmt: Optional[int] = None   # repro.precision format id


def prefill(params, prompts: jnp.ndarray, cfg: ArchConfig,
            scfg: ServeConfig, s_max: int):
    """Feed the prompt through decode steps to warm the caches.

    prompts: (B, S_prompt) int32. Returns (caches, last_logits)."""
    b, s_prompt = prompts.shape
    caches = init_caches(cfg, b, s_max, scfg.compute_dtype)

    def step(carry, tok):
        caches, _ = carry
        logits, caches = decode_step(params, tok[:, None], caches, cfg,
                                     scfg.compute_dtype,
                                     cache_fmt=scfg.cache_fmt)
        return (caches, logits[:, 0]), None

    (caches, last), _ = jax.lax.scan(
        step, (caches, jnp.zeros((b, cfg.vocab_size), jnp.float32)),
        prompts.T)
    return caches, last


def generate(params, prompts: jnp.ndarray, cfg: ArchConfig,
             scfg: ServeConfig = ServeConfig(), key=None):
    """Greedy (or sampled) continuation. Returns (B, max_new_tokens)."""
    b, s_prompt = prompts.shape
    s_max = s_prompt + scfg.max_new_tokens
    caches, last = prefill(params, prompts, cfg, scfg, s_max)
    key = key if key is not None else jax.random.PRNGKey(0)

    def pick(logits, k):
        if scfg.temperature > 0:
            return jax.random.categorical(k, logits / scfg.temperature,
                                          axis=-1)
        return jnp.argmax(logits, axis=-1)

    def step(carry, k):
        caches, logits = carry
        tok = pick(logits, k).astype(jnp.int32)
        new_logits, caches = decode_step(params, tok[:, None], caches, cfg,
                                         scfg.compute_dtype,
                                         cache_fmt=scfg.cache_fmt)
        return (caches, new_logits[:, 0]), tok

    keys = jax.random.split(key, scfg.max_new_tokens)
    (_, _), toks = jax.lax.scan(step, (caches, last), keys)
    return toks.T                                  # (B, new_tokens)
