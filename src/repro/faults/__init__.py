"""Deterministic seeded fault injection (DESIGN.md §11.3).

Per-test::

    from repro.faults import FaultSpec, injected
    with injected(FaultSpec("batcher.flush", "raise", p=0.5), seed=7):
        ...

Chaos runs (CI `chaos` job)::

    REPRO_FAULTS="solver.outcome:divergence:p=0.1" REPRO_FAULTS_SEED=3 \
        python -m pytest tests/test_faults.py -k chaos
"""
from repro.faults.injector import (ENV_PLAN, ENV_SEED, KINDS, SITES,
                                   FaultInjected, FaultInjector, FaultSpec,
                                   active, corrupt_outcome, from_env,
                                   injected, install, maybe_raise,
                                   uninstall, wrap_clock)

__all__ = [
    "ENV_PLAN", "ENV_SEED", "FaultInjected", "FaultInjector", "FaultSpec",
    "KINDS", "SITES", "active", "corrupt_outcome", "from_env", "injected",
    "install", "maybe_raise", "uninstall", "wrap_clock",
]
