"""Deterministic, seeded fault injection for the serving stack
(DESIGN.md §11.3).

A `FaultInjector` holds a list of `FaultSpec`s — (site, kind, firing
policy) triples — and is consulted from fixed *injection points*
threaded through the production code: the solver outcome path, executor
dispatch, micro-batcher flush, registry I/O, trajectory-log writes, and
the HTTP request path. With no injector installed every injection point
is a no-op costing one module-attribute read, so production traffic
pays nothing.

Determinism is the contract: each spec owns a `random.Random(seed ^
spec_index)` stream and fires on its own hit counter, so a test (or a
CI chaos run pinned to `REPRO_FAULTS_SEED`) sees the exact same fault
schedule every run. Faults are injected in two ways:

  * per-test: ``with injected(FaultSpec("batcher.flush", "raise")): ...``
  * via env for chaos runs: ``REPRO_FAULTS="solver.outcome:nan:p=0.1;
    trajlog.write:io_error:p=0.05:max=3" REPRO_FAULTS_SEED=7 pytest ...``

Fault kinds:

  ``nan``          corrupt an `Outcome`: every metric (and cost) → NaN,
                   status preserved — the poisoned-reward vector the
                   breaker quarantine must stop.
  ``divergence``   corrupt an `Outcome`: status → FAILED, residual-like
                   metrics → +inf — a diverged solve.
  ``raise``        raise `FaultInjected` (RuntimeError) at the site.
  ``io_error``     raise `OSError` at the site (registry/log I/O).
  ``delay``        sleep `value` seconds at the site (slow solves).
  ``clock_skew``   advance a wrapped clock by `value` seconds per fire.

Every fire is counted fail-open in
``repro_faults_injected_total{site,kind}`` so a chaos run's schedule is
visible on the same /metrics surface it is perturbing.
"""
from __future__ import annotations

import dataclasses
import math
import os
import random
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: Injection points threaded through the serving stack. Sites are part
#: of the public contract (tests and REPRO_FAULTS plans name them);
#: DESIGN.md §11.3 carries the inventory with the guarding layer.
SITES = (
    "solver.outcome",     # corrupt a solved Outcome (batcher + engine)
    "engine.solve",       # raise inside the engine solve cache
    "executor.dispatch",  # raise/delay inside SolveExecutor.dispatch
    "batcher.flush",      # raise/delay inside a micro-batch flush
    "registry.io",        # I/O error in snapshot publish/promote/load
    "trajlog.write",      # I/O error appending to the trajectory log
    "http.request",       # raise/delay in the HTTP dispatch path
    "clock",              # skew a wrap_clock()-wrapped server clock
)

KINDS = ("nan", "divergence", "raise", "io_error", "delay", "clock_skew")

ENV_PLAN = "REPRO_FAULTS"
ENV_SEED = "REPRO_FAULTS_SEED"


class FaultInjected(RuntimeError):
    """Raised at an injection point by a ``raise``-kind spec."""


@dataclasses.dataclass
class FaultSpec:
    """One fault: where, what, and the (deterministic) firing policy.

    ``p`` is the per-hit firing probability, drawn from the spec's own
    seeded stream; ``after`` skips the first N matching hits; hits
    beyond ``max_fires`` fires never fire again (lets a chaos fault
    exhaust itself so recovery paths are exercised too). ``match`` is a
    code-only predicate over the injection point's context kwargs
    (e.g. ``lambda ctx: not ctx.get("safe_arm")``)."""

    site: str
    kind: str
    p: float = 1.0
    after: int = 0
    max_fires: Optional[int] = None
    value: float = 0.05         # seconds, for delay / clock_skew
    match: Optional[Callable[[dict], bool]] = None

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")


class FaultInjector:
    """Deterministic fault scheduler over a list of `FaultSpec`s."""

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        # One independent stream + hit/fire counter per spec: adding a
        # spec to a plan never perturbs the schedule of the others.
        self._rngs = [random.Random((self.seed << 8) ^ i)
                      for i in range(len(self.specs))]
        self.hits: List[int] = [0] * len(self.specs)
        self.fires: List[int] = [0] * len(self.specs)

    def fire(self, site: str, **ctx) -> Optional[FaultSpec]:
        """First spec that fires at `site` for this hit, else None."""
        fired = None
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if spec.match is not None:
                    try:
                        if not spec.match(ctx):
                            continue
                    except Exception:
                        continue
                self.hits[i] += 1
                if self.hits[i] <= spec.after:
                    continue
                if (spec.max_fires is not None
                        and self.fires[i] >= spec.max_fires):
                    continue
                if spec.p < 1.0 and self._rngs[i].random() >= spec.p:
                    continue
                self.fires[i] += 1
                fired = spec
                break
        if fired is not None:
            _count_fire(site, fired.kind)
        return fired

    def counts(self) -> Dict[Tuple[str, str], Tuple[int, int]]:
        """(site, kind) -> (hits, fires) for every spec."""
        with self._lock:
            return {(s.site, s.kind): (h, f) for s, h, f
                    in zip(self.specs, self.hits, self.fires)}


def _count_fire(site: str, kind: str) -> None:
    """Fail-open fire counter on the process-default metrics registry —
    a chaos run's fault schedule shows up on the /metrics surface it is
    perturbing (same pattern as the registry/engine lifecycle counters)."""
    try:
        from repro.obs.metrics import default_registry
        default_registry().counter(
            "repro_faults_injected_total",
            "Faults fired by the injection subsystem, by site and kind.",
            ("site", "kind")).labels(site=site, kind=kind).inc()
    except Exception:
        pass


# ---------------------------------------------------------------------------
# Process-global installation (per-test via `injected`, global via env)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None
_ENV_PARSED = False


def install(injector: Optional[FaultInjector]) -> None:
    """Install `injector` as the process-global fault source (None
    uninstalls). Prefer the `injected` context manager in tests."""
    global _ACTIVE, _ENV_PARSED
    _ACTIVE = injector
    _ENV_PARSED = True          # explicit install overrides the env plan


def uninstall() -> None:
    """Remove any installed injector and re-arm env-plan discovery (the
    next `active()` call re-reads REPRO_FAULTS)."""
    global _ACTIVE, _ENV_PARSED
    _ACTIVE = None
    _ENV_PARSED = False


def active() -> Optional[FaultInjector]:
    """The installed injector; lazily parses REPRO_FAULTS once when
    nothing was installed explicitly (the chaos-run entry point)."""
    global _ACTIVE, _ENV_PARSED
    if _ACTIVE is None and not _ENV_PARSED:
        _ENV_PARSED = True
        plan = os.environ.get(ENV_PLAN, "").strip()
        if plan:
            _ACTIVE = from_env(plan,
                               int(os.environ.get(ENV_SEED, "0") or 0))
    return _ACTIVE


@contextmanager
def injected(*specs: FaultSpec, seed: int = 0):
    """Install a fresh injector for the `with` body, restoring whatever
    was active before (the per-test entry point)."""
    global _ACTIVE, _ENV_PARSED
    prev, prev_parsed = _ACTIVE, _ENV_PARSED
    inj = FaultInjector(specs, seed=seed)
    _ACTIVE = inj
    _ENV_PARSED = True
    try:
        yield inj
    finally:
        _ACTIVE, _ENV_PARSED = prev, prev_parsed


def from_env(plan: str, seed: int = 0) -> FaultInjector:
    """Parse a ``REPRO_FAULTS`` plan string into an injector.

    Grammar: ``site:kind[:p=F][:after=N][:max=N][:value=F]`` joined by
    ``;``. Example::

        solver.outcome:divergence:p=0.15;trajlog.write:io_error:max=3
    """
    specs: List[FaultSpec] = []
    for part in plan.split(";"):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) < 2:
            raise ValueError(f"bad fault spec {part!r}: need site:kind")
        kwargs: dict = {}
        for opt in fields[2:]:
            k, _, v = opt.partition("=")
            k = k.strip()
            if k == "p":
                kwargs["p"] = float(v)
            elif k == "after":
                kwargs["after"] = int(v)
            elif k == "max":
                kwargs["max_fires"] = int(v)
            elif k == "value":
                kwargs["value"] = float(v)
            else:
                raise ValueError(f"unknown fault option {opt!r} in {part!r}")
        specs.append(FaultSpec(fields[0].strip(), fields[1].strip(),
                               **kwargs))
    return FaultInjector(specs, seed=seed)


# ---------------------------------------------------------------------------
# Injection-point helpers (what production code calls)
# ---------------------------------------------------------------------------

def maybe_raise(site: str, **ctx) -> None:
    """Raise at `site` when a ``raise``/``io_error`` spec fires; apply
    ``delay`` specs too (a slow solve is observed at the same points an
    exception would be)."""
    inj = active()
    if inj is None:
        return
    spec = inj.fire(site, **ctx)
    if spec is None:
        return
    if spec.kind == "raise":
        raise FaultInjected(f"injected fault at {site}")
    if spec.kind == "io_error":
        raise OSError(f"injected I/O error at {site}")
    if spec.kind == "delay":
        time.sleep(max(float(spec.value), 0.0))


def corrupt_outcome(site: str, outcome, **ctx):
    """Return `outcome`, possibly corrupted by a ``nan``/``divergence``
    spec at `site` (other kinds at the site behave as in maybe_raise)."""
    inj = active()
    if inj is None:
        return outcome
    spec = inj.fire(site, **ctx)
    if spec is None:
        return outcome
    from repro.core.task import FAILED, Outcome
    if spec.kind == "nan":
        # Healthy-looking status with poisoned numbers: the reward
        # computed from these metrics is NaN — the quarantine test case.
        return Outcome(status=int(outcome.status), cost=math.nan,
                       metrics={k: math.nan for k in outcome.metrics})
    if spec.kind == "divergence":
        return Outcome(status=FAILED, cost=float(outcome.cost),
                       metrics={k: math.inf for k in outcome.metrics})
    if spec.kind == "raise":
        raise FaultInjected(f"injected fault at {site}")
    if spec.kind == "io_error":
        raise OSError(f"injected I/O error at {site}")
    if spec.kind == "delay":
        time.sleep(max(float(spec.value), 0.0))
    return outcome


def wrap_clock(clock: Callable[[], float]) -> Callable[[], float]:
    """Wrap a clock callable so ``clock_skew`` specs at site ``clock``
    accumulate an offset (each fire adds `value` seconds). With no
    injector active the wrapper is a transparent pass-through."""
    offset = [0.0]

    def skewed() -> float:
        inj = active()
        if inj is not None:
            spec = inj.fire("clock")
            if spec is not None and spec.kind == "clock_skew":
                offset[0] += float(spec.value)
        return clock() + offset[0]

    return skewed
