"""Pure-jnp oracle for kernels/flash_attention: masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30


def flash_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              kind: str = "attn", window: int = 0, chunk: int = 0,
              scale: float | None = None, softcap: float = 0.0,
              groups: int = 1) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BHkv, Sk, D). Causal, optional window/chunk."""
    bh, sq, d = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.repeat(k, groups, axis=0)
    v = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = qp >= kp
    if kind == "local" and window:
        mask &= (qp - kp) < window
    if kind == "chunked" and chunk:
        mask &= (qp // chunk) == (kp // chunk)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
