from .ops import flash_attention_op
from .ref import flash_ref

__all__ = ["flash_attention_op", "flash_ref"]
