"""Jitted public wrapper: (B, S, H, D) model layout -> flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .flash import DEFAULT_BK, DEFAULT_BQ, flash_attention_pallas

_KIND = {"attn": 0, "local": 1, "chunked": 2}


def flash_attention_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       kind: str = "attn", window: int = 0, chunk: int = 0,
                       softcap: float = 0.0, scale: float | None = None,
                       bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                       interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    assert sq % bq_ == 0 and sk % bk_ == 0, "pad sequence to block multiple"
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    iparams = jnp.asarray([_KIND[kind], window or 0, chunk or 0], jnp.int32)
    fparams = jnp.asarray([scale, softcap], jnp.float32)
    o = flash_attention_pallas(qf, kf, vf, iparams, fparams, groups=groups,
                               bq=bq_, bk=bk_, interpret=interpret)
    return o.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
