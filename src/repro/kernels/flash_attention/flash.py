"""Pallas TPU kernel: blockwise online-softmax (flash) attention forward.

Covers the attention flavors in the assigned archs: causal global, sliding
window (gemma2), chunked (llama4), attention-logit softcap (gemma2), GQA
head grouping — selected by runtime SMEM parameters, so one compiled kernel
serves all layer kinds.

Grid: (B*H, Sq/bq, Sk/bk), k-dim innermost; the (m, l, acc) online-softmax
state lives in VMEM scratch that persists across the sequential k-steps
(canonical TPU flash pattern). Fully-masked k-blocks (beyond the causal
frontier / outside the window or chunk) are skipped with pl.when — the same
block-sparsity the roofline credits for sub-quadratic attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(iparams_ref, fparams_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bq, bk, nk):
    """iparams: int32[3] = [kind, window, chunk] (kind: 0 global, 1 local,
    2 chunked); fparams: f32[2] = [scale, softcap (0 = off)]."""
    kind = iparams_ref[0]
    window = iparams_ref[1]
    chunk = iparams_ref[2]
    scale = fparams_ref[0]
    cap = fparams_ref[1]

    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level skip: causal frontier and window/chunk left edges.
    q_lo, q_hi = qb * bq, qb * bq + bq - 1
    k_lo = kb * bk
    live = k_lo <= q_hi                                  # causal
    live &= jnp.where(kind == 1, k_lo + bk - 1 > q_lo - window, True)
    live &= jnp.where(kind == 2, k_lo + bk - 1 >= (q_lo // chunk) * chunk,
                      True)

    @pl.when(live)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(cap > 0, cap * jnp.tanh(s / jnp.maximum(cap, 1e-6)),
                      s)
        mask = q_pos >= k_pos
        mask &= jnp.where(kind == 1, (q_pos - k_pos) < window, True)
        mask &= jnp.where(kind == 2, (q_pos // chunk) == (k_pos // chunk),
                          True)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kb == nk - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0, 1.0, l)
        o_ref[...] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           iparams: jnp.ndarray, fparams: jnp.ndarray, *,
                           groups: int = 1, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BHkv, Sk, D) with BH = BHkv * groups.
    Sq % bq == 0 and Sk % bk == 0 (ops.py pads)."""
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    assert bh == bhkv * groups and sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((None, bk, d), lambda h, i, j, g=groups: (h // g, j, 0)),
            pl.BlockSpec((None, bk, d), lambda h, i, j, g=groups: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(iparams, fparams, q, k, v)
