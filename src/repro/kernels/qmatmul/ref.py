"""Pure-jnp oracle for kernels/qmatmul: chop inputs, f32-accumulate matmul,
optionally chop the output — identical semantics to the fused kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.precision import chop, fma_barrier, tree_sum

# TPU lane width. Single source of truth for the K padding that both the
# pallas kernel (qmatmul.qmv_pallas via ops.qmv_op) and this oracle
# apply: identical reduction shape is the bit-exactness contract
# (DESIGN.md §6.2). Defined here so the oracle stays pallas-free.
LANE = 128


def qmv_ref(a: jnp.ndarray, v: jnp.ndarray, fmt_id,
            chop_out: bool = True) -> jnp.ndarray:
    """Bit-exact jnp oracle for the fused chopped matvec (`ops.qmv_op`).

    Shares the kernel's reduction shape: K is zero-padded to the LANE
    multiple and reduced with one row-sum in the f32 carrier (per-row
    reductions are tiling-invariant over rows, but NOT over reduction
    length — hence the shared padding; DESIGN.md §6.2). Works on any
    float carrier; the pallas kernel itself is f32-only.
    """
    K = a.shape[-1]
    Kp = -(-K // LANE) * LANE
    ap = jnp.pad(a, ((0, 0), (0, Kp - K)))
    vp = jnp.pad(v, (0, Kp - K))
    ac = chop(ap, fmt_id)
    vc = chop(vp, fmt_id)
    # Carrier accumulation, fully pinned: the product is materialized
    # behind the FMA barrier (no context-dependent mul-into-reduce
    # contraction) and the row-sum is the fixed pairwise tree (no
    # context-dependent accumulation order) — the reduction shape alone
    # does not pin the bits once the surrounding program changes, e.g.
    # in a shard_map body (DESIGN.md §6.2, §7.3). The kernel body
    # executes the same barrier + tree.
    out = tree_sum(fma_barrier(ac * vc[None, :]), axis=1)
    if chop_out:
        out = chop(out, fmt_id)
    return out


def qgemm_ref(a: jnp.ndarray, b: jnp.ndarray, fmt_id,
              chop_out: bool = True,
              chop_inputs: bool = True) -> jnp.ndarray:
    """Bit-exact jnp oracle for the pinned-contract chopped GEMM
    (`ops.qgemm_op` — the `backend.chop_matmul` implementation).

    Contract (DESIGN.md §6.2): K is zero-padded to the LANE multiple and
    reduced by ONE carrier dot. The dot's per-element reduction over K is
    invariant to how M and N are tiled (measured on XLA:CPU, including
    under vmap) but NOT to the reduction length — hence the shared K
    padding, exactly as in `qmv_ref`. The kernel runs the same dot on
    (bm, Kp) x (Kp, bn) tiles, so both backends produce identical bits.
    Works on any float carrier; the pallas kernel itself is f32-only.
    """
    K = a.shape[-1]
    Kp = -(-K // LANE) * LANE
    ap = jnp.pad(a, ((0, 0), (0, Kp - K)))
    bp = jnp.pad(b, ((0, Kp - K), (0, 0)))
    if chop_inputs:
        ap = chop(ap, fmt_id)
        bp = chop(bp, fmt_id)
    out = jnp.dot(ap, bp, preferred_element_type=a.dtype)
    if chop_out:
        out = chop(out, fmt_id)
    return out


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray, fmt_id,
                chop_out: bool = True) -> jnp.ndarray:
    a32 = chop(a.astype(jnp.float32), fmt_id)
    b32 = chop(b.astype(jnp.float32), fmt_id)
    out = jnp.dot(a32, b32, preferred_element_type=jnp.float32)
    if chop_out:
        out = chop(out, fmt_id)
    return out


def qmatmul_ref_blocked(a: jnp.ndarray, b: jnp.ndarray, fmt_id, bk: int,
                        chop_out: bool = True) -> jnp.ndarray:
    """Bit-exact oracle for the kernel's K-blocked accumulation order:
    f32 partial dot per K-block, summed sequentially."""
    K = a.shape[1]
    assert K % bk == 0
    a32 = chop(a.astype(jnp.float32), fmt_id)
    b32 = chop(b.astype(jnp.float32), fmt_id)
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for k0 in range(0, K, bk):
        acc = acc + jnp.dot(a32[:, k0:k0 + bk], b32[k0:k0 + bk, :],
                            preferred_element_type=jnp.float32)
    if chop_out:
        acc = chop(acc, fmt_id)
    return acc
