"""Pure-jnp oracle for kernels/qmatmul: chop inputs, f32-accumulate matmul,
optionally chop the output — identical semantics to the fused kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.precision import chop


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray, fmt_id,
                chop_out: bool = True) -> jnp.ndarray:
    a32 = chop(a.astype(jnp.float32), fmt_id)
    b32 = chop(b.astype(jnp.float32), fmt_id)
    out = jnp.dot(a32, b32, preferred_element_type=jnp.float32)
    if chop_out:
        out = chop(out, fmt_id)
    return out


def qmatmul_ref_blocked(a: jnp.ndarray, b: jnp.ndarray, fmt_id, bk: int,
                        chop_out: bool = True) -> jnp.ndarray:
    """Bit-exact oracle for the kernel's K-blocked accumulation order:
    f32 partial dot per K-block, summed sequentially."""
    K = a.shape[1]
    assert K % bk == 0
    a32 = chop(a.astype(jnp.float32), fmt_id)
    b32 = chop(b.astype(jnp.float32), fmt_id)
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for k0 in range(0, K, bk):
        acc = acc + jnp.dot(a32[:, k0:k0 + bk], b32[k0:k0 + bk, :],
                            preferred_element_type=jnp.float32)
    if chop_out:
        acc = chop(acc, fmt_id)
    return acc
