from .ops import make_fmt_params, qgemm_op, qmatmul_op, qmv_op
from .ref import qgemm_ref, qmatmul_ref, qmatmul_ref_blocked, qmv_ref

__all__ = ["qmatmul_op", "qmatmul_ref", "qmatmul_ref_blocked",
           "qgemm_op", "qgemm_ref", "qmv_op", "qmv_ref", "make_fmt_params"]
