from .ops import make_fmt_params, qmatmul_op, qmv_op
from .ref import qmatmul_ref, qmatmul_ref_blocked, qmv_ref

__all__ = ["qmatmul_op", "qmatmul_ref", "qmatmul_ref_blocked",
           "qmv_op", "qmv_ref", "make_fmt_params"]
