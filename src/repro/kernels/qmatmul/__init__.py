from .ops import make_fmt_params, qmatmul_op
from .ref import qmatmul_ref, qmatmul_ref_blocked

__all__ = ["qmatmul_op", "qmatmul_ref", "qmatmul_ref_blocked",
           "make_fmt_params"]
