"""Pallas TPU kernel: format-emulated matmul (the paper's "run step i in
precision u_i", fused).

The autotuner's chosen precision is enforced by rounding both operands to
the selected format *inside the MXU tile loop* (VMEM-resident), accumulating
in fp32 — the semantics of real mixed-precision GEMM hardware (bf16 x bf16
-> f32 MXU) generalized to any emulated format, without the two extra HBM
round trips a standalone chop pass would cost.

Grid (M/bm, N/bn, K/bk) with K innermost; fp32 VMEM scratch accumulator;
optional output rounding (for "store in format u" steps).

Format parameters live in SMEM as runtime data: one compiled kernel serves
every precision action (DESIGN.md §3.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.precision.chop import _chop_core

from .ref import LANE

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def _qmatmul_kernel(fmt_ref, a_ref, b_ref, o_ref, acc_ref):
    """fmt_ref (SMEM): int32[5] = [t, emin, xmax_bits, saturate, chop_out]."""
    t = fmt_ref[0]
    emin = fmt_ref[1]
    xmax_bits = fmt_ref[2].astype(jnp.uint32)
    saturate = fmt_ref[3] != 0

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _chop_core(a_ref[...], t, emin, 0, xmax_bits, saturate)
    b = _chop_core(b_ref[...], t, emin, 0, xmax_bits, saturate)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _emit():
        acc = acc_ref[...]
        chopped = _chop_core(acc, t, emin, 0, xmax_bits, saturate)
        o_ref[...] = jnp.where(fmt_ref[4] != 0, chopped, acc)


QMV_BM = 256  # rows of A per grid step (multiple of LANE)


def _qmv_kernel(fmt_ref, a_ref, v_ref, o_ref):
    """Fused chopped matvec tile: chop operands in VMEM, multiply, row-sum.

    fmt_ref (SMEM): int32[5] = [t, emin, xmax_bits, saturate, chop_out].
    a_ref: (bm, Kp) tile of A; v_ref: (1, Kp); o_ref: (bm // LANE, LANE).

    The reduction is the VPU-friendly row-sum over the full (lane-padded)
    K axis in one block — deliberately NOT an MXU dot: a matvec is
    memory-bound, and the single-block row-sum gives the jnp oracle
    (`ref.qmv_ref`) an identical reduction: the product is materialized
    behind the FMA barrier and accumulated by the fixed pairwise tree,
    the exact ops the oracle traces, which is what makes the backend
    dispatch layer bit-exact across implementations and program
    contexts (DESIGN.md §6.2, §7.3). Per-row reductions are invariant
    to tiling over rows, so the grid over M does not perturb results.
    """
    from repro.precision import fma_barrier, tree_sum
    t = fmt_ref[0]
    emin = fmt_ref[1]
    xmax_bits = fmt_ref[2].astype(jnp.uint32)
    saturate = fmt_ref[3] != 0
    a = _chop_core(a_ref[...], t, emin, 0, xmax_bits, saturate)
    v = _chop_core(v_ref[...], t, emin, 0, xmax_bits, saturate)
    out = tree_sum(fma_barrier(a * v), axis=1)         # carrier accumulation
    chopped = _chop_core(out, t, emin, 0, xmax_bits, saturate)
    out = jnp.where(fmt_ref[4] != 0, chopped, out)
    o_ref[...] = out.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def qmv_pallas(a: jnp.ndarray, v: jnp.ndarray, fmt_params: jnp.ndarray,
               *, bm: int = QMV_BM, interpret: bool = True) -> jnp.ndarray:
    """a: (Mp, Kp) f32, v: (1, Kp) f32 — padded by ops.qmv_op so that
    Mp % bm == 0, Kp % LANE == 0, bm % LANE == 0. fmt_params: int32[5].
    Returns the fused chopped matvec as (Mp,)."""
    M, K = a.shape
    assert M % bm == 0 and K % LANE == 0 and bm % LANE == 0
    out = pl.pallas_call(
        _qmv_kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm // LANE, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M // LANE, LANE), jnp.float32),
        interpret=interpret,
    )(fmt_params, a, v)
    return out.reshape(M)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def qmatmul_pallas(a: jnp.ndarray, b: jnp.ndarray, fmt_params: jnp.ndarray,
                   *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK,
                   interpret: bool = True) -> jnp.ndarray:
    """a: (M, K) f32, b: (K, N) f32 — M/N/K padded to block multiples by
    ops.qmatmul_op. fmt_params: int32[5]."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(fmt_params, a, b)
