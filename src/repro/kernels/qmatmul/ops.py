"""Jitted public wrapper for qmatmul: padding + format-id -> SMEM params."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chop.ops import _FMT_PACKED

from .qmatmul import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, LANE, QMV_BM,
                      qmatmul_pallas, qmv_pallas)


def make_fmt_params(fmt_id, chop_out: bool = True) -> jnp.ndarray:
    """int32[5] = [t, emin, xmax_bits, saturate, chop_out]."""
    row = jnp.asarray(_FMT_PACKED)[jnp.asarray(fmt_id, jnp.int32)]
    return jnp.concatenate(
        [row, jnp.asarray([1 if chop_out else 0], jnp.int32)])


def _pad_to(x, m0, m1):
    p0 = -x.shape[0] % m0
    p1 = -x.shape[1] % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def qmv_op(a: jnp.ndarray, v: jnp.ndarray, fmt_id, *,
           chop_out: bool = True, bm: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Fused chopped matvec for arbitrary (M, K) x (K,) f32 operands.

    Pads K to the LANE multiple shared with `ref.qmv_ref` (the reduction
    shape is part of the bit-exactness contract, DESIGN.md §6.2) and M to
    the row-block multiple, then runs the single-K-block row-sum kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if a.dtype != jnp.float32 or v.dtype != jnp.float32:
        raise TypeError("qmv_op targets the f32 TPU carrier; got "
                        f"{a.dtype} x {v.dtype}")
    M, K = a.shape
    bm = min(bm or QMV_BM,
             max(LANE, 1 << int(np.ceil(np.log2(max(M, 1))))))
    Kp = -(-K // LANE) * LANE
    ap = _pad_to(a, bm, LANE)
    vp = jnp.pad(v, (0, Kp - K)).reshape(1, Kp)
    out = qmv_pallas(ap, vp, make_fmt_params(fmt_id, chop_out),
                     bm=bm, interpret=interpret)
    return out[:M]


# Largest lane-padded K the single-K-block qgemm kernel keeps in VMEM
# per tile pair; larger reductions fall back to the bit-identical oracle.
QGEMM_MAX_KP = 512


def qgemm_op(a: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
             chop_out: bool = True, bm: int | None = None,
             bn: int | None = None,
             interpret: bool | None = None) -> jnp.ndarray:
    """Pinned-contract chopped GEMM for (M, K) x (K, N) f32 operands —
    the `backend.chop_matmul` fast path (DESIGN.md §6.2).

    Pads K to the LANE multiple shared with `ref.qgemm_ref` and runs the
    qmatmul kernel with a SINGLE K block (`bk = Kp`), so the kernel's
    per-tile dot performs the same length-Kp reduction as the oracle's
    full-shape dot; dot reductions are M/N-tile-invariant (measured),
    which is what makes the two backends bit-identical. Reductions
    beyond `QGEMM_MAX_KP` route to the oracle (bit-identical by the same
    contract — a pure VMEM-budget choice).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if a.dtype != jnp.float32 or b.dtype != jnp.float32:
        raise TypeError("qgemm_op targets the f32 TPU carrier; got "
                        f"{a.dtype} x {b.dtype}")
    M, K = a.shape
    _, N = b.shape
    Kp = -(-K // LANE) * LANE
    if Kp > QGEMM_MAX_KP:
        from .ref import qgemm_ref
        return qgemm_ref(a, b, fmt_id, chop_out=chop_out)
    bm = min(bm or DEFAULT_BM, max(8, 1 << int(np.ceil(np.log2(max(M, 1))))))
    bn = min(bn or DEFAULT_BN, max(128, 1 << int(np.ceil(np.log2(max(N, 1))))))
    ap = _pad_to(a, bm, Kp)
    bp = _pad_to(b, Kp, bn)
    out = qmatmul_pallas(ap, bp, make_fmt_params(fmt_id, chop_out),
                         bm=bm, bn=bn, bk=Kp, interpret=interpret)
    return out[:M, :N]


def qmatmul_op(a: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
               chop_out: bool = True, bm: int | None = None,
               bn: int | None = None, bk: int | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Mixed-precision-emulated matmul for arbitrary (M,K)x(K,N) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, K = a.shape
    _, N = b.shape
    bm = min(bm or DEFAULT_BM, max(8, 1 << int(np.ceil(np.log2(max(M, 1))))))
    bn = min(bn or DEFAULT_BN, max(128, 1 << int(np.ceil(np.log2(max(N, 1))))))
    bk = min(bk or DEFAULT_BK, max(128, 1 << int(np.ceil(np.log2(max(K, 1))))))
    ap = _pad_to(a.astype(jnp.float32), bm, bk)
    bp = _pad_to(b.astype(jnp.float32), bk, bn)
    out = qmatmul_pallas(ap, bp, make_fmt_params(fmt_id, chop_out),
                         bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]
