"""Jitted public wrapper for the trisolve kernel: padding + SMEM params."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.chop.ops import make_fmt_params

from .ref import pad_unit, trisolve_ref
from .trisolve import MAX_N, trisolve_pallas


def trisolve_op(Lu: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
                lower: bool, block: int = 128,
                interpret: bool | None = None) -> jnp.ndarray:
    """Blocked triangular solve on the combined LU matrix, f32 carrier.

    Identity-pads n to the block multiple shared with `ref.trisolve_ref`
    (padded shapes and reduction lengths are part of the bit-exactness
    contract, DESIGN.md §6.2) and runs the single-launch kernel. Systems
    larger than `trisolve.MAX_N` exceed the whole-matrix VMEM budget and
    route to the bit-identical oracle — a pure perf choice, like the
    pallas backend's `chop_min_elems` routing.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if Lu.dtype != jnp.float32 or b.dtype != jnp.float32:
        raise TypeError("trisolve_op targets the f32 TPU carrier; got "
                        f"{Lu.dtype} x {b.dtype}")
    n = Lu.shape[-1]
    n_pad = -(-n // block) * block
    if n_pad > MAX_N:
        return trisolve_ref(Lu, b, fmt_id, lower=lower, block=block)
    Lp, bp = pad_unit(Lu, b, n_pad)
    out = trisolve_pallas(Lp, bp.reshape(1, n_pad), make_fmt_params(fmt_id),
                          lower=lower, block=block, interpret=interpret)
    return out[0, :n]
