from .ops import trisolve_op
from .ref import trisolve_ref

__all__ = ["trisolve_op", "trisolve_ref"]
