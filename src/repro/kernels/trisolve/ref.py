"""Pure-jnp oracle for kernels/trisolve — and the shared computational core.

`_trisolve_core` is the single source of truth for the blocked
substitution semantics: the Pallas kernel body (`trisolve.trisolve_pallas`)
executes this exact function on its VMEM-resident blocks, and the jnp
oracle (`trisolve_ref`, the `JnpBackend.chop_trisolve` implementation)
executes it directly. Sharing the traced ops — not just the reduction
*shape* — is what makes the two backends bit-identical by construction
(DESIGN.md §6.2), the same way `precision.chop._chop_core` is shared by
the chop kernel and its oracle.

Blocked semantics (DESIGN.md §6.4): for block row i,

  * off-diagonal tiles are chopped matvecs with the strict path's
    product semantics — products rounded to the format, per-tile
    row-sums accumulated *unrounded* in the carrier (a tiled reduction
    over the strict row's prefix sum);
  * one rounding on the off-diagonal subtraction `t = chop(b_i - acc)`;
  * the diagonal block is solved by the strict row loop with the strict
    path's op-level semantics: products rounded, masked carrier row-sum,
    one rounding on the subtraction and (upper) one on the division —
    see `solvers.triangular` for why the division re-rounds.

This module is deliberately pallas-free so the jnp backend never
imports the Pallas toolchain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.precision.chop import chop as _chop_runtime
from repro.precision.chop import tree_sum

# Block sizes are lane-aligned by the default policy (128); the core
# itself only requires n % block == 0 (ops/ref pad via `pad_unit`).


def _trisolve_core(Lu: jnp.ndarray, b2d: jnp.ndarray, chop_fn, *,
                   lower: bool, block: int) -> jnp.ndarray:
    """Blocked forward/backward substitution on the combined LU matrix.

    Lu: (n, n) carrier, n % block == 0. Lower solves read the strictly
    lower triangle with an implicit unit diagonal; upper solves read the
    upper triangle including the diagonal. b2d: (1, n). chop_fn: the
    elementwise round-to-format closure (traced format parameters).
    Returns y: (1, n).
    """
    n = Lu.shape[-1]
    nb = n // block
    Luc = chop_fn(Lu)
    bc = chop_fn(b2d)
    idx = lax.broadcasted_iota(jnp.int32, (1, block), 1)
    rr = lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cc = lax.broadcasted_iota(jnp.int32, (block, block), 1)
    zero = jnp.zeros((), Lu.dtype)

    def blk(bi, y):
        i = bi if lower else nb - 1 - bi
        r0 = i * block

        def off_body(j, acc):
            tile = lax.dynamic_slice(Luc, (r0, j * block), (block, block))
            yj = lax.dynamic_slice(y, (0, j * block), (1, block))
            # Chopped matvec tile, strict-path product semantics:
            # products rounded to the format, carrier row-sum. Rounding
            # the products (an integer-bitcast chain) blocks FMA
            # contraction of the multiply into the row-sum, and the
            # fixed pairwise tree pins the accumulation order, both of
            # which XLA would otherwise pick per program context
            # (DESIGN.md §6.2, §7.3).
            return acc + tree_sum(chop_fn(tile * yj), axis=1)[None, :]

        lo, hi = (0, i) if lower else (i + 1, nb)
        acc = lax.fori_loop(lo, hi, off_body,
                            jnp.zeros((1, block), Lu.dtype))
        rhs = lax.dynamic_slice(bc, (0, r0), (1, block))
        t = chop_fn(rhs - acc)

        diag = lax.dynamic_slice(Luc, (r0, r0), (block, block))
        # Mask to the triangle the solve reads (unit diagonal of a lower
        # solve is implicit and never multiplied).
        tri = jnp.where(rr > cc if lower else rr <= cc, diag, zero)

        def row(rloc, yb):
            r = rloc if lower else block - 1 - rloc
            lrow = lax.dynamic_slice(tri, (r, 0), (1, block))
            prods = chop_fn(lrow * yb)
            mask = (idx < r) if lower else (idx > r)
            s = tree_sum(jnp.where(mask, prods, zero).reshape(-1))
            val = chop_fn(t[0, r] - s)
            if not lower:
                d = tri[r, r]
                safe = jnp.where(d == 0, jnp.ones((), Lu.dtype), d)
                val = chop_fn(val / safe)
            return lax.dynamic_update_slice(yb, val.reshape(1, 1), (0, r))

        yb = lax.fori_loop(0, block, row, jnp.zeros((1, block), Lu.dtype))
        return lax.dynamic_update_slice(y, yb, (0, r0))

    return lax.fori_loop(0, nb, blk, jnp.zeros_like(bc))


def identity_pad(M: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    """Zero-extend a square matrix to n_pad with ones on the padded
    diagonal. The single source of the solution-preserving padding
    convention shared by the blocked trisolve (here) and the blocked LU
    (`solvers/lu.lu_factor_blocked`): the identity tail solves/factors
    trivially and never couples back into the leading n x n block."""
    n = M.shape[-1]
    if n_pad == n:
        return M
    Mp = jnp.pad(M, ((0, n_pad - n), (0, n_pad - n)))
    tail = jnp.arange(n, n_pad)
    return Mp.at[tail, tail].set(jnp.ones((), M.dtype))


def pad_unit(Lu: jnp.ndarray, b: jnp.ndarray, n_pad: int):
    """Identity-extend (Lu, b) to n_pad: padded diagonal 1, padded rhs 0.

    Solution preserving — the padded rows solve 1*y = 0 and never couple
    back — and shared by the kernel wrapper and the oracle so both
    backends run the core on identical shapes (the reduction lengths are
    part of the bit-exactness contract, DESIGN.md §6.2).
    """
    n = Lu.shape[-1]
    if n_pad == n:
        return Lu, b
    return identity_pad(Lu, n_pad), jnp.pad(b, (0, n_pad - n))


@functools.partial(jax.jit, static_argnames=("lower", "block"))
def trisolve_ref(Lu: jnp.ndarray, b: jnp.ndarray, fmt_id, *,
                 lower: bool, block: int = 128) -> jnp.ndarray:
    """Bit-exact jnp oracle for the blocked trisolve kernel
    (`ops.trisolve_op`). Works on any float carrier; the Pallas kernel
    itself is f32-only. b: (n,); returns (n,).

    Jitted deliberately: XLA's eager (op-by-op) execution fuses the
    tile multiply into the row-sum differently than a compiled program
    (FMA contraction), which shifts f32 bits for formats whose chop is
    the identity on the carrier. Every solver path runs under jit, so
    the compiled program IS the contract — the oracle pins it."""
    n = Lu.shape[-1]
    n_pad = -(-n // block) * block
    Lp, bp = pad_unit(Lu, b, n_pad)

    def chop_fn(x):
        return _chop_runtime(x, fmt_id)

    out = _trisolve_core(Lp, bp.reshape(1, n_pad), chop_fn,
                         lower=lower, block=block)
    return out[0, :n]
