"""Pallas TPU kernel: blocked triangular substitution in emulated precision.

The strict row-loop forward/backward substitutions dominate GMRES-IR/CG-IR
wall time at small-to-medium n: every row is a kernel-launch-sized piece
of work with an HBM round trip on the jnp path. This kernel runs the
*whole* blocked solve — off-diagonal fused chopped-matvec tiles plus the
strict-row-loop diagonal solves — in one launch with the factor matrix
VMEM-resident, mirroring how kernels/qmatmul fuses the matvec.

The kernel body is `ref._trisolve_core`, the exact function the jnp
oracle executes: the two backends are bit-identical by construction, not
by a shared reduction *shape* (DESIGN.md §6.2). Format parameters live
in SMEM as runtime data — one compiled kernel serves every precision
action (DESIGN.md §3.4).

Whole-matrix VMEM residency caps the kernel at moderate n (the ops
wrapper routes larger systems to the oracle); the paper's Table 2/4
grids and the serving buckets sit comfortably below the cap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.precision.chop import _chop_core

from .ref import _trisolve_core

# Above this padded size the solve no longer fits VMEM: the kernel
# holds the (n, n) factor AND its chopped copy (f32: 2 * 1024^2 * 4 B
# = 8 MiB of the ~16 MiB/core budget, plus rhs/output/loop buffers);
# ops.trisolve_op falls back to the bit-identical oracle beyond it.
MAX_N = 1024


def _trisolve_kernel(fmt_ref, a_ref, b_ref, o_ref, *, lower: bool,
                     block: int):
    """fmt_ref (SMEM): int32[4] = [t, emin, xmax_bits, saturate]."""
    t = fmt_ref[0]
    emin = fmt_ref[1]
    xmax_bits = fmt_ref[2].astype(jnp.uint32)
    saturate = fmt_ref[3] != 0

    def chop_fn(x):
        return _chop_core(x, t, emin, 0, xmax_bits, saturate)

    o_ref[...] = _trisolve_core(a_ref[...], b_ref[...], chop_fn,
                                lower=lower, block=block)


@functools.partial(jax.jit,
                   static_argnames=("lower", "block", "interpret"))
def trisolve_pallas(Lu: jnp.ndarray, b2d: jnp.ndarray,
                    fmt_params: jnp.ndarray, *, lower: bool,
                    block: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Lu: (n, n) f32 with n % block == 0 (padded by ops.trisolve_op);
    b2d: (1, n) f32. fmt_params: int32[4]. Returns y as (1, n)."""
    n = Lu.shape[-1]
    assert n % block == 0, "pad to a block multiple (ops.trisolve_op)"
    return pl.pallas_call(
        functools.partial(_trisolve_kernel, lower=lower, block=block),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(fmt_params, Lu, b2d)
