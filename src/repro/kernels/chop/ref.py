"""Pure-jnp oracle for kernels/chop.

The reference is repro.precision.chop (itself validated bit-for-bit against
an exact Fraction-arithmetic oracle in tests/test_precision.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.precision import chop as _chop


def chop_ref(x: jnp.ndarray, fmt_id) -> jnp.ndarray:
    return _chop(x, fmt_id)
