"""Jitted public wrapper for the chop kernel: format-id -> SMEM params."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.precision.chop import FMT_XMAX_BITS32
from repro.precision.formats import FMT_EMIN, FMT_SATURATE, FMT_T

from .chop import BLOCK_ROWS, chop_pallas

# Packed per-format parameter rows: [t, emin, xmax_bits(int32 view), saturate]
_FMT_PACKED = np.stack([
    FMT_T.astype(np.int32),
    FMT_EMIN.astype(np.int32),
    FMT_XMAX_BITS32.view(np.int32),
    FMT_SATURATE.astype(np.int32),
], axis=1)


def make_fmt_params(fmt_id) -> jnp.ndarray:
    """int32[4] SMEM parameter row for a (possibly traced) format id."""
    return jnp.asarray(_FMT_PACKED)[jnp.asarray(fmt_id, jnp.int32)]


def chop_op(x: jnp.ndarray, fmt_id, *, block_rows: int = BLOCK_ROWS,
            interpret: bool | None = None) -> jnp.ndarray:
    """Round `x` (f32) to the format selected by the runtime `fmt_id`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return chop_pallas(x, make_fmt_params(fmt_id), block_rows=block_rows,
                       interpret=interpret)
