"""Pallas TPU kernel: quantize-to-format (the bandit's enforcement op).

Every precision action the autotuner selects is *applied* by rounding tensors
to the chosen format. Done naively (jnp.astype round-trips or the pure-jnp
chop) this costs an extra HBM round trip per tensor; as a Pallas kernel the
rounding happens on VMEM-resident tiles and can be fused into producers /
consumers (see kernels/qmatmul for the fused-matmul version).

The kernel body is the same integer RNE algorithm as
repro.precision.chop._chop_core (bit manipulation only — exact, FTZ/DAZ-
immune, and MXU/VPU-friendly: no transcendental ops). Format parameters are
runtime data living in SMEM, so one compiled kernel serves every format id
(DESIGN.md §3.4: recompile-free bandit exploration).

Layout: input is flattened and tiled (BLOCK_ROWS, 128) — (8,128)-aligned for
the f32 VPU lane structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.precision.chop import _chop_core

LANE = 128
BLOCK_ROWS = 256  # (256, 128) f32 tile = 128 KiB/buffer in VMEM


def _chop_kernel(fmt_ref, x_ref, o_ref):
    """fmt_ref (SMEM): int32[4] = [t, emin, xmax_bits(int32 view), saturate].

    emax is implied by xmax_bits, which is the only overflow check needed.
    """
    t = fmt_ref[0]
    emin = fmt_ref[1]
    xmax_bits = fmt_ref[2].astype(jnp.uint32)
    saturate = fmt_ref[3] != 0
    x = x_ref[...]
    # emax is unused by _chop_core (overflow is via xmax_bits); pass a dummy.
    o_ref[...] = _chop_core(x, t, emin, 0, xmax_bits, saturate)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def chop_pallas(x: jnp.ndarray, fmt_params: jnp.ndarray, *,
                block_rows: int = BLOCK_ROWS,
                interpret: bool = True) -> jnp.ndarray:
    """Apply round-to-format to `x` (any shape, f32) on TPU via Pallas.

    fmt_params: int32[4] = [t, emin, xmax_bits_as_int32, saturate] — runtime
    data (see ops.make_fmt_params / ops.chop_op for the format-id wrapper).
    """
    if x.dtype != jnp.float32:
        raise TypeError("chop_pallas targets the f32 TPU carrier; "
                        f"got {x.dtype}")
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_block = block_rows * LANE
    n_pad = -n % per_block
    flat = jnp.pad(flat, (0, n_pad))
    rows = flat.shape[0] // LANE
    x2 = flat.reshape(rows, LANE)

    out = pl.pallas_call(
        _chop_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),              # fmt params
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),  # x tile
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=interpret,
    )(fmt_params, x2)
    return out.reshape(-1)[:n].reshape(shape)
