from .ops import chop_op, make_fmt_params
from .ref import chop_ref

__all__ = ["chop_op", "chop_ref", "make_fmt_params"]
