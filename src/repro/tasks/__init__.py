"""Concrete `TunableTask` instantiations.

Each task binds one algorithm from `repro.solvers` to the solver-
agnostic autotuning API in `repro.core.task`, so the single
`AutotuneEngine` / `AutotuneServer` pair can train and serve it. Adding
a workload means adding a module here — the engine, trainer, service,
and registry are shared.

`adapt_legacy` coerces pre-TunableTask call signatures (a bare
`IRConfig` / `CGConfig`, or None for the historical GMRES-IR default)
into tasks; `core.task.coerce_task` defers here so the engine and
server never import a solver.
"""
from __future__ import annotations

from .base import LinearSystemTask, stack_fixed
from .cg_ir import CGIRTask
from .gmres_ir import GMRESIRTask, outcome_of_record


def adapt_legacy(obj=None, *, action_space=None, bucket_step=None,
                 min_bucket=None):
    """Adapt a legacy solver-config object into a `TunableTask`."""
    from repro.solvers.cg import CGConfig
    from repro.solvers.ir import IRConfig
    kw = dict(action_space=action_space,
              bucket_step=bucket_step if bucket_step is not None else 128,
              min_bucket=min_bucket if min_bucket is not None else 128)
    if obj is None:
        return GMRESIRTask(**kw)
    if isinstance(obj, IRConfig):
        return GMRESIRTask(ir_cfg=obj, **kw)
    if isinstance(obj, CGConfig):
        return CGIRTask(cg_cfg=obj, **kw)
    raise TypeError(f"cannot adapt {type(obj).__name__} into a TunableTask; "
                    "pass a TunableTask, an IRConfig, or a CGConfig")


__all__ = ["LinearSystemTask", "GMRESIRTask", "CGIRTask", "adapt_legacy",
           "outcome_of_record", "stack_fixed"]
