"""CG-IR as a `TunableTask` — proof the autotuning API generalizes.

Same bandit, same engine, same server as GMRES-IR; only the batched
solver and the work metric differ. Intended for SPD systems (the
`data.matrices.sparse_spd` generator); on indefinite matrices the CG
recurrence breaks down and the reward's failure path takes over.

As with GMRES-IR, `cg_cfg.blocking` (DESIGN.md §6.4) size-dispatches
the LU preconditioner construction and its per-iteration triangular
applications onto the blocked hot path for buckets at or above the
threshold.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.task import Outcome
from repro.data.matrices import LinearSystem
from repro.solvers.cg import CGConfig, cg_ir_batch_lowerable
from repro.tasks.base import LinearSystemTask, stack_fixed


class CGIRTask(LinearSystemTask):
    name = "cg_ir"
    inner_iter_metric = "n_cg"

    def __init__(self, systems: Sequence[LinearSystem] = (),
                 action_space: Optional[ActionSpace] = None,
                 cg_cfg: CGConfig = CGConfig(),
                 bucket_step: int = 128, min_bucket: int = 128,
                 backend=None, executor=None, tune_blocking: bool = False):
        super().__init__(systems, action_space, bucket_step, min_bucket,
                         backend=backend, executor=executor,
                         tune_blocking=tune_blocking)
        self.cg_cfg = cg_cfg

    def solve_rows(self, rows, action_rows: Sequence[np.ndarray],
                   chunk: int) -> List[Outcome]:
        A, b, x, acts, k = stack_fixed(rows, action_rows,
                                       self.executor.preferred_chunk(chunk))
        cfg = self.solver_cfg_for(self.cg_cfg, A.shape[-1])
        # Value-keyed lowerable: dedupes the executable with any other
        # call site (or task) running the same (cfg, backend) program
        # and gives AOT warmup its precompile target (DESIGN.md §12).
        stats = self.executor.dispatch(
            cg_ir_batch_lowerable(cfg, self.backend),
            (A, b, x, acts), A.shape[-1])
        # One host transfer for the whole stats tuple (DESIGN.md §7).
        ferr, nbe, n_outer, n_cg, status, res = (
            np.asarray(f) for f in jax.device_get(tuple(stats)))
        return [Outcome(status=int(status[j]), cost=float(n_cg[j]),
                        metrics={"ferr": float(ferr[j]),
                                 "nbe": float(nbe[j]),
                                 "n_outer": int(n_outer[j]),
                                 "n_cg": int(n_cg[j]),
                                 "res_norm": float(res[j])})
                for j in range(k)]

    def lowerable_for(self, n_pad: int):
        """AOT form (DESIGN.md §12): same (cfg, backend)-keyed lowerable
        as `solve_rows`, so warmup and live traffic share executables."""
        return cg_ir_batch_lowerable(
            self.solver_cfg_for(self.cg_cfg, int(n_pad)), self.backend)
