"""CG-IR as a `TunableTask` — proof the autotuning API generalizes.

Same bandit, same engine, same server as GMRES-IR; only the batched
solver and the work metric differ. Intended for SPD systems (the
`data.matrices.sparse_spd` generator); on indefinite matrices the CG
recurrence breaks down and the reward's failure path takes over.

As with GMRES-IR, `cg_cfg.blocking` (DESIGN.md §6.4) size-dispatches
the LU preconditioner construction and its per-iteration triangular
applications onto the blocked hot path for buckets at or above the
threshold.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.task import Outcome
from repro.data.matrices import LinearSystem
from repro.solvers.cg import CGConfig, cg_ir_batch
from repro.tasks.base import LinearSystemTask, stack_fixed


class CGIRTask(LinearSystemTask):
    name = "cg_ir"
    inner_iter_metric = "n_cg"

    def __init__(self, systems: Sequence[LinearSystem] = (),
                 action_space: Optional[ActionSpace] = None,
                 cg_cfg: CGConfig = CGConfig(),
                 bucket_step: int = 128, min_bucket: int = 128,
                 backend=None):
        super().__init__(systems, action_space, bucket_step, min_bucket,
                         backend=backend)
        self.cg_cfg = cg_cfg

    def solve_rows(self, rows, action_rows: Sequence[np.ndarray],
                   chunk: int) -> List[Outcome]:
        A, b, x, acts, k = stack_fixed(rows, action_rows, chunk)
        stats = cg_ir_batch(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x),
                            jnp.asarray(acts, jnp.int32), self.cg_cfg,
                            backend=self.backend)
        ferr = np.asarray(stats.ferr)
        nbe = np.asarray(stats.nbe)
        n_outer = np.asarray(stats.n_outer)
        n_cg = np.asarray(stats.n_cg)
        status = np.asarray(stats.status)
        res = np.asarray(stats.res_norm)
        return [Outcome(status=int(status[j]), cost=float(n_cg[j]),
                        metrics={"ferr": float(ferr[j]),
                                 "nbe": float(nbe[j]),
                                 "n_outer": int(n_outer[j]),
                                 "n_cg": int(n_cg[j]),
                                 "res_norm": float(res[j])})
                for j in range(k)]
