"""GMRES-IR as a `TunableTask` — the paper's original workload.

A thin adapter over the existing `core.batching` fixed-shape layer:
`solve_rows` funnels through `solve_fixed_batch` (one compiled
`gmres_ir_batch` executable per size bucket) and lifts each
`SolveRecord` into the solver-agnostic `Outcome`.

The factorization/substitution hot path is size-dispatched by
`ir_cfg.blocking` (DESIGN.md §6.4): buckets at or above its threshold
(256 by default) factor with blocked LU and solve with the blocked
trisolve kernel on whichever precision backend the task was built
with — no task- or engine-level code is involved, the policy rides the
frozen config into the jit key.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.batching import SolveRecord, solve_fixed_batch
from repro.core.task import Outcome
from repro.data.matrices import LinearSystem
from repro.solvers.ir import IRConfig, gmres_ir_batch_lowerable
from repro.tasks.base import LinearSystemTask


def outcome_of_record(rec: SolveRecord) -> Outcome:
    """Lift a GMRES-IR `SolveRecord` into a generic `Outcome`."""
    return Outcome(status=int(rec.status), cost=float(rec.n_gmres),
                   metrics={"ferr": float(rec.ferr), "nbe": float(rec.nbe),
                            "n_outer": int(rec.n_outer),
                            "n_gmres": int(rec.n_gmres),
                            "res_norm": float(rec.res_norm)})


class GMRESIRTask(LinearSystemTask):
    name = "gmres_ir"
    inner_iter_metric = "n_gmres"

    def __init__(self, systems: Sequence[LinearSystem] = (),
                 action_space: Optional[ActionSpace] = None,
                 ir_cfg: IRConfig = IRConfig(),
                 bucket_step: int = 128, min_bucket: int = 128,
                 backend=None, executor=None, tune_blocking: bool = False):
        super().__init__(systems, action_space, bucket_step, min_bucket,
                         backend=backend, executor=executor,
                         tune_blocking=tune_blocking)
        self.ir_cfg = ir_cfg

    def solve_rows(self, rows, action_rows: Sequence[np.ndarray],
                   chunk: int) -> List[Outcome]:
        cfg = self.solver_cfg_for(self.ir_cfg, rows[0][0].shape[-1])
        recs = solve_fixed_batch([r[0] for r in rows], [r[1] for r in rows],
                                 [r[2] for r in rows], action_rows,
                                 cfg, chunk, backend=self.backend,
                                 executor=self.executor)
        return [outcome_of_record(r) for r in recs]

    def lowerable_for(self, n_pad: int):
        """AOT form (DESIGN.md §12): the same (cfg, backend)-keyed
        lowerable `solve_rows` dispatches through, so warmup builds the
        very executable live traffic will run."""
        return gmres_ir_batch_lowerable(
            self.solver_cfg_for(self.ir_cfg, int(n_pad)), self.backend)
