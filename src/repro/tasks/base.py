"""Shared `TunableTask` implementation for linear-system solvers.

Both shipped tasks (GMRES-IR, CG-IR) autotune per-step precisions for
`Ax = b` over `data.matrices.LinearSystem` instances, so everything but
the batched solver itself lives here: paper features (Eq. 18), size
bucketing with identity padding (solution preserving), fixed-shape
batch stacking, and the Eq. 21 reward mapped from an `Outcome`'s
metrics. Subclasses provide `name`, `inner_iter_metric` (the metrics
key holding the work count fed to the Eq. 25 penalty), and
`solve_rows`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.action_space import ActionSpace
from repro.core.executor import resolve_executor
from repro.core.features import PAPER_FEATURES, feature_vector
from repro.core.rewards import reward as reward_fn
from repro.core.task import Outcome, bucket_of
from repro.data.matrices import LinearSystem, pad_system
from repro.precision import resolve_backend


def stack_fixed(rows: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]],
                action_rows: Sequence[np.ndarray], chunk: int):
    """Stack padded (A, b, x) rows + action rows into fixed-shape arrays.

    The batch dimension is padded to exactly `chunk` by repeating row 0,
    keeping the compiled shape constant; callers drop the pad rows from
    the results (`k` = number of real rows).
    """
    k = len(rows)
    assert 0 < k <= chunk, (k, chunk)
    idx = list(range(k)) + [0] * (chunk - k)
    A = np.stack([rows[i][0] for i in idx])
    b = np.stack([rows[i][1] for i in idx])
    x = np.stack([rows[i][2] for i in idx])
    acts = np.stack([np.asarray(action_rows[i], np.int32) for i in idx])
    return A, b, x, acts, k


class LinearSystemTask:
    """Base task over a (possibly empty) set of `LinearSystem`s.

    `action_space` may be None for serving-only adapters; the server
    injects the promoted policy snapshot's space before any reward is
    computed.

    `backend` selects the precision backend the batched solver runs on
    (DESIGN.md §6): an instance, a registry name ("jnp", "pallas", ...),
    or None for the process default. It is resolved once here so every
    solve the engine/server funnels through this task hits the same
    compiled executable.

    `executor` selects the solve executor the same way (DESIGN.md §7):
    an instance, a registry name ("local", "sharded"), or None for the
    process default. The executor owns device placement and chunk
    granularity; the engine and micro-batcher read it off the task.

    `tune_blocking=True` runs a one-off startup sweep per (bucket,
    backend) over blocked-LU panel widths and pins the winner into that
    bucket's solver config (`solvers.block_autotune`) — the same
    measure-then-commit move the bandit makes for precisions, applied
    to the kernel-blocking knob. Off by default: the tuned policy is a
    legitimate config change (panel-restricted pivoting differs by
    width), so opting in is a per-task decision.
    """

    name = "linear-system"
    inner_iter_metric = "n_inner"

    def __init__(self, systems: Sequence[LinearSystem] = (),
                 action_space: Optional[ActionSpace] = None,
                 bucket_step: int = 128, min_bucket: int = 128,
                 backend=None, executor=None, tune_blocking: bool = False):
        self.instances: List[LinearSystem] = list(systems)
        self.action_space = action_space
        self.bucket_step = bucket_step
        self.min_bucket = min_bucket
        self.backend = resolve_backend(backend)
        self.executor = resolve_executor(executor)
        self.tune_blocking = tune_blocking
        self._features: Optional[np.ndarray] = None
        self._kappas: Optional[np.ndarray] = None
        self._tuned_cfgs: dict = {}

    # -- context features --------------------------------------------------
    @property
    def features(self) -> np.ndarray:
        if self._features is None:
            if not self.instances:
                return np.zeros((0, len(PAPER_FEATURES)))
            self._features = np.stack([self.feature_of(s)
                                       for s in self.instances])
        return self._features

    @property
    def kappas(self) -> np.ndarray:
        if self._kappas is None:
            self._kappas = np.array([s.features["kappa_est"]
                                     for s in self.instances])
        return self._kappas

    def feature_of(self, system: LinearSystem) -> np.ndarray:
        return feature_vector(system.features)

    # -- shape bucketing ---------------------------------------------------
    def bucket_key(self, system: LinearSystem) -> int:
        return bucket_of(system.n, self.bucket_step, self.min_bucket)

    def prepare(self, system: LinearSystem):
        """(A, b, x) identity-padded to the system's size bucket."""
        return pad_system(system, self.bucket_key(system))

    # -- solving / reward --------------------------------------------------
    def solver_cfg_for(self, cfg, n_pad: int):
        """Per-bucket solver config: the static config, with the
        blocked-LU panel width swapped for the startup-sweep winner when
        `tune_blocking` is on. Cached per (config type, bucket), so each
        bucket still compiles exactly one executable."""
        if not self.tune_blocking:
            return cfg
        key = (type(cfg).__name__, int(n_pad))
        if key not in self._tuned_cfgs:
            from repro.solvers.block_autotune import tuned_blocking
            pol = tuned_blocking(n_pad, backend=self.backend,
                                 base=cfg.blocking)
            self._tuned_cfgs[key] = (
                cfg if pol == cfg.blocking
                else dataclasses.replace(cfg, blocking=pol))
        return self._tuned_cfgs[key]

    def solve_rows(self, rows, action_rows, chunk: int) -> List[Outcome]:
        raise NotImplementedError

    # -- AOT warmup (DESIGN.md §12) ----------------------------------------
    def lowerable_for(self, n_pad: int):
        """The batched solver as a `core.executor.LowerableCall` for one
        padded size, or None when the task has no AOT form (warmup then
        falls back to first-hit compilation, exactly as before)."""
        return None

    def warm_rows(self, bucket: int):
        """One representative prepared row for `bucket`: an identity
        system with the exact shapes/dtypes of any live padded row
        (`data.matrices.pad_system` pads with the identity, so this is
        literally a member of the live input family)."""
        n = int(bucket)
        return (np.eye(n), np.ones(n), np.ones(n))

    def precompile_bucket(self, bucket: int, chunk: int) -> bool:
        """AOT-build this task's executable for (bucket, chunk) without
        solving anything (DESIGN.md §12). The warm batch is shaped
        exactly like a live flush — `stack_fixed` to the executor's
        preferred chunk, int32 action rows — so the first real request
        hits the compiled executable. Returns False when the task has
        no AOT form."""
        low = self.lowerable_for(int(bucket))
        if low is None or self.action_space is None:
            return False
        row = self.warm_rows(int(bucket))
        action = np.asarray(self.action_space.actions[0], np.int32)
        A, b, x, acts, _ = stack_fixed(
            [row], [action],
            self.executor.preferred_chunk(int(chunk), int(bucket)))
        return bool(self.executor.precompile(low, (A, b, x, acts),
                                             A.shape[-1]))

    def reward(self, outcome: Outcome, action_idx: int,
               instance: LinearSystem, cfg) -> float:
        """Eq. 21 on the outcome's metrics; the inner-iteration count
        named by `inner_iter_metric` feeds the Eq. 25 work penalty."""
        m = outcome.metrics
        return reward_fn(m["ferr"], m["nbe"], m[self.inner_iter_metric],
                         outcome.status,
                         self.action_space.actions[int(action_idx)],
                         instance.features["kappa_est"], cfg)
