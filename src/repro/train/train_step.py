"""Train step: loss -> grad -> (optional cross-pod sync) -> AdamW update."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import loss_fn

from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update
from .schedule import cosine_with_warmup


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    opt: AdamWConfig = AdamWConfig()
    compute_dtype: Any = jnp.bfloat16
    # Cast fp32 master params to compute_dtype BEFORE use, so FSDP
    # all-gathers move bf16 instead of fp32 (halves the gather bytes — a
    # §Perf collective-term lever). Router weights stay fp32 (DESIGN §4).
    cast_params_for_compute: bool = False


def cast_params(params, dtype):
    def leaf(path, v):
        if any(getattr(k, "key", None) == "router" for k in path):
            return v
        if hasattr(v, "dtype") and v.dtype == jnp.float32:
            return v.astype(dtype)
        return v
    return jax.tree_util.tree_map_with_path(leaf, params)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray


def init_train_state(cfg: ArchConfig, key, tcfg: TrainStepConfig,
                     param_dtype=jnp.float32) -> TrainState:
    from repro.models import init_params
    params = init_params(cfg, key, param_dtype)
    return TrainState(params, adamw_init(params, tcfg.opt),
                      jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, tcfg: TrainStepConfig, policy=None,
                    residual_sharding=None):
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-able."""

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        def loss_of(p):
            if tcfg.cast_params_for_compute:
                p = cast_params(p, tcfg.compute_dtype)
            return loss_fn(p, batch, cfg, tcfg.compute_dtype, policy,
                           residual_sharding)

        (loss, aux), grads = jax.value_and_grad(loss_of, has_aux=True)(
            state.params)
        lr = cosine_with_warmup(state.step, peak_lr=tcfg.peak_lr,
                                warmup=tcfg.warmup, total=tcfg.total_steps)
        params, opt, stats = adamw_update(state.params, grads, state.opt,
                                          lr, tcfg.opt)
        metrics = {"loss": loss, "lr": lr, **stats}
        return TrainState(params, opt, state.step + 1), metrics

    return train_step
