"""Cross-pod gradient synchronization with bandit-controlled compression.

Within a pod, gradient reduction happens implicitly inside the pjit'd
backward pass (fast ICI). The cross-pod axis is the low-bandwidth link and
syncs EXPLICITLY here so its format is a precision knob:

  fp32 : plain pmean over "pod"
  bf16 : cast before pmean (halves collective bytes — visible in the
         dry-run's collective-bytes accounting)
  int8 : blockwise-quantized all_gather + local dequant-average (quarter
         bytes + scales; summing int8 codes directly would overflow and
         mis-round, so reduce-after-gather is the correct primitive)

Used by launch/train.py via shard_map over the "pod" mesh axis. Note: the
int8 path reduces *after* an all_gather, which shard_map's static
replication checker cannot prove replicated — wrap calls with
``check_vma=False`` (the result is replicated by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize import dequantize_int8, quantize_int8, QTensor


def sync_leaf(g: jnp.ndarray, mode: str, axis: str = "pod") -> jnp.ndarray:
    if mode == "fp32":
        return jax.lax.pmean(g.astype(jnp.float32), axis)
    if mode == "bf16":
        return jax.lax.pmean(g.astype(jnp.bfloat16), axis
                             ).astype(jnp.float32)
    if mode == "int8":
        q = quantize_int8(g, block=256)
        codes = jax.lax.all_gather(q.codes, axis)        # (n_pods, ...)
        scales = jax.lax.all_gather(q.scales, axis)
        n = codes.shape[0]
        deq = [dequantize_int8(QTensor(codes[i], scales[i]), 256)
               for i in range(n)]
        return sum(deq) / n
    raise ValueError(mode)


def sync_grads(grads, mode: str, axis: str = "pod"):
    """Apply sync_leaf over a gradient pytree (call inside shard_map)."""
    return jax.tree_util.tree_map(lambda g: sync_leaf(g, mode, axis), grads)
