"""Blockwise int8 quantization: optimizer moments + gradient compression.

Absmax scheme: per contiguous block of `block` elements (on the flattened
array), code = round(x / s * 127) with s = absmax(block). Used for
  * 8-bit Adam moments (fits deepseek-v2-236b optimizer state in HBM,
    DESIGN.md §5), and
  * cross-pod gradient compression (train/grad_sync.py),
both of which are bandit-tunable precision knobs (the paper's technique
applied to the training stack)."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class QTensor(NamedTuple):
    codes: jnp.ndarray     # int8, original shape
    scales: jnp.ndarray    # f32, (n_blocks,)
    # static metadata lives in the shapes; block is implied by scales size


def quantize_int8(x: jnp.ndarray, block: int = 256) -> QTensor:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.shape[0] % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scales = jnp.max(jnp.abs(blocks), axis=1)
    safe = jnp.where(scales == 0, 1.0, scales)
    codes = jnp.clip(jnp.round(blocks / safe[:, None] * 127.0),
                     -127, 127).astype(jnp.int8)
    codes = codes.reshape(-1)[:x.size].reshape(shape)
    return QTensor(codes, scales)


def dequantize_int8(q: QTensor, block: int = 256) -> jnp.ndarray:
    shape = q.codes.shape
    flat = q.codes.astype(jnp.float32).reshape(-1)
    pad = -flat.shape[0] % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    out = flat * (q.scales[:, None] / 127.0)
    return out.reshape(-1)[:q.codes.size].reshape(shape)
