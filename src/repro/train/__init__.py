from .grad_sync import sync_grads, sync_leaf
from .optimizer import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                        global_norm)
from .precision_hooks import (LMPrecisionPolicy, TrainPrecisionController,
                              default_policy)
from .quantize import QTensor, dequantize_int8, quantize_int8
from .schedule import cosine_with_warmup
from .train_step import (TrainState, TrainStepConfig, init_train_state,
                         make_train_step)

__all__ = [
    "sync_grads", "sync_leaf", "AdamWConfig", "AdamWState", "adamw_init",
    "adamw_update", "global_norm", "LMPrecisionPolicy",
    "TrainPrecisionController", "default_policy", "QTensor",
    "dequantize_int8", "quantize_int8", "cosine_with_warmup", "TrainState",
    "TrainStepConfig", "init_train_state", "make_train_step",
]
