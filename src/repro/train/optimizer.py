"""AdamW with fp32 master params and optionally int8-quantized moments.

Functional optax-style API (optax is not available offline):
  state = adamw_init(params, cfg)
  params, state = adamw_update(params, grads, state, lr, cfg)

With `quantize_moments=True` both Adam moments live as blockwise-int8
QTensors: 2 bytes/param of optimizer state instead of 8 — the knob that
lets deepseek-v2-236b train on 512 v5e chips (DESIGN.md §5), and a
precision-autotuner action in the LM integration."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from .quantize import QTensor, dequantize_int8, quantize_int8


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False
    quant_block: int = 256


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any           # pytree of f32 arrays or QTensors
    v: Any


def _maybe_q(x, cfg: AdamWConfig):
    return quantize_int8(x, cfg.quant_block) if cfg.quantize_moments else x


def _maybe_dq(x, cfg: AdamWConfig):
    return dequantize_int8(x, cfg.quant_block) if isinstance(x, QTensor) \
        else x


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: _maybe_q(jnp.zeros(p.shape, jnp.float32), cfg), params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: _maybe_q(jnp.zeros(p.shape, jnp.float32), cfg), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros2)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state: AdamWState, lr,
                 cfg: AdamWConfig = AdamWConfig()):
    """params: fp32 master weights. Returns (new_params, new_state, stats)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0

    is_q = lambda x: isinstance(x, QTensor)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = _maybe_dq(m, cfg)
        v = _maybe_dq(v, cfg)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), _maybe_q(m, cfg), _maybe_q(v, cfg)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state.m, is_leaf=is_q)[0]
    flat_v = jax.tree_util.tree_flatten(state.v, is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
