"""LM-side precision policy: the paper's bandit driving the training stack.

`LMPrecisionPolicy` is the object `models.layers.dot` routes through. An
action is a monotone tuple over the TPU ladder (e4m3 <= bf16 <= fp32) for
three step groups — the LM analogue of (u_f, u, u_g, u_r):

  step "attn"/"ffn"/"ssm" : matmul operand format (emulated via chop, or
                            native bf16/f32 cast when the format has one)
  step "comm"             : cross-pod gradient-sync format (grad_sync.py)
  step "opt"              : optimizer-moment format (int8 when below bf16)

Context features (the kappa/norm analogues — they predict rounding-error
amplification): log10 grad-norm ratio, log10 update-to-weight ratio, and
the loss EMA trend. Rewards follow Eq. 21's shape: precision savings
(Eq. 22 with kappa -> grad-ratio), accuracy = -loss-degradation, penalty =
divergence/rollback events."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.action_space import reduced_action_space
from repro.core.bandit import QTable, epsilon_schedule
from repro.core.discretize import Discretizer
from repro.precision import FORMAT_ID, FORMATS, chop

TPU_LADDER = ("e4m3", "bf16", "fp32")
STEP_GROUPS = ("matmul", "comm", "opt")


@dataclasses.dataclass
class LMPrecisionPolicy:
    """Per-train-step matmul routing. fmt ids are *runtime* data so action
    switches never recompile (DESIGN.md §3.4)."""
    matmul_fmt: jnp.ndarray      # scalar int32 format id
    comm_fmt: int = FORMAT_ID["bf16"]
    opt_8bit: bool = False
    emulate: bool = True         # chop-based emulation vs native casts

    def matmul(self, x: jnp.ndarray, w: jnp.ndarray,
               step: str) -> jnp.ndarray:
        w = w.astype(x.dtype)
        if self.emulate:
            xf = x.astype(jnp.float32)
            wf = w.astype(jnp.float32)
            out = jnp.dot(chop(xf, self.matmul_fmt),
                          chop(wf, self.matmul_fmt),
                          preferred_element_type=jnp.float32)
            return out.astype(x.dtype)
        return jnp.dot(x, w, preferred_element_type=jnp.float32
                       ).astype(x.dtype)


def default_policy(fmt: str = "bf16") -> LMPrecisionPolicy:
    return LMPrecisionPolicy(jnp.asarray(FORMAT_ID[fmt], jnp.int32))


class TrainPrecisionController:
    """Online contextual bandit over train-step precision actions.

    Reuses the paper's exact core (reduced action space, binned context,
    tabular Q, eps-greedy with linear decay). One decision every
    `interval` steps; the reward for the previous interval is observed
    before the next action is chosen (contextual bandit, not full RL)."""

    def __init__(self, total_decisions: int, interval: int = 20,
                 n_bins=(6, 6), alpha: float = 0.5, eps_min: float = 0.05,
                 seed: int = 0, w_accuracy: float = 1.0,
                 w_precision: float = 0.2):
        self.space = reduced_action_space(TPU_LADDER, k=len(STEP_GROUPS))
        self.disc = Discretizer(np.array([-2.0, -4.0]),
                                np.array([2.0, 0.0]), tuple(n_bins))
        self.qt = QTable(self.disc.n_states, self.space.n_actions, alpha,
                         seed)
        self.interval = interval
        self.total = total_decisions
        self.eps_min = eps_min
        self.decision = 0
        self.w_acc = w_accuracy
        self.w_prec = w_precision
        self._pending = None      # (state, action)
        self.history = []

    # -- feature extraction -------------------------------------------------
    @staticmethod
    def features(grad_norm_ratio: float, update_weight_ratio: float):
        return np.array([np.log10(max(grad_norm_ratio, 1e-2)),
                         np.log10(max(update_weight_ratio, 1e-4))])

    def act(self, feats: np.ndarray) -> LMPrecisionPolicy:
        s = int(self.disc(feats))
        eps = epsilon_schedule(self.decision, self.total, self.eps_min)
        a = self.qt.select(s, eps)
        self._pending = (s, a)
        self.decision += 1
        fmt_ids = self.space.actions[a]
        return LMPrecisionPolicy(
            matmul_fmt=jnp.asarray(fmt_ids[0], jnp.int32),
            comm_fmt=int(fmt_ids[1]),
            opt_8bit=bool(self.space.ladder_idx[a][2] == 0))

    def observe(self, loss_before: float, loss_after: float,
                diverged: bool = False):
        """Close the loop for the last action (Eq. 21-shaped reward)."""
        if self._pending is None:
            return
        s, a = self._pending
        fmt_ids = self.space.actions[a]
        t_bits = np.array([FORMATS[self.space.ladder[i]].t
                           for i in self.space.ladder_idx[a]])
        prec = float(np.sum(FORMATS["fp32"].t / t_bits)) / len(t_bits)
        d = loss_after - loss_before
        acc = -10.0 * max(d, 0.0) + min(-d, 0.1) * 10.0
        r = self.w_prec * prec + self.w_acc * acc
        if diverged or not np.isfinite(loss_after):
            r = -30.0
        rpe = self.qt.update(s, a, r)
        self.history.append({"state": s, "action": a, "reward": r,
                             "rpe": rpe})
        self._pending = None
