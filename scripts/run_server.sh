#!/usr/bin/env bash
# Opinionated production runner for the autotune server (DESIGN.md §12).
#
# Pins the environment the serving stack is tuned for, then execs the
# given entry point (default: examples/serve_http.py). Every knob is an
# override-able default — anything already set in the environment wins.
#
#   scripts/run_server.sh                         # HTTP front door demo
#   scripts/run_server.sh examples/serve_autotune.py
#   REPRO_SOLVE_EXECUTOR=sharded scripts/run_server.sh my_server.py
#
# Knobs (defaults below, see DESIGN.md for the sections that own them):
#   REPRO_COMPILE_CACHE_DIR  persistent XLA compile cache (§12): restarts
#                            rebuild the executable grid from disk with
#                            zero fresh compiles. Default: .cache/xla
#                            under the repo root.
#   REPRO_SOLVE_EXECUTOR     solve executor registry name (§7):
#                            local | sharded. Default: local.
#   REPRO_PRECISION_BACKEND  precision backend registry name (§6):
#                            jnp | pallas | ... Default: process default.
#   JAX_ENABLE_X64           the solvers' fp64 carrier (§2). Pinned on —
#                            the bit-parity contract assumes it.
#   XLA_FLAGS                host-device count for the sharded executor
#                            is appended here when REPRO_SOLVE_EXECUTOR
#                            is sharded and no count was given.
set -euo pipefail

REPO_ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

# --- allocator: tcmalloc when present (long-lived servers fragment the
# glibc heap under the batcher's steady large-array churn) --------------
if [[ -z "${LD_PRELOAD:-}" ]]; then
    for so in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
              /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
              /usr/lib/libtcmalloc_minimal.so.4; do
        if [[ -e "$so" ]]; then
            export LD_PRELOAD="$so"
            # Silence the one-line report tcmalloc emits per large
            # (>1GiB) allocation — stacked solver batches trip it.
            export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-1099511627776}"
            break
        fi
    done
fi

# --- dtype + logging pins ---------------------------------------------
# fp64 carrier on (DESIGN.md §2); absl/XLA chatter off the serving logs.
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-1}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# --- persistent compile cache (DESIGN.md §12) --------------------------
export REPRO_COMPILE_CACHE_DIR="${REPRO_COMPILE_CACHE_DIR:-$REPO_ROOT/.cache/xla}"
mkdir -p "$REPRO_COMPILE_CACHE_DIR"

# --- executor / backend selection (DESIGN.md §6-§7) --------------------
export REPRO_SOLVE_EXECUTOR="${REPRO_SOLVE_EXECUTOR:-local}"
if [[ -n "${REPRO_PRECISION_BACKEND:-}" ]]; then
    export REPRO_PRECISION_BACKEND
fi
if [[ "$REPRO_SOLVE_EXECUTOR" == "sharded" \
      && "${XLA_FLAGS:-}" != *host_platform_device_count* ]]; then
    # A host-device mesh for the sharded executor on CPU hosts; real
    # accelerator fleets already expose their devices and skip this.
    export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES:-8}"
fi

# --- XLA host tuning ---------------------------------------------------
# Donated-buffer reuse + multi-threaded Eigen GEMMs are defaults today;
# the one knob that reliably helps the solver's many small CPU
# executables is keeping compilation parallel.
export XLA_FLAGS="${XLA_FLAGS:-} --xla_cpu_use_thunk_runtime=true"

export PYTHONPATH="$REPO_ROOT/src${PYTHONPATH:+:$PYTHONPATH}"

ENTRY="${1:-$REPO_ROOT/examples/serve_http.py}"
shift || true
exec python "$ENTRY" "$@"
