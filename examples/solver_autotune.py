"""Out-of-sample precision autotuning (the paper's headline claim):
train on dense randsvd systems, infer precision configs for NEW systems —
including a distribution shift to sparse SPD systems — and compare against
the all-FP64 baseline.

    PYTHONPATH=src python examples/solver_autotune.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (GMRESIREnv, TrainConfig, W1, W2,
                        evaluate_fixed_action, evaluate_policy,
                        reduced_action_space, train_policy)
from repro.data import generate_dense_set, generate_sparse_set
from repro.solvers import IRConfig


def show(tag, table):
    for rng_name, row in table.items():
        print(f"  {tag:14s} [{rng_name:6s}] xi={row['xi']:.0%} "
              f"ferr={row['avg_ferr']:.2e} nbe={row['avg_nbe']:.2e} "
              f"iters={row['avg_iter']:.2f} gmres={row['avg_gmres_iter']:.2f}")


def main():
    rng = np.random.default_rng(1)
    train = generate_dense_set(40, rng, n_range=(60, 120),
                               log10_kappa_range=(1, 9))
    test_dense = generate_dense_set(20, rng, n_range=(60, 120),
                                    log10_kappa_range=(1, 9))
    test_sparse = generate_sparse_set(10, rng, n_range=(60, 120))

    space = reduced_action_space()
    env = GMRESIREnv(train, space, IRConfig(tau=1e-6), chunk=8)

    for name, w in [("W1(conservative)", W1), ("W2(aggressive)", W2)]:
        policy, _ = train_policy(env, w, TrainConfig(episodes=40))
        print(f"\n== {name} ==")
        envd = GMRESIREnv(test_dense, space, IRConfig(tau=1e-6), chunk=8)
        ev = evaluate_policy(policy, envd, tau_base=1e-6)
        show("dense-unseen", ev["table"])
        print(f"  format usage/solve: {ev['usage_per_solve']}")
        envs = GMRESIREnv(test_sparse, space, IRConfig(tau=1e-6), chunk=8)
        evs = evaluate_policy(policy, envs, tau_base=1e-6)
        show("sparse-shift", evs["table"])
        print(f"  format usage/solve: {evs['usage_per_solve']} "
              "(expect FP64-dominant on ill-conditioned sparse)")

    envd = GMRESIREnv(test_dense, space, IRConfig(tau=1e-6), chunk=8)
    bl = evaluate_fixed_action(envd, space.n_actions - 1, 1e-6)
    print("\n== FP64 baseline ==")
    show("dense-unseen", bl["table"])


if __name__ == "__main__":
    main()
