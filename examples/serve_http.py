"""HTTP front door + shadow/canary rollout controller, end to end:

1. Warm-start a versioned policy registry (offline training).
2. Put a `ShadowServer` behind the asyncio HTTP front door and solve
   over the wire: fire-and-poll (`/v1/solve` + `/v1/result/{id}`) and
   synchronous (`/v1/solve:sync`).
3. Stage a deliberately degraded candidate (Q-table pinned to the
   all-bf16 arm, whose bf16 residuals stagnate short of tau) — watch
   the gate trip and auto-rollback restore the baseline.
4. Stage a healthy candidate on the same stream — watch it pass
   consecutive decision windows and auto-promote.
5. Inspect `/v1/policy` and the decision-trail JSONL along the way.

    PYTHONPATH=src python examples/serve_http.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import json
import os
import random
import tempfile
import time
import urllib.request

import numpy as np

from repro.core import (GMRESIREnv, TrainConfig, W1, executor_compile_count,
                        reduced_action_space)
from repro.data import generate_dense_set
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry, RolloutConfig, ShadowServer)
from repro.service.http import HttpConfig, retry_delay, serve_http
from repro.solvers import IRConfig


def http(method, url, payload=None, max_attempts=8):
    """One HTTP exchange, honoring 429 backpressure like a polite
    client: on 429 the server's Retry-After floors a jittered
    exponential backoff (`repro.service.http.retry_delay`) and the
    request is retried; other errors return immediately."""
    data = json.dumps(payload).encode() if payload is not None else None
    rng = random.Random(0)
    for attempt in range(max_attempts):
        req = urllib.request.Request(
            url, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=60) as r:
                return r.status, json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            body = e.read().decode()
            parsed = json.loads(body) if body else {}
            if e.code != 429 or attempt == max_attempts - 1:
                return e.code, parsed
            time.sleep(retry_delay(attempt,
                                   e.headers.get("Retry-After"),
                                   base_s=0.05, rng=rng))
    raise RuntimeError("unreachable")


def payload(system):
    return {"A": system.A.tolist(), "b": system.b.tolist(),
            "x_true": system.x_true.tolist()}


def drive(url, shadow, systems, tag):
    """Sync-solve until the rollout controller leaves the canary phase."""
    rewards = []
    for i, system in enumerate(systems):
        code, body = http("POST", url + "/v1/solve:sync", payload(system))
        assert code == 200, body
        rewards.append(body["reward"])
        if shadow.phase != "canary":
            print(f"  [{tag}] decision after {i + 1} requests "
                  f"(mean reward {np.mean(rewards):+.2f})")
            return
    print(f"  [{tag}] stream ended still in canary "
          f"(mean reward {np.mean(rewards):+.2f})")


def main():
    rng = np.random.default_rng(7)
    ir_cfg = IRConfig(tau=1e-6)
    space = reduced_action_space()
    bcfg = BatcherConfig(max_batch=4, max_wait_s=0.002, bucket_step=16,
                         min_bucket=16)

    def requests(n, seed):
        return generate_dense_set(n, np.random.default_rng(seed),
                                  n_range=(12, 28),
                                  log10_kappa_range=(3, 6))

    with tempfile.TemporaryDirectory() as root:
        print("== 1. warm-start registry + baseline telemetry ==")
        train = generate_dense_set(8, rng, n_range=(12, 28),
                                   log10_kappa_range=(3, 6))
        env = GMRESIREnv(train, space, ir_cfg, chunk=4, bucket_step=16)
        reg, version, _ = PolicyRegistry.warm_start(
            os.path.join(root, "reg"), env, W1, TrainConfig(episodes=6))
        # Serve some traffic and snapshot so the baseline's meta carries
        # the telemetry evidence the rollout gates read. The server
        # AOT-warms its bucket grid in the background (DESIGN.md §12)
        # and we log progress until every expected bucket is warm.
        c0 = executor_compile_count()
        seed_srv = AutotuneServer(reg, ir_cfg, W1, bcfg, OnlineConfig(),
                                  seed=0, obs=False,
                                  warmup="background",
                                  warmup_buckets=[16, 32])
        total = len(seed_srv.warmup_state()["expected_buckets"])
        last = -1
        while not seed_srv.warmup.done:
            st = seed_srv.warmup_state()
            if len(st["warmed_buckets"]) != last:
                last = len(st["warmed_buckets"])
                print(f"  warmup: {last}/{total} buckets warm "
                      f"({st['elapsed_s']:.1f}s elapsed)")
            seed_srv.warmup.wait(2.0)
        st = seed_srv.warmup_state()
        built = executor_compile_count() - c0
        print(f"  warmup done: {len(st['warmed_buckets'])}/{total} "
              f"buckets in {st['elapsed_s']:.1f}s, {built} executables "
              "built" + ("" if built else
                         " (grid shared with offline training)"))
        for system in requests(40, seed=3):
            seed_srv.submit(system)
        seed_srv.drain()
        baseline = seed_srv.snapshot(note="baseline with telemetry")
        print(f"  baseline {baseline} "
              f"(warm-start {version} + 40 served requests)")

        print("== 2. HTTP front door over a ShadowServer ==")
        log_path = os.path.join(root, "decisions.jsonl")
        shadow = ShadowServer(
            reg, ir_cfg, W1, bcfg, OnlineConfig(),
            rollout_cfg=RolloutConfig(canary_frac=0.3, decision_window=24,
                                      min_samples=20, promote_windows=2,
                                      reward_margin=10.0,
                                      pass_rate_floor=0.12,
                                      pass_rate_margin=0.9, p99_bound=50.0),
            seed=0, decision_log_path=log_path)
        fd = serve_http(shadow, cfg=HttpConfig(max_n=64,
                                               flush_interval_s=0.002))
        print(f"  listening at {fd.url}")
        system = requests(1, seed=1)[0]
        code, acc = http("POST", fd.url + "/v1/solve", payload(system))
        rid = acc["request_id"]
        print(f"  POST /v1/solve -> {code} request_id={rid} "
              f"bucket={acc['bucket']}")
        while True:
            code, body = http("GET", fd.url + f"/v1/result/{rid}")
            if code == 200:
                break
        print(f"  GET /v1/result/{rid} -> 200 "
              f"action=({', '.join(body['action_names'])}) "
              f"reward={body['reward']:+.2f}")

        print("== 3. degraded candidate: auto-rollback ==")
        bad = reg.load()
        bad.qtable.Q[:] = 0.0
        bad.qtable.Q[:, 0] = 1.0       # pin greedy to the all-bf16 arm
        vbad = reg.publish(bad, note="degraded on purpose")
        shadow.start_rollout(vbad)
        print(f"  staged {vbad} (current={reg.current_version()})")
        drive(fd.url, shadow, requests(48, seed=9), "degraded")
        last = shadow.decisions[-1]
        print(f"  phase={shadow.phase} failures={last.failures} "
              f"current={reg.current_version()}")

        print("== 4. healthy candidate: auto-promote ==")
        vgood = reg.publish(reg.load(), note="healthy copy")
        shadow.start_rollout(vgood)
        drive(fd.url, shadow, requests(60, seed=9), "healthy")
        print(f"  phase={shadow.phase} current={reg.current_version()}")

        print("== 5. policy endpoint + decision trail ==")
        code, pol = http("GET", fd.url + "/v1/policy")
        print(f"  GET /v1/policy -> current={pol['current']} "
              f"rollout.phase={pol['rollout']['phase']}")
        events = [json.loads(ln) for ln in open(log_path) if ln.strip()]
        for e in events:
            if e["event"] == "decision":
                print(f"  decision: {e['outcome']:8s} "
                      f"responses={e['responses']} "
                      f"failures={e['failures']}")
        fd.close()
        print("  front door drained and closed")


if __name__ == "__main__":
    main()
