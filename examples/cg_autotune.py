"""One autotuning engine, many algorithms: the CG-IR instantiation.

The exact pipeline of `solver_autotune.py` / `serve_autotune.py`, but
with conjugate-gradient iterative refinement plugged in through the
`TunableTask` API instead of GMRES-IR — same `train_policy`, same
`PolicyRegistry.warm_start`, same `AutotuneServer`; only the task
object differs:

1. Train a policy offline on SPD systems via `CGIRTask`.
2. Evaluate greedy precision picks against the all-FP64 baseline.
3. Warm-start a registry and stream solve requests through the
   micro-batched server, learning online from every observed reward.

    PYTHONPATH=src python examples/cg_autotune.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import tempfile

import numpy as np

from repro.core import (TrainConfig, W1, evaluate_fixed_action,
                        evaluate_policy, reduced_action_space, train_policy)
from repro.data import generate_sparse_set
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)
from repro.solvers import CGConfig
from repro.tasks import CGIRTask


def show(tag, table):
    for rng_name, row in table.items():
        print(f"  {tag:14s} [{rng_name:6s}] xi={row['xi']:.0%} "
              f"ferr={row['avg_ferr']:.2e} nbe={row['avg_nbe']:.2e} "
              f"iters={row['avg_iter']:.2f} cg={row['avg_gmres_iter']:.2f}")


def main():
    rng = np.random.default_rng(3)
    cg_cfg = CGConfig(tau=1e-6)
    space = reduced_action_space()

    print("== 1. offline training (CGIRTask through train_policy) ==")
    train = generate_sparse_set(24, rng, n_range=(40, 120))
    task = CGIRTask(train, space, cg_cfg, bucket_step=64, min_bucket=64)
    policy, hist = train_policy(task, W1, TrainConfig(episodes=25))
    print(f"  {len(hist.episode_reward)} episodes, final mean reward "
          f"{hist.episode_reward[-1]:+.2f}, "
          f"{hist.n_solves} solves (+{hist.n_pad_solves} pad rows)")

    print("== 2. greedy inference vs FP64 baseline ==")
    test = generate_sparse_set(12, rng, n_range=(40, 120))
    test_task = CGIRTask(test, space, cg_cfg, bucket_step=64, min_bucket=64)
    ev = evaluate_policy(policy, test_task, tau_base=1e-6)
    show("cg-autotuned", ev["table"])
    print(f"  format usage/solve: {ev['usage_per_solve']}")
    bl = evaluate_fixed_action(
        CGIRTask(test, space, cg_cfg, bucket_step=64, min_bucket=64),
        space.n_actions - 1, 1e-6)
    show("cg-fp64", bl["table"])

    print("== 3. online serving (same AutotuneServer as GMRES-IR) ==")
    with tempfile.TemporaryDirectory() as root:
        reg, version, _ = PolicyRegistry.warm_start(
            root, CGIRTask(train, space, cg_cfg, bucket_step=64,
                           min_bucket=64),
            W1, TrainConfig(episodes=15))
        server = AutotuneServer(
            reg, CGIRTask(action_space=space, cg_cfg=cg_cfg, bucket_step=64,
                          min_bucket=64),
            W1,
            BatcherConfig(max_batch=8, max_wait_s=0.02, bucket_step=64,
                          min_bucket=64),
            OnlineConfig(warmup_updates=6, cooldown_updates=16))
        stream = generate_sparse_set(24, rng, n_range=(40, 120))
        ids = [server.submit(s) for s in stream]
        server.drain()
        responses = [server.poll(i) for i in ids]
        mean_r = np.mean([r.reward for r in responses])
        tel = server.telemetry.snapshot()
        print(f"  served {len(responses)} CG-IR solves, mean reward "
              f"{mean_r:+.2f}")
        print(f"  throughput {tel['throughput_rps']:.1f} req/s, p50 "
              f"{tel['latency_s']['p50'] * 1e3:.1f} ms, pad waste "
              f"{tel['pad_waste_frac']:.1%}")
        v2 = server.snapshot(note="online CG-IR adaptation")
        print(f"  promoted {v2} (task={reg.meta(v2)['task']})")


if __name__ == "__main__":
    main()
