"""Batched serving example: prefill + cached greedy decode, with the
KV-cache precision knob (bandit's serve-side action) demonstrated by
comparing logit drift across cache formats.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import init_params
from repro.precision import FORMAT_ID
from repro.serve import ServeConfig, generate


def main():
    cfg = get_smoke("gemma2-9b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    prompts = jax.random.randint(key, (8, 24), 0, cfg.vocab_size)

    outs = {}
    for fmt in [None, "bf16", "e4m3"]:
        scfg = ServeConfig(max_new_tokens=24, compute_dtype=jnp.float32,
                           cache_fmt=FORMAT_ID[fmt] if fmt else None)
        t0 = time.time()
        toks = np.asarray(generate(params, prompts, cfg, scfg, key))
        dt = time.time() - t0
        outs[fmt or "fp32-cache"] = toks
        print(f"[serve] cache={fmt or 'fp32':10s} "
              f"{8 * 24 / dt:7.1f} tok/s  sample={toks[0][:10]}")

    ref = outs["fp32-cache"]
    for fmt in ["bf16", "e4m3"]:
        agree = float(np.mean(outs[fmt] == ref))
        print(f"[serve] {fmt} KV cache token agreement vs fp32: "
              f"{agree:.1%} (memory {'-50%' if fmt == 'bf16' else '-75%'})")


if __name__ == "__main__":
    main()
