"""End-to-end driver: train a ~100M-parameter granite-style LM for a few
hundred steps with the bandit precision controller online, checkpointing,
and automatic restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.train import (AdamWConfig, TrainPrecisionController,
                         TrainStepConfig, init_train_state, make_train_step)


def lm_100m():
    """~100M-param config in the granite family (107M total)."""
    base = get_arch("granite-3-2b")
    return dataclasses.replace(
        base, name="granite-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--autotune", action="store_true", default=True)
    args = ap.parse_args()

    cfg = lm_100m()
    n_params = cfg.params_total()
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    tcfg = TrainStepConfig(peak_lr=6e-4, warmup=30, total_steps=args.steps,
                           opt=AdamWConfig(), compute_dtype=jnp.float32)
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)

    if latest_step(args.ckpt) is not None:
        state, meta = restore_checkpoint(args.ckpt, state)
        pipe.load_state_dict(meta["pipeline"])
        print(f"[train_lm] resumed at step {int(state.step)}")

    ctrl = TrainPrecisionController(total_decisions=args.steps // 10,
                                    interval=10) if args.autotune else None
    step_default = jax.jit(make_train_step(cfg, tcfg))
    losses, prev_loss, policy = [], None, None
    t0 = time.time()
    while int(state.step) < args.steps:
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        s = int(state.step)
        if ctrl is not None and s % 10 == 0:
            if prev_loss is not None:
                ctrl.observe(losses[-11] if len(losses) > 10 else losses[0],
                             prev_loss,
                             diverged=not np.isfinite(prev_loss))
            gn_ratio = 1.0
            uw = 1e-3
            policy = ctrl.act(ctrl.features(gn_ratio, uw))
            # The emulated-format policy routes matmuls through chop with a
            # runtime format id — no recompilation on action switches.
            step = jax.jit(make_train_step(cfg, tcfg, policy=policy))
        else:
            step = step_default if policy is None else step
        state, metrics = step(state, batch)
        prev_loss = float(metrics["loss"])
        losses.append(prev_loss)
        if s % 25 == 0:
            fmt = "default"
            if policy is not None:
                from repro.precision import FORMAT_LIST
                fmt = FORMAT_LIST[int(policy.matmul_fmt)].name
            print(f"  step {s:4d} loss {prev_loss:.4f} "
                  f"matmul_fmt={fmt} ({(time.time()-t0):.0f}s)")
        if s > 0 and s % 100 == 0:
            save_checkpoint(args.ckpt, s, state,
                            {"pipeline": pipe.state_dict()})
    save_checkpoint(args.ckpt, int(state.step), state,
                    {"pipeline": pipe.state_dict()})
    n = min(20, len(losses) // 4)
    print(f"[train_lm] loss {np.mean(losses[:n]):.3f} -> "
          f"{np.mean(losses[-n:]):.3f} over {len(losses)} steps; "
          f"{'DECREASED' if np.mean(losses[-n:]) < np.mean(losses[:n]) else 'FLAT'}")
    if ctrl is not None and ctrl.history:
        acts = [h["action"] for h in ctrl.history]
        print(f"[train_lm] bandit decisions: {len(acts)}, "
              f"last-5 actions {acts[-5:]}, "
              f"mean reward {np.mean([h['reward'] for h in ctrl.history]):.2f}")


if __name__ == "__main__":
    main()
