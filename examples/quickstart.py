"""Quickstart: train the paper's contextual bandit on a small set of linear
systems and watch it pick per-instance precision configurations.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (GMRESIREnv, TrainConfig, W2, evaluate_policy,
                        reduced_action_space, train_policy)
from repro.data import generate_dense_set
from repro.solvers import IRConfig


def main():
    rng = np.random.default_rng(0)
    train = generate_dense_set(24, rng, n_range=(60, 100),
                               log10_kappa_range=(1, 9))
    test = generate_dense_set(12, rng, n_range=(60, 100),
                              log10_kappa_range=(1, 9))

    space = reduced_action_space()          # 35 monotone precision tuples
    print(f"action space: {space.n_actions} actions over {space.ladder}")

    env = GMRESIREnv(train, space, IRConfig(tau=1e-6), chunk=8)
    policy, hist = train_policy(env, W2, TrainConfig(episodes=30))
    print(f"trained: reward {hist.episode_reward[0]:.1f} -> "
          f"{hist.episode_reward[-1]:.1f} "
          f"({env.cache_size} unique solves)")

    env_test = GMRESIREnv(test, space, IRConfig(tau=1e-6), chunk=8)
    ev = evaluate_policy(policy, env_test, tau_base=1e-6)
    print("\nper-instance decisions on UNSEEN systems:")
    for i, (idx, a) in enumerate(ev["actions"][:8]):
        s = test[idx]
        print(f"  kappa={s.kappa:9.2e} n={s.n:3d} -> "
              f"(u_f,u,u_g,u_r)={policy.action_space.names(a)} "
              f"ferr={ev['ferr'][i]:.2e}")
    for rng_name, row in ev["table"].items():
        print(f"  [{rng_name:6s}] success={row['xi']:.0%} "
              f"avg_ferr={row['avg_ferr']:.2e} "
              f"gmres_iters={row['avg_gmres_iter']:.1f}")


if __name__ == "__main__":
    main()
