"""Online precision-autotuning service, end to end:

1. Train a policy offline on dense systems (`core.autotune.train_policy`).
2. Warm-start a versioned policy registry from that run.
3. Serve a stream of solve requests through the micro-batched server,
   learning online from every observed reward.
4. Shift the distribution to ill-conditioned sparse systems mid-stream —
   watch the |RPE| drift detector trigger re-exploration.
5. Scrape the live observability front door (`/metrics`, `/readyz`)
   and inspect the JSONL trajectory log it wrote along the way.
6. Snapshot the adapted policy, then demonstrate rollback.

    PYTHONPATH=src python examples/serve_autotune.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import os
import tempfile
import urllib.request

import numpy as np

from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.data import generate_dense_set, generate_sparse_set
from repro.obs import Observability
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)
from repro.solvers import IRConfig


def stream(server, systems, tag):
    ids = [server.submit(s) for s in systems]
    server.drain()
    responses = [server.poll(i) for i in ids]
    drifts = sum(r.drift for r in responses)
    mean_r = np.mean([r.reward for r in responses])
    acts = {", ".join(r.action_names) for r in responses}
    print(f"  [{tag}] {len(responses)} solves, mean reward {mean_r:+.2f}, "
          f"drift events {drifts}")
    for a in sorted(acts):
        print(f"      action seen: ({a})")
    return responses


def main():
    rng = np.random.default_rng(7)
    ir_cfg = IRConfig(tau=1e-6)
    space = reduced_action_space()

    print("== 1. offline training ==")
    train = generate_dense_set(32, rng, n_range=(40, 120),
                               log10_kappa_range=(1, 6))
    env = GMRESIREnv(train, space, ir_cfg, chunk=8, bucket_step=64)

    with tempfile.TemporaryDirectory() as root:
        print("== 2. warm-start registry ==")
        reg, version, _ = PolicyRegistry.warm_start(
            root, env, W1, TrainConfig(episodes=25))
        print(f"  promoted {version}: {reg.meta(version)['note']}")

        print("== 3. serve a dense stream ==")
        obs = Observability(
            trajectory_path=os.path.join(root, "trajectory.jsonl"))
        server = AutotuneServer(
            reg, ir_cfg, W1,
            BatcherConfig(max_batch=8, max_wait_s=0.02, bucket_step=64,
                          min_bucket=64),
            # Demo-scale drift windows: only non-exploratory visits to known
            # states feed the detector, and this stream is only 64 requests.
            OnlineConfig(warmup_updates=6, cooldown_updates=16),
            obs=obs)
        http = server.serve_obs()
        print(f"  observability at {http.url}  "
              "(/metrics /healthz /readyz /telemetry /trace)")
        dense = generate_dense_set(32, rng, n_range=(40, 120),
                                   log10_kappa_range=(1, 6))
        stream(server, dense, "dense")

        print("== 4. distribution shift: ill-conditioned sparse ==")
        sparse = generate_sparse_set(32, rng, n_range=(40, 120))
        stream(server, sparse, "sparse-shift")
        tel = server.telemetry.snapshot()
        print(f"  drift events total: {tel['drift_events']}, "
              f"epsilon now {server.learner.epsilon.value:.3f}")
        print(f"  throughput {tel['throughput_rps']:.1f} req/s, "
              f"p50 latency {tel['latency_s']['p50'] * 1e3:.1f} ms, "
              f"pad waste {tel['pad_waste_frac']:.1%}")

        print("== 5. scrape the front door ==")
        with urllib.request.urlopen(http.url + "/readyz") as r:
            print(f"  GET /readyz -> {r.status} {r.read().decode().strip()}")
        with urllib.request.urlopen(http.url + "/metrics") as r:
            scrape = r.read().decode()
        for line in scrape.splitlines():
            if line.startswith(("repro_service_responses_total",
                                "repro_online_drift_events_total",
                                "repro_obs_errors_total")):
                print(f"  {line}")
        print(f"  trajectory log: {obs.trajlog.written} records "
              f"at {obs.trajlog.path}")

        print("== 6. snapshot + rollback ==")
        v2 = server.snapshot(note="adapted to sparse shift")
        print(f"  promoted {v2} (current={reg.current_version()})")
        prev = reg.rollback()
        print(f"  rolled back to {prev} (current={reg.current_version()})")


if __name__ == "__main__":
    main()
