"""Roofline summary rows, read from launch/dryrun artifacts.

The dry-run (src/repro/launch/dryrun.py) writes one JSON per
(arch x shape x mesh) cell with HLO FLOPs / bytes / collective bytes;
this module converts them to the three roofline terms
(EXPERIMENTS.md §Roofline) and emits CSV rows."""
from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")

# TPU v5e hardware constants (per chip), from the assignment.
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link


def terms_from_artifact(art: dict) -> dict:
    chips = art["n_devices"]
    flops = art.get("flops", art.get("flops_raw", 0.0))
    bytes_ = art.get("bytes_accessed", art.get("bytes_accessed_raw", 0.0))
    coll = art.get("collective_bytes", art.get("collective_bytes_raw", 0.0))
    per_device = art.get("cost_is_per_device", True)
    scale = 1.0 if per_device else 1.0 / chips
    t_c = flops * scale / PEAK_FLOPS
    t_m = bytes_ * scale / HBM_BW
    t_x = coll * scale / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bottleneck": dom[1],
            "roofline_frac": t_c / max(t_c, t_m, t_x, 1e-30)}


def run(full: bool = False):
    rows = []
    paths = sorted(glob.glob(os.path.join(ARTIFACTS, "*.json")))
    if not paths:
        return ["roofline/none,0,run `python -m repro.launch.dryrun` first"]
    for p in paths:
        with open(p) as f:
            art = json.load(f)
        if "flops" not in art:
            continue
        t = terms_from_artifact(art)
        name = os.path.splitext(os.path.basename(p))[0]
        rows.append(
            f"roofline/{name},{max(t['compute_s'], t['memory_s'], t['collective_s']) * 1e6:.0f},"
            f"compute_s={t['compute_s']:.4e};memory_s={t['memory_s']:.4e};"
            f"collective_s={t['collective_s']:.4e};bottleneck={t['bottleneck']};"
            f"frac={t['roofline_frac']:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
