"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table2  — dense randsvd (paper Table 2 + Fig. 2 usage distribution)
  table2fp8 — the dense grid re-run with the fp8-extended action space
            (SOLVER_LADDER_FP8; reduced scale, honestly recorded)
  table6  — penalty ablation (paper Table 6 + Fig. 4); shares solve caches
            with table2 via the env registry
  table4  — sparse SPD (paper Tables 3/4/5)
  tasks   — per-TunableTask training throughput (GMRES-IR vs CG-IR
            through the shared AutotuneEngine)
  sharded — SolveExecutor scaling: solves/s vs data-axis width on a
            forced 8-device host mesh (DESIGN.md §7; subprocess)
  backend — precision-backend comparison: jnp oracle vs pallas kernels,
            solves/s + req/s per task (DESIGN.md §6)
  service — online autotuning service: req/s + latency vs micro-batch size
  cold_start — compile-cliff arms (DESIGN.md §12): cold vs sync-warmed vs
            disk-cache-restart boots, first-hit vs steady-state per bucket
            (subprocess per arm)
  kernels — chop / qmatmul microbenchmarks
  roofline— summary rows from launch/dryrun artifacts, if present

After the selected sections run, a top-level ``BENCH_results.json`` is
written with the headline perf numbers (req/s + p50/p99 from the service
bench, solves/s per task) plus execution metadata (`jax.device_count()`,
mesh shape of the sharded sweep) so the trajectory accumulates across
PRs.

Flags: --full (paper-scale §5.1), --only <name>, --skip-solver.
"""
from __future__ import annotations

import json
import os
import sys

# Script entry (`python benchmarks/run.py`) puts benchmarks/ on sys.path,
# not the repo root the `benchmarks.*` imports need.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

import jax

jax.config.update("jax_enable_x64", True)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_RESULTS_PATH = os.path.join(REPO_ROOT, "BENCH_results.json")

_PRINTED = 0


def _flush(rows):
    global _PRINTED
    for r in rows[_PRINTED:]:
        print(r, flush=True)
    _PRINTED = len(rows)


def write_bench_results(path: str = BENCH_RESULTS_PATH) -> dict:
    """Aggregate headline numbers from the per-section reports into one
    top-level JSON (req/s, p50/p99, solves/s per task).

    Merges into the existing file: a section is only rewritten when its
    per-section report is present in benchmarks/results/, so re-running
    one section never erases the others' committed trajectory."""
    from benchmarks.common import load_report
    summary = {"service": None, "tasks": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                summary.update(json.load(f))
        except (OSError, json.JSONDecodeError):
            pass
    summary["metadata"] = {"jax_device_count": jax.device_count(),
                           "jax_backend": jax.default_backend(),
                           **summary.get("metadata", {})}
    summary["metadata"]["jax_device_count"] = jax.device_count()
    summary["metadata"]["jax_backend"] = jax.default_backend()
    summary["metadata"]["jax_version"] = jax.__version__
    service = load_report("service_bench")
    if service:
        summary["service"] = [
            {"max_batch": s["max_batch"],
             "rps": s["rps"],
             "p50_s": s["latency_s"]["p50"],
             "p99_s": s["latency_s"]["p99"],
             "pad_waste_frac": s.get("pad_waste_frac")}
            for s in service.get("settings", [])]
        if service.get("obs_overhead"):
            # Metrics-on vs metrics-off req/s (acceptance bar: <= 5%).
            summary["service_obs_overhead"] = service["obs_overhead"]
        if service.get("http_front_door"):
            # Same trace over the asyncio front door (DESIGN.md §9.1):
            # wire + JSON + admission overhead vs in-process serving.
            summary["http_front_door"] = service["http_front_door"]
    tasks = load_report("task_bench")
    if tasks:
        summary["tasks"] = {
            t["task"]: {"solves_per_s": t["solves_per_s"],
                        "n_solves": t["n_solves"],
                        "reward_last": t["reward_last"]}
            for t in tasks.get("tasks", [])}
    backend = load_report("precision_backend_bench")
    if backend:
        summary["precision_backend"] = {
            "pallas_mode": backend.get("pallas_mode"),
            "entries": [
                {"task": e["task"], "backend": e["backend"],
                 "mode": e["mode"],
                 "solves_per_s": e["solves_per_s"],
                 "req_per_s": e["req_per_s"]}
                for e in backend.get("entries", [])]}
        if backend.get("lu_trisolve"):
            # Strict row-loop vs blocked LU+trisolve pipeline
            # (DESIGN.md §6.4), with per-n blocked/strict speedups.
            entries = backend["lu_trisolve"]
            strict = {(e["n"], e["backend"]): e["solves_per_s"]
                      for e in entries if e["variant"] == "strict"}
            summary["lu_trisolve"] = [
                dict(e, speedup_vs_strict=(
                    e["solves_per_s"] / strict[(e["n"], e["backend"])]
                    if e["variant"] == "blocked"
                    and strict.get((e["n"], e["backend"])) else None))
                for e in entries]
    sharded = load_report("task_bench_sharded")
    if sharded:
        # Honest labeling: host devices share one CPU — the sweep shows
        # partition/dispatch overhead vs data width, not HW speedup.
        summary["task_bench_sharded"] = {
            "label": sharded["label"], "note": sharded["note"],
            "device_count": sharded["device_count"],
            "n": sharded["n"], "chunk": sharded["chunk"],
            "local_solves_per_s": sharded["local_solves_per_s"],
            "entries": [{"data": e["data"], "mesh_shape": e["mesh_shape"],
                         "solves_per_s": e["solves_per_s"],
                         "speedup_vs_local": e["speedup_vs_local"]}
                        for e in sharded["entries"]]}
        summary["metadata"]["sharded_mesh"] = \
            sharded["entries"][-1]["mesh_shape"]
        summary["metadata"]["sharded_device_count"] = \
            sharded["device_count"]
    cold = load_report("cold_start")
    if cold:
        # DESIGN.md §12: first-hit vs steady-state per arm + the
        # counter-based warm-restart proof; the persistent-cache-hot
        # flag rides the metadata so every headline number carries
        # whether it was produced against a warm compile cache.
        summary["cold_start"] = {
            "note": cold.get("note"),
            "warm_restart_zero_fresh_compiles":
                cold.get("warm_restart_zero_fresh_compiles"),
            "arms": {
                arm: {"boot_to_ready_s": a.get("boot_to_ready_s"),
                      "boot_to_first_solve_s":
                          a.get("boot_to_first_solve_s"),
                      "executor_compiles": a.get("executor_compiles"),
                      "compile_cache": a.get("compile_cache"),
                      "buckets": a.get("buckets")}
                for arm, a in cold.get("arms", {}).items()}}
        summary["metadata"]["compile_cache_hot"] = bool(
            cold.get("warm_restart_zero_fresh_compiles"))
    fp8 = load_report("table2_fp8")
    if fp8:
        w1 = fp8.get("settings", {}).get("W1", {})
        summary["table2_fp8"] = {
            "ladder": fp8.get("ladder"),
            "n_actions": fp8.get("n_actions"),
            "scale": fp8.get("scale"),
            "usage_per_solve": w1.get("usage_per_solve"),
            "usage_per_range": w1.get("usage_per_range"),
            "table": w1.get("table"),
            "fp64_baseline": fp8.get("fp64_baseline", {}).get("table")}
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, default=float)
    return summary


def main() -> None:
    args = set(sys.argv[1:])
    full = "--full" in args
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    rows = ["name,us_per_call,derived"]
    env_registry = {}

    def want(name, solver=True):
        if solver and "--skip-solver" in args:
            return False
        return only is None or only == name

    _flush(rows)
    if want("table2"):
        from benchmarks import table2_dense
        rows += table2_dense.run(full=full, env_registry=env_registry)
        _flush(rows)
    if want("table6"):
        from benchmarks import table6_ablation
        rows += table6_ablation.run(full=full, env_registry=env_registry)
        _flush(rows)
    if want("table4"):
        from benchmarks import table4_sparse
        rows += table4_sparse.run(full=full)
        _flush(rows)
    if want("tasks"):
        from benchmarks import task_bench
        rows += task_bench.run(full=full)
        _flush(rows)
    if want("table2fp8"):
        from benchmarks import table2_dense
        rows += table2_dense.run_fp8(full=full)
        _flush(rows)
    if want("sharded"):
        from benchmarks import task_bench
        rows += task_bench.run_sharded(full=full)
        _flush(rows)
    if want("backend"):
        from benchmarks import precision_backend_bench
        rows += precision_backend_bench.run(full=full)
        _flush(rows)
    if want("service"):
        from benchmarks import service_bench
        rows += service_bench.run(full=full)
        _flush(rows)
    if want("cold_start"):
        from benchmarks import cold_start
        rows += cold_start.run(full=full)
        _flush(rows)
    if want("kernels", solver=False):
        from benchmarks import kernel_bench
        rows += kernel_bench.run(full=full)
        _flush(rows)
    if want("roofline", solver=False):
        from benchmarks import roofline
        rows += roofline.run()
        _flush(rows)
    write_bench_results()


if __name__ == "__main__":
    main()
