"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table2  — dense randsvd (paper Table 2 + Fig. 2 usage distribution)
  table6  — penalty ablation (paper Table 6 + Fig. 4); shares solve caches
            with table2 via the env registry
  table4  — sparse SPD (paper Tables 3/4/5)
  service — online autotuning service: req/s + latency vs micro-batch size
  kernels — chop / qmatmul microbenchmarks
  roofline— summary rows from launch/dryrun artifacts, if present

Flags: --full (paper-scale §5.1), --only <name>, --skip-solver.
"""
from __future__ import annotations

import os
import sys

# Script entry (`python benchmarks/run.py`) puts benchmarks/ on sys.path,
# not the repo root the `benchmarks.*` imports need.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

import jax

jax.config.update("jax_enable_x64", True)

_PRINTED = 0


def _flush(rows):
    global _PRINTED
    for r in rows[_PRINTED:]:
        print(r, flush=True)
    _PRINTED = len(rows)


def main() -> None:
    args = set(sys.argv[1:])
    full = "--full" in args
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    rows = ["name,us_per_call,derived"]
    env_registry = {}

    def want(name, solver=True):
        if solver and "--skip-solver" in args:
            return False
        return only is None or only == name

    _flush(rows)
    if want("table2"):
        from benchmarks import table2_dense
        rows += table2_dense.run(full=full, env_registry=env_registry)
        _flush(rows)
    if want("table6"):
        from benchmarks import table6_ablation
        rows += table6_ablation.run(full=full, env_registry=env_registry)
        _flush(rows)
    if want("table4"):
        from benchmarks import table4_sparse
        rows += table4_sparse.run(full=full)
        _flush(rows)
    if want("service"):
        from benchmarks import service_bench
        rows += service_bench.run(full=full)
        _flush(rows)
    if want("kernels", solver=False):
        from benchmarks import kernel_bench
        rows += kernel_bench.run(full=full)
        _flush(rows)
    if want("roofline", solver=False):
        from benchmarks import roofline
        rows += roofline.run()
        _flush(rows)


if __name__ == "__main__":
    main()
