"""Paper Table 2: dense randsvd systems, tau in {1e-6, 1e-8}, W1/W2 + FP64
baseline, metrics per condition range. Also emits Figure 2's per-range
precision-usage distribution (the same evaluation pass produces both)."""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks.common import (W1, W2, emit_csv_rows, get_scale,
                               make_datasets, run_setting, save_report)


def run(full: bool = False, taus=(1e-6, 1e-8), env_registry=None,
        recompute: bool = False):
    from benchmarks.common import load_report
    cached = None if recompute else load_report("table2_dense")
    if cached is not None:
        rows = []
        for tau_key, report in cached.items():
            rows += emit_csv_rows(f"table2/{tau_key}", report)
        return rows
    scale = get_scale(full)
    train, test = make_datasets("dense", scale)
    rows = []
    reports = {}
    for tau in taus:
        key = ("dense", tau)
        prior = env_registry.get(key) if env_registry is not None else None
        report, envs = run_setting(train, test, tau, {"W1": W1, "W2": W2},
                                   scale, envs=prior)
        if env_registry is not None:
            env_registry[key] = envs
        reports[f"tau={tau:g}"] = report
        rows += emit_csv_rows(f"table2/tau={tau:g}", report)
    save_report("table2_dense", reports)
    return rows


if __name__ == "__main__":
    import sys
    for r in run(full="--full" in sys.argv):
        print(r)
