"""Paper Table 2: dense randsvd systems, tau in {1e-6, 1e-8}, W1/W2 + FP64
baseline, metrics per condition range. Also emits Figure 2's per-range
precision-usage distribution (the same evaluation pass produces both)."""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):      # script entry: repo root onto sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))))

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks.common import (Scale, W1, W2, emit_csv_rows, get_scale,
                               make_datasets, run_setting, save_report)


def run(full: bool = False, taus=(1e-6, 1e-8), env_registry=None,
        recompute: bool = False):
    from benchmarks.common import load_report
    cached = None if recompute else load_report("table2_dense")
    if cached is not None:
        rows = []
        for tau_key, report in cached.items():
            rows += emit_csv_rows(f"table2/{tau_key}", report)
        return rows
    scale = get_scale(full)
    train, test = make_datasets("dense", scale)
    rows = []
    reports = {}
    for tau in taus:
        key = ("dense", tau)
        prior = env_registry.get(key) if env_registry is not None else None
        report, envs = run_setting(train, test, tau, {"W1": W1, "W2": W2},
                                   scale, envs=prior)
        if env_registry is not None:
            env_registry[key] = envs
        reports[f"tau={tau:g}"] = report
        rows += emit_csv_rows(f"table2/tau={tau:g}", report)
    save_report("table2_dense", reports)
    return rows


def run_fp8(full: bool = False, recompute: bool = False, tau: float = 1e-6,
            subsample: int = 48):
    """The Table 2 dense grid re-run with the fp8-extended action space
    (`SOLVER_LADDER_FP8`: e5m2/e4m3 prepended — saturating overflow
    makes fp8 factorization a viable arm on well-conditioned systems).

    Scale is reduced relative to the paper grid (W1 only, fewer systems,
    pruned to `subsample` of the 126 monotone arms — the paper itself
    prunes to ~1/4) so the fp8 sweep stays CPU-host-sized; the report
    carries the exact scale so `BENCH_results.json` is honest about it.
    """
    from benchmarks.common import load_report
    from repro.core import fp8_reduced_action_space
    cached = None if recompute else load_report("table2_fp8")
    if cached is None:
        scale = Scale(n_train=24, n_test=24, episodes=30,
                      n_range=(100, 250)) if not full else get_scale(True)
        space = fp8_reduced_action_space(subsample=subsample)
        train, test = make_datasets("dense", scale)
        report, _ = run_setting(train, test, tau, {"W1": W1}, scale,
                                space=space)
        report["ladder"] = list(space.ladder)
        report["n_actions"] = int(space.n_actions)
        report["scale"] = {"n_train": scale.n_train, "n_test": scale.n_test,
                           "episodes": scale.episodes,
                           "n_range": list(scale.n_range),
                           "subsample": subsample, "weights": ["W1"]}
        save_report("table2_fp8", report)
        cached = report
    return emit_csv_rows("table2_fp8", cached)


if __name__ == "__main__":
    import sys
    if "--fp8" in sys.argv:
        for r in run_fp8(full="--full" in sys.argv,
                         recompute="--recompute" in sys.argv):
            print(r)
    else:
        for r in run(full="--full" in sys.argv):
            print(r)
