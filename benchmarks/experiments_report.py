"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts. Usage: PYTHONPATH=src python -m benchmarks.experiments_report"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, terms_from_artifact

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_all():
    arts = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            arts.append(json.load(f))
    return arts


def dryrun_table(arts):
    rows = ["| arch | shape | mesh | compile s | HLO flops/dev | "
            "bytes/dev | collective B/dev | temp GB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for a in sorted(arts, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        mem = a.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        coll = a.get("collective_bytes", a.get("collective_bytes_raw", 0))
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a.get('compile_s', 0):.0f} "
            f"| {a.get('flops', a.get('flops_raw', 0)):.3e} "
            f"| {a.get('bytes_accessed', 0):.3e} "
            f"| {coll:.3e} | {mem:.2f} |")
    return "\n".join(rows)


def roofline_table(arts):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | roofline frac | MODEL_FLOPS/HLO | accounting |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in sorted(arts, key=lambda x: (x["arch"], x["shape"])):
        if a["mesh"] != "pod16x16":
            continue
        t = terms_from_artifact(a)
        flops = a.get("flops", a.get("flops_raw", 0.0))
        useful = a["model_flops"] / max(flops * a["n_devices"], 1e-30)
        acct = "calibrated" if "calibration" in a else "raw(loop-once)"
        rows.append(
            f"| {a['arch']} | {a['shape']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['bottleneck']} "
            f"| {t['roofline_frac']:.3f} | {useful:.3f} | {acct} |")
    return "\n".join(rows)


def pick_hillclimb(arts):
    """worst roofline fraction / most collective-bound / most paper-
    representative (largest precision-knob surface = biggest MoE train)."""
    singles = [a for a in arts if a["mesh"] == "pod16x16" and "flops" in a]
    with_t = [(a, terms_from_artifact(a)) for a in singles]
    worst = min(with_t, key=lambda at: at[1]["roofline_frac"])
    coll = max(with_t, key=lambda at: at[1]["collective_s"]
               / max(at[1]["compute_s"], 1e-30))
    return worst[0], coll[0]


def main():
    arts = load_all()
    print(f"## §Dry-run ({len(arts)} cells)\n")
    print(dryrun_table(arts))
    print("\n## §Roofline (single-pod 16x16)\n")
    print(roofline_table(arts))
    if any("flops" in a for a in arts):
        w, c = pick_hillclimb(arts)
        print(f"\nworst-fraction cell: {w['arch']} x {w['shape']}")
        print(f"most collective-bound: {c['arch']} x {c['shape']}")


if __name__ == "__main__":
    main()
