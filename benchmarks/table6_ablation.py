"""Paper Table 6 + Fig. 4: reward ablation — remove f_penalty.

Expectation from the paper: without the iteration penalty the agent selects
more reduced-precision steps and compensates with extra (GMRES) iterations
for comparable accuracy — demonstrating why the penalty term matters."""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks.common import (W1, W1_NOPEN, W2, W2_NOPEN, emit_csv_rows,
                               get_scale, make_datasets, run_setting,
                               save_report)


def run(full: bool = False, taus=(1e-6, 1e-8), env_registry=None,
        recompute: bool = False):
    from benchmarks.common import load_report
    cached = None if recompute else load_report("table6_ablation")
    if cached is not None:
        rows = []
        for tau_key, report in cached.items():
            rows += emit_csv_rows(f"table6/{tau_key}", report)
            for w in ("W1", "W2"):
                with_p = report["settings"][w]["table"]
                no_p = report["settings"][f"{w}_nopenalty"]["table"]
                for rng_name in with_p:
                    if rng_name in no_p:
                        d = (no_p[rng_name]["avg_gmres_iter"]
                             - with_p[rng_name]["avg_gmres_iter"])
                        rows.append(f"table6/{tau_key}/delta_gmres/{w}/"
                                    f"{rng_name},0,nopen_minus_pen={d:.2f}")
        return rows
    scale = get_scale(full)
    train, test = make_datasets("dense", scale)
    rows = []
    reports = {}
    for tau in taus:
        # Shared env caches across with/without-penalty (reward-independent)
        # and with table2 (same systems, same tau) via the registry.
        key = ("dense", tau)
        prior = env_registry.get(key) if env_registry is not None else None
        report, envs = run_setting(
            train, test, tau,
            {"W1_nopenalty": W1_NOPEN, "W2_nopenalty": W2_NOPEN,
             "W1": W1, "W2": W2}, scale, envs=prior)
        if env_registry is not None:
            env_registry[key] = envs
        reports[f"tau={tau:g}"] = report
        rows += emit_csv_rows(f"table6/tau={tau:g}", report)
        # Headline ablation check: no-penalty uses at least as many GMRES
        # iterations as with-penalty (paper's Table 6 finding).
        for w in ("W1", "W2"):
            with_p = report["settings"][w]["table"]
            no_p = report["settings"][f"{w}_nopenalty"]["table"]
            for rng_name in with_p:
                if rng_name in no_p:
                    d = (no_p[rng_name]["avg_gmres_iter"]
                         - with_p[rng_name]["avg_gmres_iter"])
                    rows.append(
                        f"table6/tau={tau:g}/delta_gmres/{w}/{rng_name},0,"
                        f"nopen_minus_pen={d:.2f}")
    save_report("table6_ablation", reports)
    return rows


if __name__ == "__main__":
    import sys
    for r in run(full="--full" in sys.argv):
        print(r)
