"""Cold-start benchmark: the compile cliff, measured (DESIGN.md §12).

Three serving arms, each booted in a fresh subprocess (a fresh process
is the only honest "cold": jit caches, dispatcher memos, and the
per-shape executable caches are all process-global):

  cold         — lazy server, no warmup, no persistent cache: the first
                 request per bucket pays lower+compile in-band.
  warmed       — ``warmup="sync"`` over the bucket grid: compiles run at
                 boot, the first request dispatches a warm executable.
  disk_restart — ``warmup="sync"`` with ``REPRO_COMPILE_CACHE_DIR``; the
                 arm is the SECOND boot against the same cache dir, so
                 its warmup is served from disk (zero fresh XLA
                 compiles, asserted on the jax compilation-cache
                 counters — never timing).

Per arm, per bucket: first-request latency, then steady-state p50/p99
over repeated single-request round trips; plus boot-to-ready and
boot-to-first-solve walls. The headline derived number is
``first/steady-p50`` — the cliff ratio the warmup is meant to kill.

All arms run single-request micro-batches on this host's CPU backend;
the report is about *relative* first-hit vs steady-state shape, not
absolute device throughput (honest-labeling rule, DESIGN.md §10).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import RESULTS_DIR, save_report

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

# Child process: boot one serving arm, time first hits + steady state.
# `_T0` is bound before any heavy import so boot walls include them.
CHILD = r"""
import time
_T0 = time.time()
import json, sys
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import Discretizer, QTable, reduced_action_space
from repro.core import aot, executor as EX
from repro.core.features import PAPER_FEATURES
from repro.core.policy import PrecisionPolicy
from repro.data import generate_dense_set
from repro.service import AutotuneServer, BatcherConfig
from repro.solvers import IRConfig

arm, steady_n = sys.argv[1], int(sys.argv[2])
SPACE = reduced_action_space()
nf = len(PAPER_FEATURES)
feats = np.random.default_rng(0).normal(size=(8, nf))
disc = Discretizer.fit(feats, [2] * nf)
pol = PrecisionPolicy(SPACE, disc, QTable(disc.n_states, SPACE.n_actions))
warm = dict(warmup="sync", warmup_buckets=[16, 32]) \
    if arm != "cold" else {}
srv = AutotuneServer(pol, IRConfig(tau=1e-5, i_max=4, m_max=12),
                     batcher_cfg=BatcherConfig(max_batch=1,
                                               max_wait_s=0.0,
                                               bucket_step=16,
                                               min_bucket=16),
                     obs=False, seed=0, **warm)
t_ready = time.time() - _T0

def solve_one(n_lo, n_hi, seed):
    s = generate_dense_set(1, np.random.default_rng(seed),
                           n_range=(n_lo, n_hi),
                           log10_kappa_range=(3, 4))[0]
    t0 = time.perf_counter()
    rid = srv.submit(s)
    srv.drain()
    assert srv.poll(rid) is not None
    return time.perf_counter() - t0

out = {"arm": arm, "boot_to_ready_s": round(t_ready, 3), "buckets": {}}
first_solve_done = None
for bucket, (lo, hi) in ((16, (12, 15)), (32, (20, 30))):
    first = solve_one(lo, hi, 100 + bucket)
    if first_solve_done is None:
        first_solve_done = time.time() - _T0
    lats = sorted(solve_one(lo, hi, 1000 + bucket + i)
                  for i in range(steady_n))
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    out["buckets"][str(bucket)] = {
        "first_request_s": round(first, 4),
        "steady_p50_s": round(p50, 4),
        "steady_p99_s": round(p99, 4),
        "first_over_steady_p50": round(first / p50, 1),
        "n_steady": len(lats)}
out["boot_to_first_solve_s"] = round(first_solve_done, 3)
out["executor_compiles"] = EX.executor_compile_count()
out["compile_cache"] = aot.cache_stats()
print("RESULT " + json.dumps(out))
"""


def _boot(arm: str, steady_n: int, cache_dir: str = "") -> dict:
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    env.pop("REPRO_COMPILE_CACHE_DIR", None)
    if cache_dir:
        env["REPRO_COMPILE_CACHE_DIR"] = cache_dir
    out = subprocess.run(
        [sys.executable, "-c", CHILD, arm, str(steady_n)],
        env=env, capture_output=True, text=True, timeout=1200)
    lines = [ln for ln in out.stdout.splitlines()
             if ln.startswith("RESULT ")]
    if not lines:
        raise RuntimeError(
            f"cold_start arm {arm!r} produced no result: "
            f"{out.stdout[-1000:]} {out.stderr[-2000:]}")
    return json.loads(lines[-1][len("RESULT "):])


def run(full: bool = False, steady_n: int = None):
    steady_n = steady_n or (50 if full else 25)
    report = {"steady_n": steady_n, "arms": {}}
    report["arms"]["cold"] = _boot("cold", steady_n)
    report["arms"]["warmed"] = _boot("warmed", steady_n)
    with tempfile.TemporaryDirectory() as d:
        cache = os.path.join(d, "xla-cache")
        priming = _boot("disk_restart", steady_n, cache_dir=cache)
        restart = _boot("disk_restart", steady_n, cache_dir=cache)
    restart["priming_boot_to_ready_s"] = priming["boot_to_ready_s"]
    report["arms"]["disk_restart"] = restart
    # Counter-based warm-restart proof: the second boot's entire grid
    # came from disk (hits > 0) with zero fresh XLA compiles.
    report["warm_restart_zero_fresh_compiles"] = bool(
        restart["compile_cache"]["misses"] == 0
        and restart["compile_cache"]["hits"] > 0)
    report["note"] = ("single-host CPU backend; relative first-hit vs "
                      "steady-state shape, not device throughput")
    save_report("cold_start", report)
    rows = []
    for arm, data in report["arms"].items():
        for bucket, b in data["buckets"].items():
            rows.append(
                f"cold_start/{arm}/bucket{bucket},"
                f"{b['first_request_s'] * 1e6:.0f},"
                f"p50={b['steady_p50_s']:.4f}s;"
                f"p99={b['steady_p99_s']:.4f}s;"
                f"cliff={b['first_over_steady_p50']:.1f}x")
        rows.append(f"cold_start/{arm}/boot,"
                    f"{data['boot_to_first_solve_s'] * 1e6:.0f},"
                    f"ready={data['boot_to_ready_s']:.1f}s;"
                    f"compiles={data['executor_compiles']}")
    return rows
