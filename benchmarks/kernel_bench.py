"""Kernel microbenchmarks (CPU wall-time is indicative only; the real perf
story for TPU is the §Roofline analysis from the compiled dry-run)."""
from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=10):
    fn(*args).block_until_ready()
    t = timeit.timeit(lambda: fn(*args).block_until_ready(), number=n) / n
    return t * 1e6


def run(full: bool = False):
    from repro.kernels.chop import chop_op
    from repro.precision import FORMAT_ID, chop

    rows = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1 << 20).astype(np.float32))
    fid = FORMAT_ID["bf16"]

    jnp_chop = jax.jit(lambda v: chop(v, fid))
    us = _time(jnp_chop, x)
    rows.append(f"kernels/chop_jnp_1M_f32,{us:.0f},"
                f"GBps={x.size * 8 / us / 1e3:.2f}")

    us = _time(lambda v: chop_op(v, fid, interpret=True), x, n=3)
    rows.append(f"kernels/chop_pallas_interp_1M_f32,{us:.0f},"
                "note=interpret-mode;correctness-only")

    from repro.kernels.qmatmul import qmatmul_ref
    a = jnp.asarray(rng.standard_normal((512, 512)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((512, 512)).astype(np.float32))
    ref = jax.jit(lambda p, q: qmatmul_ref(p, q, fid))
    us = _time(ref, a, b)
    flops = 2 * 512 ** 3
    rows.append(f"kernels/qmatmul_ref_512,{us:.0f},"
                f"GFLOPs={flops / us / 1e3:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
