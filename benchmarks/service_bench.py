"""Online-service throughput/latency benchmark.

Streams a fixed mixed-size request trace through `service.AutotuneServer`
at several micro-batch sizes and reports requests/sec plus p50/p90/p99
per-request latency for each. Per-bucket executables are warmed up (one
full batch per bucket) before timing so the numbers measure steady-state
serving, not XLA compilation.

An extra arm re-runs one batch size with observability fully off
(`obs=False`) vs fully on (metrics + tracer + trajectory log) and
records the req/s overhead — the fail-open layer's <= 5% acceptance
bar (DESIGN.md §8) — under ``obs_overhead`` in the report. A second
extra arm replays the same trace through the asyncio HTTP front door
(DESIGN.md §9.1) and records req/s + p50/p99 vs the in-process
setting under ``http_front_door``. A third arm prices the trajectory
log's WAL fsync knob (DESIGN.md §11.1) — the same trace at
``sync="none"|"rotate"|"always"`` — under ``trajlog_sync``;
``--trajlog-sync`` prints just that row.

CSV rows follow the `benchmarks/run.py` contract (name,us_per_call,derived)
and the full report lands in benchmarks/results/service_bench.json.

    PYTHONPATH=src python benchmarks/service_bench.py \\
        [--full] [--recompute] [--trajlog-sync]
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):      # script entry: repo root onto sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))))

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from benchmarks.common import (W1, get_scale, load_report, save_report)
from repro.core import (GMRESIREnv, TrainConfig, bucket_of,
                        reduced_action_space)
from repro.data import generate_dense_set, generate_sparse_set
from repro.obs import MetricsRegistry, Observability
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)
from repro.solvers import IRConfig

BATCH_SIZES = (1, 4, 8)
BATCH_SIZES_FULL = (1, 4, 8, 16)


def _trace(n_requests: int, n_range, seed: int):
    """Mixed dense/sparse request stream, interleaved deterministically."""
    rng = np.random.default_rng(seed)
    dense = generate_dense_set(int(n_requests * 0.8), rng, n_range)
    sparse = generate_sparse_set(n_requests - len(dense), rng, n_range)
    trace = dense + sparse
    rng.shuffle(trace)
    return trace


def bench_setting(registry_root, trace, max_batch: int, ir_cfg,
                  bucket_step: int, obs=None) -> dict:
    """One timed streaming pass. `obs` is forwarded to the server:
    None = the production default (process-default metrics registry),
    False = observability disabled, or an explicit `Observability`
    bundle (the metrics-on arm of the overhead comparison)."""
    srv = AutotuneServer(
        PolicyRegistry(registry_root), ir_cfg, W1,
        BatcherConfig(max_batch=max_batch, max_wait_s=0.02,
                      bucket_step=bucket_step, min_bucket=bucket_step),
        OnlineConfig(), obs=obs)
    # Warm-up: compile each bucket's executable outside the timed window.
    buckets = {}
    for s in trace:
        buckets.setdefault(bucket_of(s.n, bucket_step, bucket_step), s)
    for s in buckets.values():
        for _ in range(max_batch):
            srv.submit(s)
        srv.drain()
    warm_responses = srv.telemetry.responses

    t0 = time.perf_counter()
    ids = []
    for s in trace:
        ids.append(srv.submit(s))
        srv.step()
    srv.drain()
    wall = time.perf_counter() - t0
    responses = [srv.poll(i) for i in ids]
    assert all(r is not None for r in responses)
    lat = np.array([r.latency_s for r in responses], dtype=np.float64)
    tel = srv.telemetry.snapshot()
    return {
        "max_batch": max_batch,
        "n_requests": len(trace),
        "wall_s": wall,
        "rps": len(trace) / wall,
        "latency_s": {f"p{q}": float(np.percentile(lat, q))
                      for q in (50, 90, 99)},
        "pad_waste_frac": tel["pad_waste_frac"],
        "solver_batches": tel["solver_batches"] ,
        "drift_events": tel["drift_events"],
        "warmup_responses": warm_responses,
        "usage_per_solve": tel["usage_per_solve"],
    }


def bench_http(registry_root, trace, max_batch: int, ir_cfg,
               bucket_step: int) -> dict:
    """The same trace over the asyncio HTTP front door: fire-and-poll
    against `/v1/solve` + `/v1/result/{id}`, so the delta vs the
    in-process setting is the wire + JSON + admission overhead."""
    import json as _json
    import urllib.error
    import urllib.request

    from repro.service.http import HttpConfig, serve_http

    srv = AutotuneServer(
        PolicyRegistry(registry_root), ir_cfg, W1,
        BatcherConfig(max_batch=max_batch, max_wait_s=0.02,
                      bucket_step=bucket_step, min_bucket=bucket_step),
        OnlineConfig(), obs=False)
    fd = serve_http(srv, cfg=HttpConfig(
        max_n=4096, max_queue_depth=len(trace) + 8 * max_batch,
        flush_interval_s=0.002))

    def call(method, path, payload=None):
        data = (_json.dumps(payload).encode()
                if payload is not None else None)
        req = urllib.request.Request(
            fd.url + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=120) as r:
                return r.status, _json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            return e.code, {}

    def payload(s):
        return {"A": s.A.tolist(), "b": s.b.tolist(),
                "x_true": s.x_true.tolist()}

    try:
        # Warm-up: compile each bucket's executable outside the timed
        # window (mirrors bench_setting).
        buckets = {}
        for s in trace:
            buckets.setdefault(bucket_of(s.n, bucket_step, bucket_step), s)
        for s in buckets.values():
            call("POST", "/v1/solve:sync", payload(s))

        t0 = time.perf_counter()
        rids = []
        for s in trace:
            code, acc = call("POST", "/v1/solve", payload(s))
            assert code == 202, code
            rids.append(acc["request_id"])
        results = {}
        while len(results) < len(rids):
            for rid in rids:
                if rid in results:
                    continue
                code, body = call("GET", f"/v1/result/{rid}")
                if code == 200:
                    results[rid] = body
        wall = time.perf_counter() - t0
    finally:
        fd.close()
    lat = np.array([results[rid]["latency_s"] for rid in rids],
                   dtype=np.float64)
    return {
        "max_batch": max_batch,
        "n_requests": len(trace),
        "wall_s": wall,
        "rps": len(trace) / wall,
        "latency_s": {f"p{q}": float(np.percentile(lat, q))
                      for q in (50, 90, 99)},
    }


def run(full: bool = False, recompute: bool = False,
        registry_root: str = None, n_requests: int = None,
        n_range: tuple = None, batches: tuple = None,
        episodes: int = None, n_train: int = None,
        bucket_step: int = 64) -> list:
    """Scale parameters default to the --full / host presets; tests pass
    tiny overrides."""
    cached = None if recompute else load_report("service_bench")
    if cached is not None:
        return emit_rows(cached)
    scale = get_scale(full)
    n_requests = n_requests or (128 if full else 48)
    n_range = n_range or (scale.n_range if full else (48, 160))
    batches = batches or (BATCH_SIZES_FULL if full else BATCH_SIZES)
    episodes = episodes or (60 if full else 20)
    n_train = n_train or (scale.n_train if full else 24)
    rng = np.random.default_rng(scale.seed)
    train = generate_dense_set(n_train, rng, n_range)
    space = reduced_action_space()
    ir_cfg = IRConfig(tau=1e-6)
    env = GMRESIREnv(train, space, ir_cfg, chunk=8, bucket_step=bucket_step)
    import tempfile
    root_ctx = None
    if registry_root is None:
        root_ctx = tempfile.TemporaryDirectory()
    root = registry_root or root_ctx.name
    PolicyRegistry.warm_start(root, env, W1,
                              TrainConfig(episodes=episodes,
                                          seed=scale.seed))
    trace = _trace(n_requests, n_range, scale.seed + 1)
    report = {"n_requests": n_requests, "bucket_step": bucket_step,
              "settings": [bench_setting(root, trace, mb, ir_cfg,
                                         bucket_step)
                           for mb in batches]}
    # Observability overhead: the same trace through one batch size with
    # the layer fully off vs fully on (isolated registry + tracer + the
    # JSONL trajectory log — the most expensive configuration). The
    # acceptance bar is <= 5% req/s; BENCH_results.json records it.
    mb = 4 if 4 in batches else batches[-1]
    off = bench_setting(root, trace, mb, ir_cfg, bucket_step, obs=False)
    with tempfile.TemporaryDirectory() as td:
        bundle = Observability(
            registry=MetricsRegistry(),
            trajectory_path=os.path.join(td, "trajectory.jsonl"))
        on = bench_setting(root, trace, mb, ir_cfg, bucket_step,
                           obs=bundle)
        bundle.close()
    report["obs_overhead"] = {
        "max_batch": mb,
        "rps_off": off["rps"],
        "rps_on": on["rps"],
        "overhead_pct": 100.0 * (1.0 - on["rps"] / off["rps"]),
    }
    # Trajectory-log durability arm (DESIGN.md §11.1): the same trace
    # with the WAL fsync knob at each level. "always" is the zero-loss
    # setting crash recovery leans on; this row quantifies its price
    # relative to "none" (page-cache durability only).
    sync_rps = {}
    for sync in ("none", "rotate", "always"):
        with tempfile.TemporaryDirectory() as td:
            bundle = Observability(
                registry=MetricsRegistry(),
                trajectory_path=os.path.join(td, "trajectory.jsonl"),
                trajectory_sync=sync)
            res = bench_setting(root, trace, mb, ir_cfg, bucket_step,
                                obs=bundle)
            bundle.close()
        sync_rps[sync] = res["rps"]
    report["trajlog_sync"] = {
        "max_batch": mb,
        "rps": sync_rps,
        "fsync_overhead_pct": 100.0 * (1.0 - sync_rps["always"]
                                       / sync_rps["none"]),
    }
    # HTTP front-door arm: the same trace fire-and-polled over the wire
    # vs the in-process setting at the same batch size.
    http = bench_http(root, trace, mb, ir_cfg, bucket_step)
    inproc = next(s for s in report["settings"] if s["max_batch"] == mb)
    report["http_front_door"] = {
        "max_batch": mb,
        "n_requests": http["n_requests"],
        "rps": http["rps"],
        "latency_s": http["latency_s"],
        "rps_inproc": inproc["rps"],
        "overhead_pct": 100.0 * (1.0 - http["rps"] / inproc["rps"]),
    }
    save_report("service_bench", report)
    if root_ctx is not None:
        root_ctx.cleanup()
    return emit_rows(report)


def emit_rows(report: dict) -> list:
    rows = []
    for s in report["settings"]:
        us = 1e6 * s["wall_s"] / max(s["n_requests"], 1)
        derived = (f"rps={s['rps']:.2f};p50={s['latency_s']['p50']:.4f};"
                   f"p99={s['latency_s']['p99']:.4f};"
                   f"pad_waste={s['pad_waste_frac']:.3f}")
        rows.append(f"service/b{s['max_batch']},{us:.0f},{derived}")
    ov = report.get("obs_overhead")
    if ov:
        us = 1e6 / max(ov["rps_on"], 1e-9)
        rows.append(
            f"service/obs_overhead_b{ov['max_batch']},{us:.0f},"
            f"rps_on={ov['rps_on']:.2f};rps_off={ov['rps_off']:.2f};"
            f"overhead_pct={ov['overhead_pct']:.2f}")
    ts = report.get("trajlog_sync")
    if ts:
        us = 1e6 / max(ts["rps"]["always"], 1e-9)
        rows.append(
            f"service/trajlog_sync_b{ts['max_batch']},{us:.0f},"
            f"rps_none={ts['rps']['none']:.2f};"
            f"rps_rotate={ts['rps']['rotate']:.2f};"
            f"rps_always={ts['rps']['always']:.2f};"
            f"fsync_overhead_pct={ts['fsync_overhead_pct']:.2f}")
    hf = report.get("http_front_door")
    if hf:
        us = 1e6 / max(hf["rps"], 1e-9)
        rows.append(
            f"service/http_b{hf['max_batch']},{us:.0f},"
            f"rps={hf['rps']:.2f};p50={hf['latency_s']['p50']:.4f};"
            f"p99={hf['latency_s']['p99']:.4f};"
            f"overhead_pct={hf['overhead_pct']:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    rows = run(full="--full" in sys.argv,
               recompute="--recompute" in sys.argv)
    if "--trajlog-sync" in sys.argv:    # just the durability-price row
        rows = [r for r in rows if r.startswith("service/trajlog_sync")]
    for r in rows:
        print(r)
