"""Paper Tables 3+4+5: sparse SPD systems (high condition numbers).

Expectation from the paper: the agent goes conservative — FP64-dominant
usage (~3.99-4.00 of 4 steps), errors and iteration counts matching the
FP64 baseline, 100% success under both weight settings."""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from benchmarks.common import (W1, W2, emit_csv_rows, get_scale,
                               make_datasets, run_setting, save_report)


def run(full: bool = False, taus=(1e-6, 1e-8), recompute: bool = False):
    import dataclasses

    from benchmarks.common import load_report
    cached = None if recompute else load_report("table4_sparse")
    if cached is not None:
        rows = []
        for tau_key, report in cached.items():
            if tau_key.startswith("tau="):
                rows += emit_csv_rows(f"table4/{tau_key}", report)
        return rows
    scale = get_scale(full)
    if not full:
        # Sparse solves on ill-conditioned systems are the slowest cells on
        # this 1-core host; the conservatism result (paper Tables 4/5) is
        # insensitive to sample count, so the default scale is smaller.
        scale = dataclasses.replace(scale, n_train=40, n_test=40,
                                    episodes=50)
    train, test = make_datasets("sparse", scale)
    # Table 3: dataset summary.
    summary = {
        "train": {
            "kappa": [float(np.min([s.kappa for s in train])),
                      float(np.max([s.kappa for s in train]))],
            "sparsity": [float(np.min([1 - s.features['sparsity']
                                       for s in train])),
                         float(np.max([1 - s.features['sparsity']
                                       for s in train]))],
            "n": [min(s.n for s in train), max(s.n for s in train)],
        },
        "test": {
            "kappa": [float(np.min([s.kappa for s in test])),
                      float(np.max([s.kappa for s in test]))],
            "n": [min(s.n for s in test), max(s.n for s in test)],
        },
    }
    rows = []
    reports = {"table3_summary": summary}
    for tau in taus:
        report, envs = run_setting(train, test, tau, {"W1": W1, "W2": W2},
                                   scale)
        # Table 5: average per-solve format usage (rows sum to 4).
        for name, data in report["settings"].items():
            data["table5_usage"] = {
                k: round(v * 4 / sum(data["usage_per_solve"].values()), 3)
                if sum(data["usage_per_solve"].values()) else 0.0
                for k, v in data["usage_per_solve"].items()}
        reports[f"tau={tau:g}"] = report
        rows += emit_csv_rows(f"table4/tau={tau:g}", report)
    save_report("table4_sparse", reports)
    return rows


if __name__ == "__main__":
    import sys
    for r in run(full="--full" in sys.argv):
        print(r)
