"""Off-policy evaluation throughput benchmark.

Times `repro.eval.ope.evaluate_policy` (IPS + DM + DR, including the
stratified bootstrap) over synthetic logged streams of several sizes,
and the `ope_gate` end to end (two candidates scored against one
shared reward model). OPE runs inside `start_rollout` on the serving
path (DESIGN.md §10.3), so its wall-clock cost per logged record is an
operational number, not a curiosity: it bounds how much log history a
gate can afford to score at each candidate admission.

CSV rows follow the `benchmarks/run.py` contract
(name,us_per_call,derived — here us per logged record); the full
report lands in benchmarks/results/ope_bench.json.

    PYTHONPATH=src python benchmarks/ope_bench.py [--full] [--recompute]
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):      # script entry: repo root onto sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))))

import time

import numpy as np

from benchmarks.common import load_report, save_report
from repro.eval.ope import (CallableCandidate, OPEConfig, evaluate_policy,
                            ope_gate)

K = 8          # arms
S = 32         # states
EPS = 0.2

SIZES = (1_000, 10_000)
SIZES_FULL = (1_000, 10_000, 100_000)
BOOTSTRAPS = (50, 200)


def _records(n, seed=0):
    rng = np.random.default_rng(seed)
    r_table = rng.normal(0.0, 3.0, (S, K))
    recs = []
    for i in range(n):
        s = int(rng.integers(S))
        explore = bool(rng.random() < EPS)
        a = int(rng.integers(K)) if explore else int(np.argmax(r_table[s]))
        recs.append({"features": [float(s)], "state": s, "action": a,
                     "eps": EPS, "explore": explore,
                     "reward": float(r_table[s, a]
                                     + 0.1 * rng.standard_normal()),
                     "bucket": 16 * (1 + s % 4)})
    return recs


def _cand(offset, name):
    return CallableCandidate(
        lambda feats, state, o=offset: (int(state) + o) % K, name=name)


def run(full=False):
    report = {"sizes": {}}
    for n in (SIZES_FULL if full else SIZES):
        recs = _records(n)
        row = {}
        for nb in BOOTSTRAPS:
            cfg = OPEConfig(n_bootstrap=nb, seed=0)
            t0 = time.perf_counter()
            ests = evaluate_policy(recs, _cand(1, "cand"), n_actions=K,
                                   cfg=cfg)
            dt = time.perf_counter() - t0
            row[f"evaluate_b{nb}"] = {
                "seconds": dt, "us_per_record": dt * 1e6 / n,
                "dr": ests["dr"].value, "ess": ests["dr"].ess}
        t0 = time.perf_counter()
        rep = ope_gate(recs, _cand(0, "incumbent"), _cand(1, "cand"),
                       n_actions=K, cfg=OPEConfig(n_bootstrap=200, seed=0))
        dt = time.perf_counter() - t0
        row["gate_b200"] = {"seconds": dt, "us_per_record": dt * 1e6 / n,
                            "accept": rep.accept, "reason": rep.reason}
        report["sizes"][str(n)] = row
    return report


def emit_csv(report):
    rows = []
    for n, row in report["sizes"].items():
        for arm, d in row.items():
            derived = ";".join(f"{k}={v}" for k, v in d.items()
                               if k not in ("seconds", "us_per_record"))
            rows.append(f"ope_bench/{arm}/n{n},"
                        f"{d['us_per_record']:.2f},{derived}")
    return rows


def main(argv):
    full = "--full" in argv
    name = "ope_bench_full" if full else "ope_bench"
    report = None if "--recompute" in argv else load_report(name)
    if report is None:
        report = run(full=full)
        save_report(name, report)
    for row in emit_csv(report):
        print(row)


if __name__ == "__main__":
    main(sys.argv[1:])
