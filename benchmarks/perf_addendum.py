"""Emit the EXPERIMENTS.md §Perf addendum: baseline vs optimized roofline
terms for the hillclimbed cells.

Usage: PYTHONPATH=src python -m benchmarks.perf_addendum
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import terms_from_artifact

ROOT = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _load(reldir, name):
    p = os.path.join(ROOT, reldir, name + "__pod16x16.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def row(tag, art):
    if art is None:
        return f"| {tag} | (pending) | | | | |"
    t = terms_from_artifact(art)
    mem = art.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
    return (f"| {tag} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['bottleneck']} "
            f"| {t['roofline_frac']:.3f} | {mem:.1f} |")


CELLS = [
    ("falcon-mamba-7b__train_4k", [
        ("baseline (materialized scan states)", "dryrun"),
        ("opt1: per-chunk scan states", "dryrun_opt"),
        ("opt2: + bf16 param gathers", "dryrun_opt2"),
    ]),
    ("falcon-mamba-7b__prefill_32k", [
        ("baseline (materialized scan states)", "dryrun"),
        ("opt: per-chunk scan states (transfer)", "dryrun_opt"),
    ]),
    ("gemma-2b__train_4k", [
        ("baseline (fp32 gathers, full SxS scores)", "dryrun"),
        ("opt: bf16 gathers + 8-way q-chunked attention", "dryrun_opt"),
    ]),
    ("llama4-scout-17b-16e__train_4k", [
        ("baseline (paper-faithful defaults)", "dryrun_calib"),
        ("opt: bf16 gathers + q-chunked attention", "dryrun_opt"),
    ]),
]


def main():
    for cell, variants in CELLS:
        print(f"\n### {cell}\n")
        print("| variant | compute s | memory s | collective s | "
              "bottleneck | roofline frac | temp GB/dev |")
        print("|---|---|---|---|---|---|---|")
        for tag, d in variants:
            print(row(tag, _load(d, cell)))


if __name__ == "__main__":
    main()
