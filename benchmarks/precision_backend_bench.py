"""Precision-backend throughput: jnp oracle vs pallas kernels, end to end.

For each task (GMRES-IR on dense randsvd, CG-IR on sparse SPD) and each
precision backend (DESIGN.md §6), measures

  * solves/s through the `AutotuneEngine` (exhaustive instance x action
    sweep — every solve runs the full batched solver on that backend), and
  * req/s through the serving stack (`AutotuneServer` submit -> micro-
    batch -> solve -> reward -> Q-update roundtrip), and
  * solves/s of the LU + triangular-substitution pipeline, strict
    row-loop vs blocked (panel LU + chopped-GEMM trailing update +
    block-triangular solves, DESIGN.md §6.4), per n and per backend —
    the `lu_trisolve` section,

so `BENCH_results.json` accumulates the jnp-vs-pallas hot-path
comparison the backend layer exists for. Off-TPU the pallas backend is
benchmarked through the Pallas *interpreter* (recorded in the report's
``mode`` field): that measures dispatch correctness and overhead, not
kernel speed — compiled-TPU numbers come from running this same bench
on a TPU host, where `"pallas"` resolves to the compiled kernels.

    PYTHONPATH=src python benchmarks/precision_backend_bench.py [--recompute]
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):      # script entry: repo root onto sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))))

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from benchmarks.common import W1, load_report, save_report
from repro.core import TrainConfig, reduced_action_space
from repro.core.engine import AutotuneEngine
from repro.data import generate_dense_set, generate_sparse_set
from repro.precision import PallasBackend, resolve_backend
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)
from repro.solvers import CGConfig, IRConfig
from repro.tasks import CGIRTask, GMRESIRTask

BUCKET = 48
CHUNK = 8


def _backend_under_test():
    """(label, backend, mode) for the pallas side of the comparison."""
    if jax.default_backend() == "tpu":
        return resolve_backend("pallas"), "compiled-tpu"
    return PallasBackend(interpret=True), "interpret-cpu"


def _systems(task_name: str, n_sys: int, seed: int, n_range=(16, 44)):
    rng = np.random.default_rng(seed)
    if task_name == "gmres_ir":
        return generate_dense_set(n_sys, rng, n_range=n_range,
                                  log10_kappa_range=(1, 6))
    return generate_sparse_set(n_sys, rng, n_range=n_range,
                               log10_kappa_range=(4, 6))


def _make_task(task_name: str, systems, backend):
    space = reduced_action_space()
    if task_name == "gmres_ir":
        return GMRESIRTask(systems, space, IRConfig(tau=1e-6),
                           bucket_step=BUCKET, min_bucket=BUCKET,
                           backend=backend)
    return CGIRTask(systems, space, CGConfig(tau=1e-6),
                    bucket_step=BUCKET, min_bucket=BUCKET, backend=backend)


def bench_engine(task_name: str, backend, n_sys: int, n_range,
                 seed: int = 0) -> dict:
    """Exhaustive (instance x action) sweep through the engine."""
    task = _make_task(task_name,
                      _systems(task_name, n_sys, seed, n_range), backend)
    engine = AutotuneEngine(task, chunk=CHUNK, seed=seed)
    # Warm-up: compile the per-bucket executable outside the timed window.
    engine.solve_pairs([(0, 0)])
    warm = engine.n_solves
    t0 = time.perf_counter()
    engine.prefill_all()
    wall = time.perf_counter() - t0
    n = engine.n_solves - warm
    return {"n_solves": n, "engine_wall_s": wall,
            "solves_per_s": n / max(wall, 1e-9)}


def bench_serving(task_name: str, backend, tmp_root: str, n_req: int,
                  n_range, seed: int = 0) -> dict:
    """Submit -> drain roundtrip through the AutotuneServer."""
    train = _systems(task_name, 6, seed, n_range)
    task = _make_task(task_name, train, backend)
    reg, _, _ = PolicyRegistry.warm_start(
        os.path.join(tmp_root, f"{task_name}_{backend.name}"), task, W1,
        TrainConfig(episodes=2, seed=seed))
    srv = AutotuneServer(
        reg, _make_task(task_name, (), backend), W1,
        BatcherConfig(max_batch=CHUNK, max_wait_s=0.001,
                      bucket_step=BUCKET, min_bucket=BUCKET),
        OnlineConfig(), seed=seed)
    reqs = _systems(task_name, n_req, seed + 1, n_range)
    for s in reqs:                      # warm the serving executable
        srv.submit(s)
    srv.drain()
    t0 = time.perf_counter()
    for s in reqs:
        srv.submit(s)
    srv.drain()
    wall = time.perf_counter() - t0
    return {"n_req": n_req, "serving_wall_s": wall,
            "req_per_s": n_req / max(wall, 1e-9)}


def bench_lu_trisolve(pallas_backend, mode: str, full: bool) -> list:
    """solves/s of jitted lu_factor_auto + lu_solve, strict vs blocked.

    The blocked path (DESIGN.md §6.4) must beat the strict row loop on
    the jnp backend at n >= 256 — the headline number of the blocked
    factorization/substitution subsystem. Off-TPU the pallas side runs
    the *interpreter* (mode-labeled, correctness-priced): it is timed at
    one size only, for dispatch-overhead visibility, not kernel speed.
    """
    from functools import partial

    import jax.numpy as jnp

    from repro.solvers import (STRICT_ONLY, BlockingPolicy, lu_factor_auto,
                               lu_solve)

    @partial(jax.jit, static_argnames=("backend", "blocking"))
    def pipeline(A, b, fmt, backend, blocking):
        f = lu_factor_auto(A, fmt, backend=backend, blocking=blocking)
        return lu_solve(f.lu, f.perm, b, fmt, backend=backend,
                        blocking=blocking)

    from repro.precision import FORMAT_ID
    fmt = jnp.asarray(FORMAT_ID["fp32"], jnp.int32)
    rng = np.random.default_rng(0)
    jnp_ns = (128, 256, 512, 1024) if full else (128, 256, 512)
    pallas_ns = jnp_ns if mode == "compiled-tpu" else (256,)
    variants = [("strict", STRICT_ONLY), ("blocked", BlockingPolicy(min_n=1))]
    entries = []
    for backend, ns, reps in ((resolve_backend("jnp"), jnp_ns, 3),
                              (pallas_backend, pallas_ns, 2)):
        label = backend.name if backend.name != "pallas" else mode
        for n in ns:
            A = jnp.asarray(rng.standard_normal((n, n)) + np.eye(n) * n,
                            jnp.float64)
            b = jnp.asarray(rng.standard_normal(n), jnp.float64)
            A, b = backend.coerce(A, b)
            for vname, pol in variants:
                pipeline(A, b, fmt, backend, pol).block_until_ready()
                t0 = time.perf_counter()
                for _ in range(reps):
                    pipeline(A, b, fmt, backend, pol).block_until_ready()
                wall = (time.perf_counter() - t0) / reps
                entries.append({"n": n, "variant": vname,
                                "backend": backend.name, "mode": label,
                                "wall_s": wall,
                                "solves_per_s": 1.0 / max(wall, 1e-9)})
    return entries


def run(full: bool = False, recompute: bool = False) -> list:
    scale = {"n_sys": 12 if full else 6, "n_req": 32 if full else 16,
             "n_range": [32, 96] if full else [16, 44]}
    pallas, mode = _backend_under_test()
    cached = None if recompute else load_report("precision_backend_bench")
    # A cached report is only valid for the same scale AND the same
    # pallas execution mode: interpret-cpu numbers must not shadow a
    # compiled-TPU pass once the host gains TPU access. Reports from
    # before the lu_trisolve section exist are also recomputed.
    if (cached is not None and cached.get("scale") == scale
            and cached.get("pallas_mode") == mode
            and "lu_trisolve" in cached):
        return emit_rows(cached)
    import tempfile
    report = {"pallas_mode": mode, "scale": scale, "entries": []}
    n_range = tuple(scale["n_range"])
    with tempfile.TemporaryDirectory() as tmp:
        for task_name in ("gmres_ir", "cg_ir"):
            for backend in (resolve_backend("jnp"), pallas):
                label = backend.name if backend.name != "pallas" else mode
                eng = bench_engine(task_name, backend, scale["n_sys"],
                                   n_range)
                srv = bench_serving(task_name, backend, tmp,
                                    scale["n_req"], n_range)
                report["entries"].append(
                    {"task": task_name, "backend": backend.name,
                     "mode": label, **eng, **srv})
    report["lu_trisolve"] = bench_lu_trisolve(pallas, mode, full)
    save_report("precision_backend_bench", report)
    return emit_rows(report)


def emit_rows(report: dict) -> list:
    rows = []
    for e in report["entries"]:
        us = 1e6 * e["engine_wall_s"] / max(e["n_solves"], 1)
        derived = (f"solves_per_s={e['solves_per_s']:.2f};"
                   f"req_per_s={e['req_per_s']:.2f};mode={e['mode']}")
        rows.append(f"backend/{e['task']}/{e['backend']},{us:.0f},{derived}")
    for e in report.get("lu_trisolve", []):
        us = 1e6 * e["wall_s"]
        derived = (f"solves_per_s={e['solves_per_s']:.2f};"
                   f"mode={e['mode']}")
        rows.append(f"lu_trisolve/n{e['n']}/{e['variant']}/{e['backend']},"
                    f"{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    for r in run(full="--full" in sys.argv,
                 recompute="--recompute" in sys.argv):
        print(r)
