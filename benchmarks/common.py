"""Shared harness for the paper-table reproductions.

Scale presets: `default` is a reduced-but-faithful configuration sized for
this CPU host (same generators, same bandit, smaller n / fewer systems);
`--full` is the paper's exact §5.1 setup (100+100 systems, n in [100, 500],
100 episodes). Solve caches are shared across weight settings and the
penalty ablation — the environment is deterministic, so (system, action)
outcomes are reward-independent (DESIGN.md §3.5).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import (GMRESIREnv, RewardConfig, TrainConfig,
                        evaluate_fixed_action, evaluate_policy,
                        reduced_action_space, train_policy)
from repro.data import generate_dense_set, generate_sparse_set
from repro.solvers import IRConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# Paper weight settings (§5.1).
W1 = RewardConfig(w1=1.0, w2=0.1)
W2 = RewardConfig(w1=1.0, w2=1.0)
W1_NOPEN = dataclasses.replace(W1, use_penalty=False)
W2_NOPEN = dataclasses.replace(W2, use_penalty=False)


@dataclasses.dataclass(frozen=True)
class Scale:
    n_train: int
    n_test: int
    episodes: int
    n_range: tuple
    seed: int = 0


DEFAULT_SCALE = Scale(n_train=80, n_test=80, episodes=80, n_range=(100, 250))
FULL_SCALE = Scale(n_train=100, n_test=100, episodes=100, n_range=(100, 500))


def get_scale(full: bool) -> Scale:
    return FULL_SCALE if full else DEFAULT_SCALE


def make_datasets(kind: str, scale: Scale):
    rng = np.random.default_rng(scale.seed)
    if kind == "dense":
        train = generate_dense_set(scale.n_train, rng, scale.n_range)
        test = generate_dense_set(scale.n_test, rng, scale.n_range)
    else:
        train = generate_sparse_set(scale.n_train, rng, scale.n_range)
        test = generate_sparse_set(scale.n_test, rng, scale.n_range)
    return train, test


def run_setting(train_systems, test_systems, tau: float, weights: dict,
                scale: Scale, envs=None, space=None):
    """Train policies for each weight setting on a shared env; evaluate all
    on a shared test env + the FP64 fixed-action baseline.

    weights: {name: RewardConfig}. Returns (report dict, envs) where envs
    can be passed back in to reuse solve caches across calls (ablation).
    `space` defaults to the paper's reduced space; the fp8 grid passes
    the `SOLVER_LADDER_FP8`-derived space instead."""
    space = space if space is not None else reduced_action_space()
    if envs is None:
        env_train = GMRESIREnv(train_systems, space, IRConfig(tau=tau))
        env_test = GMRESIREnv(test_systems, space, IRConfig(tau=tau))
    else:
        env_train, env_test = envs
    report = {"tau": tau, "settings": {}}
    for name, rcfg in weights.items():
        t0 = time.time()
        policy, hist = train_policy(
            env_train, rcfg,
            TrainConfig(episodes=scale.episodes, seed=scale.seed))
        ev = evaluate_policy(policy, env_test, tau_base=tau)
        report["settings"][name] = {
            "table": ev["table"],
            "usage_per_range": ev["usage_per_range"],
            "usage_per_solve": ev["usage_per_solve"],
            "train_s": round(time.time() - t0, 1),
            "episode_reward_first5": [round(r, 2) for r in
                                      hist.episode_reward[:5]],
            "episode_reward_last5": [round(r, 2) for r in
                                     hist.episode_reward[-5:]],
            "episode_rpe_last5": [round(r, 2) for r in hist.episode_rpe[-5:]],
            "unique_solves": env_train.cache_size,
        }
    bl = evaluate_fixed_action(env_test, space.n_actions - 1, tau)
    report["fp64_baseline"] = {"table": bl["table"]}
    return report, (env_train, env_test)


def emit_csv_rows(bench: str, report: dict):
    """Benchmark-harness CSV contract: name,us_per_call,derived."""
    rows = []
    for setting, data in report.get("settings", {}).items():
        for rng_name, row in data["table"].items():
            derived = (f"xi={row['xi']:.3f};ferr={row['avg_ferr']:.2e};"
                       f"nbe={row['avg_nbe']:.2e};iter={row['avg_iter']:.2f};"
                       f"gmres={row['avg_gmres_iter']:.2f}")
            us = data["train_s"] * 1e6 / max(data["unique_solves"], 1)
            rows.append(f"{bench}/{setting}/{rng_name},{us:.0f},{derived}")
    for rng_name, row in report.get("fp64_baseline", {}).get("table",
                                                             {}).items():
        derived = (f"ferr={row['avg_ferr']:.2e};nbe={row['avg_nbe']:.2e};"
                   f"iter={row['avg_iter']:.2f};"
                   f"gmres={row['avg_gmres_iter']:.2f}")
        rows.append(f"{bench}/fp64_baseline/{rng_name},0,{derived}")
    return rows


def save_report(name: str, report: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    return path


def load_report(name: str):
    """Cached results (benchmark runs are deterministic per scale/seed;
    re-emitting from results/<name>.json avoids hour-scale recompute on this
    1-core host). Delete the JSON or pass --recompute to rerun."""
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def fix_table_types(report: dict) -> dict:
    """json round-trip turns table values into plain floats — ensure the
    emit_csv_rows contract (numeric fields) still holds."""
    return report
