"""Per-task autotuning throughput: solves/s through the shared engine.

Runs each registered `TunableTask` (GMRES-IR on dense randsvd, CG-IR on
sparse SPD) through the same `train_policy` loop and reports unique
solver rows per second plus training reward trajectory endpoints — the
cross-algorithm perf row set that `BENCH_results.json` accumulates.

CSV rows follow the `benchmarks/run.py` contract (name,us_per_call,
derived) and the full report lands in benchmarks/results/task_bench.json.

    PYTHONPATH=src python benchmarks/task_bench.py [--full] [--recompute]
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):      # script entry: repo root onto sys.path
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))))

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from benchmarks.common import W1, load_report, save_report
from repro.core import TrainConfig, reduced_action_space, train_policy
from repro.core.engine import AutotuneEngine
from repro.data import generate_dense_set, generate_sparse_set
from repro.solvers import CGConfig, IRConfig
from repro.tasks import CGIRTask, GMRESIRTask


def _make_task(name: str, n_train: int, n_range, bucket_step: int,
               seed: int):
    space = reduced_action_space()
    rng = np.random.default_rng(seed)
    if name == "gmres_ir":
        systems = generate_dense_set(n_train, rng, n_range)
        return GMRESIRTask(systems, space, IRConfig(tau=1e-6),
                           bucket_step=bucket_step, min_bucket=bucket_step)
    if name == "cg_ir":
        systems = generate_sparse_set(n_train, rng, n_range)
        return CGIRTask(systems, space, CGConfig(tau=1e-6),
                        bucket_step=bucket_step, min_bucket=bucket_step)
    raise ValueError(name)


def bench_task(name: str, n_train: int, n_range, episodes: int,
               bucket_step: int, chunk: int, seed: int) -> dict:
    task = _make_task(name, n_train, n_range, bucket_step, seed)
    engine = AutotuneEngine(task, chunk=chunk, seed=seed)
    # Warm-up: compile each bucket's executable outside the timed window.
    engine.solve_pairs([(i, task.action_space.n_actions - 1)
                        for i in range(len(task.instances))])
    warm_solves, warm_pad = engine.n_solves, engine.n_pad_solves
    t0 = time.perf_counter()
    policy, hist = train_policy(engine, W1,
                                TrainConfig(episodes=episodes, seed=seed))
    wall = time.perf_counter() - t0
    n_solves = engine.n_solves - warm_solves
    return {
        "task": name,
        "n_train": n_train,
        "episodes": episodes,
        "wall_s": wall,
        "n_solves": n_solves,
        "n_pad_solves": engine.n_pad_solves - warm_pad,
        "solves_per_s": n_solves / max(wall, 1e-9),
        "reward_first": hist.episode_reward[0],
        "reward_last": hist.episode_reward[-1],
        "unique_solves": engine.cache_size,
    }


def run(full: bool = False, recompute: bool = False,
        n_train: int = None, n_range: tuple = None,
        episodes: int = None, bucket_step: int = 64,
        chunk: int = 8, seed: int = 0) -> list:
    cached = None if recompute else load_report("task_bench")
    if cached is not None:
        return emit_rows(cached)
    n_train = n_train or (32 if full else 12)
    n_range = n_range or ((100, 250) if full else (32, 96))
    episodes = episodes or (40 if full else 10)
    report = {"tasks": [bench_task(name, n_train, n_range, episodes,
                                   bucket_step, chunk, seed)
                        for name in ("gmres_ir", "cg_ir")]}
    save_report("task_bench", report)
    return emit_rows(report)


def emit_rows(report: dict) -> list:
    rows = []
    for t in report["tasks"]:
        us = 1e6 * t["wall_s"] / max(t["n_solves"], 1)
        derived = (f"solves_per_s={t['solves_per_s']:.2f};"
                   f"reward_last={t['reward_last']:.2f};"
                   f"pad={t['n_pad_solves']}")
        rows.append(f"task/{t['task']},{us:.0f},{derived}")
    return rows


# ---------------------------------------------------------------------------
# Sharded section: solves/s vs data-axis width on a forced host-device
# mesh (DESIGN.md §7). Device count is fixed at jax import, so the sweep
# runs in a subprocess with XLA_FLAGS forcing 8 host devices. Host
# devices share this machine's cores — the numbers measure dispatch and
# partition overhead (plumbing evidence), NOT hardware speedup, and the
# report labels them `host-device-cpu` accordingly; the compiled
# TPU/pod pass is the standing roadmap item.
# ---------------------------------------------------------------------------

SHARDED_DEVICES = 8
SHARDED_WIDTHS = (1, 2, 4, 8)


def _run_sharded_child(n: int = 128, chunk: int = 32, repeats: int = 3,
                       seed: int = 0) -> dict:
    """Executed inside the forced-8-device subprocess."""
    import time

    import numpy as np

    from repro.core import (LocalExecutor, ShardedExecutor, pad_to_bucket,
                            reduced_action_space, solve_fixed_batch)
    from repro.data import generate_dense_set
    from repro.solvers import IRConfig

    space = reduced_action_space()
    rng = np.random.default_rng(seed)
    systems = generate_dense_set(chunk, rng, (n - 28, n))
    rows = [pad_to_bucket(s, n, n) for s in systems]
    acts = [space.actions[i % space.n_actions] for i in range(chunk)]
    cfg = IRConfig(tau=1e-6)
    A, b, x = ([r[i] for r in rows] for i in range(3))

    def bench(executor):
        solve_fixed_batch(A, b, x, acts, cfg, chunk,
                          executor=executor)        # compile + warm
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            solve_fixed_batch(A, b, x, acts, cfg, chunk, executor=executor)
            best = min(best, time.perf_counter() - t0)
        return best

    entries = []
    for w in SHARDED_WIDTHS:
        ex = ShardedExecutor(data=w)
        wall = bench(ex)
        entries.append({"data": w, "wall_s": wall,
                        "solves_per_s": chunk / wall,
                        "mesh_shape": ex.mesh_shape()})
    local_wall = bench(LocalExecutor())
    base = chunk / local_wall
    for e in entries:
        e["speedup_vs_local"] = e["solves_per_s"] / base
    jax_dev = __import__("jax").device_count()
    return {"label": "host-device-cpu",
            "note": ("forced host devices share one CPU; scaling shows "
                     "partition overhead, not hardware speedup"),
            "device_count": jax_dev, "n": n, "chunk": chunk,
            "local_solves_per_s": base, "entries": entries}


def run_sharded(full: bool = False, recompute: bool = False) -> list:
    cached = None if recompute else load_report("task_bench_sharded")
    if cached is None:
        import json
        import subprocess
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{SHARDED_DEVICES}")
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--sharded-child"],
            env=env, capture_output=True, text=True, timeout=1800)
        if out.returncode != 0:
            raise RuntimeError("sharded child failed:\n" + out.stderr[-3000:])
        cached = json.loads(out.stdout.splitlines()[-1])
        save_report("task_bench_sharded", cached)
    rows = []
    for e in cached["entries"]:
        us = 1e6 * e["wall_s"] / max(cached["chunk"], 1)
        derived = (f"solves_per_s={e['solves_per_s']:.2f};"
                   f"speedup_vs_local={e['speedup_vs_local']:.2f};"
                   f"label={cached['label']}")
        rows.append(f"task/sharded/d{e['data']},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        import json
        print(json.dumps(_run_sharded_child()))
    elif "--sharded" in sys.argv:
        for r in run_sharded(recompute="--recompute" in sys.argv):
            print(r)
    else:
        for r in run(full="--full" in sys.argv,
                     recompute="--recompute" in sys.argv):
            print(r)
