"""Deterministic stand-in for `hypothesis`, used only when the real package
is absent (it is declared in requirements.txt; some execution environments
cannot install it).

Implements exactly the API surface this test-suite uses — ``@given`` over
``st.integers`` / ``st.floats`` / ``st.sampled_from`` plus
``@settings(max_examples=..., deadline=...)`` — as a boundary-inclusive
deterministic sweep: every strategy first yields its edge cases, then
pseudo-random draws seeded from the test's qualified name, so failures
reproduce across runs. No shrinking, no database. ``tests/conftest.py``
registers this module as ``hypothesis`` only on ModuleNotFoundError.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import os
import sys
import types

import numpy as np

# Property sweeps are capped to bound suite runtime; the declared
# max_examples still scales the sweep below the cap.
_CAP = int(os.environ.get("REPRO_HYPOTHESIS_MAX_EXAMPLES", "60"))

_F64_MAX = np.finfo(np.float64).max


class _Strategy:
    def _boundaries(self):
        return []

    def _draw(self, rng):
        raise NotImplementedError

    def examples(self, rng, n: int):
        out = list(self._boundaries())[:n]
        while len(out) < n:
            out.append(self._draw(rng))
        return out


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 63) if min_value is None else int(min_value)
        self.hi = 2 ** 63 - 1 if max_value is None else int(max_value)

    def _boundaries(self):
        mid = (self.lo + self.hi) // 2
        return list(dict.fromkeys([self.lo, self.hi, mid]))

    def _draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value=None, max_value=None, allow_nan=None,
                 allow_infinity=None, width=64):
        self.lo, self.hi = min_value, max_value
        bounded = min_value is not None or max_value is not None
        self.allow_inf = (allow_infinity if allow_infinity is not None
                          else (allow_nan is not False and not bounded)
                          or (allow_nan is None and not bounded))
        if bounded:
            self.allow_inf = False

    def _boundaries(self):
        if self.lo is not None or self.hi is not None:
            lo = self.lo if self.lo is not None else -_F64_MAX
            hi = self.hi if self.hi is not None else _F64_MAX
            out = [lo, hi]
            if lo <= 0.0 <= hi:
                out.append(0.0)
            if lo <= 1.0 <= hi:
                out.append(1.0)
            out.append((lo + hi) / 2.0)
            return list(dict.fromkeys(out))
        out = [0.0, -0.0, 1.0, -1.0, 0.5, -0.5, 3.0, 1e-300, -1e-300,
               5e-324, -5e-324, 1e300, -1e300, _F64_MAX, -_F64_MAX,
               1.5e-5, 6.1e-5, 65504.0]
        if self.allow_inf:
            out += [np.inf, -np.inf]
        return out

    def _draw(self, rng):
        if self.lo is not None or self.hi is not None:
            lo = self.lo if self.lo is not None else -1e30
            hi = self.hi if self.hi is not None else 1e30
            if lo > 0 and hi / max(lo, 5e-324) > 1e3:
                # wide positive range: log-uniform
                return float(10.0 ** rng.uniform(np.log10(lo),
                                                 np.log10(hi)))
            return float(rng.uniform(lo, hi))
        sign = -1.0 if rng.random() < 0.5 else 1.0
        return float(sign * 10.0 ** rng.uniform(-300.0, 300.0)
                     * rng.uniform(1.0, 9.999))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def examples(self, rng, n: int):
        # Cycle so every element appears before any repeats.
        reps = (n + len(self.elements) - 1) // len(self.elements)
        pool = self.elements * reps
        return pool[:n]


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(int(getattr(wrapper, "_stub_max_examples", 20)), _CAP)
            seed = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:4],
                "big")
            cols = [s.examples(np.random.default_rng(seed + j), n)
                    for j, s in enumerate(strategies)]
            for vals in zip(*cols):
                fn(*args, *vals, **kwargs)
        wrapper._stub_max_examples = 20
        # Strategy-filled params must not look like pytest fixtures.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _Integers
strategies.floats = _Floats
strategies.sampled_from = _SampledFrom


def install():
    """Register this module as `hypothesis` (call only when absent)."""
    mod = sys.modules[__name__]
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
