"""Off-policy evaluation + deterministic trajectory replay (eval/).

Three layers, matching DESIGN.md §10:

  * synthetic logged streams with a *known* reward table, so the IPS /
    DM / DR estimators can be checked against ground truth (DR within
    its own bootstrap CI of the true on-policy value; IPS and DR agree
    on the incumbent-vs-candidate ranking);
  * bit-identical replay of a real server-produced trajectory segment
    through a fresh `AutotuneEngine` (`eval.replay`);
  * the rollout-controller OPE gate end to end: a degraded candidate
    whose snapshot meta carries healthy telemetry evidence — it would
    pass the meta-baseline telemetry gates — is refused a canary slice
    by `start_rollout`, visibly (decision trail JSONL, decision
    counter, registry meta annotation).
"""
import json

import numpy as np
import pytest

from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.core.task import coerce_task
from repro.core.engine import AutotuneEngine
from repro.data import generate_dense_set
from repro.eval import (CallableCandidate, EmpiricalRewardModel, OPEConfig,
                        SnapshotCandidate, as_candidate, behavior_propensity,
                        evaluate_policy, ope_gate, replay_records,
                        assert_replay_ok, steps_from_records)
from repro.obs import MetricsRegistry, Observability, TrajectoryLog
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           OPEGateRejected, PolicyRegistry, RolloutConfig,
                           ShadowServer)
from repro.solvers import IRConfig

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
BCFG = BatcherConfig(max_batch=4, max_wait_s=0.002, bucket_step=16,
                     min_bucket=16)


# ---------------------------------------------------------------------------
# Synthetic logged streams: estimators vs ground truth
# ---------------------------------------------------------------------------

K = 5          # arms
S = 6          # states
EPS = 0.3
# Known reward table R[s, a]: best arm differs by state, spread wide
# enough that policies are clearly separated.
R_TABLE = np.array([[float((s * K + a) % 7) - 3.0 + 2.0 * (a == s % K)
                     for a in range(K)] for s in range(S)])


def _behavior_action(s):
    """The greedy arm of the logging policy."""
    return (s + 1) % K


def _synthetic_records(n, seed, noise=0.05):
    """n logged ε-greedy decisions over R_TABLE with two buckets."""
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        s = int(rng.integers(S))
        explore = bool(rng.random() < EPS)
        a = int(rng.integers(K)) if explore else _behavior_action(s)
        r = float(R_TABLE[s, a] + noise * rng.standard_normal())
        recs.append({"features": [float(s)], "state": s, "action": a,
                     "eps": EPS, "explore": explore, "reward": r,
                     "bucket": 16 if s % 2 == 0 else 32,
                     "request_id": i, "task": "synthetic"})
    return recs


def _true_value(policy_fn, noise=0.0):
    """Exact on-policy value under the uniform state distribution."""
    return float(np.mean([R_TABLE[s, policy_fn(s)] for s in range(S)]))


def _candidate(policy_fn, name):
    return CallableCandidate(lambda feats, state: policy_fn(int(state)),
                             name=name)


@pytest.mark.fast
def test_behavior_propensity_contract():
    # explore=False: greedy arm, reachable through both branches.
    assert behavior_propensity(0.3, False, 5) == pytest.approx(
        0.7 + 0.3 / 5)
    # explore=True: the uniform branch.
    assert behavior_propensity(0.3, True, 5) == pytest.approx(0.3 / 5)
    # Greedy decisions under eps=0 are propensity 1 exactly.
    assert behavior_propensity(0.0, False, 5) == 1.0


@pytest.mark.fast
def test_steps_from_records_drops_malformed_rows():
    good = _synthetic_records(10, seed=0)
    bad = [
        {"event": "decision", "outcome": "hold"},          # trail event
        {**good[0], "action": K + 3},                      # out of range
        {**good[1], "reward": float("nan")},               # non-finite
        {**good[2], "eps": 1.5},                           # bad epsilon
        dict(good[3], **{"state": "not-an-int"}),          # uncoercible
    ]
    steps = steps_from_records(good + bad, n_actions=K)
    assert len(steps) == len(good)
    assert all(0 <= st.action < K for st in steps)


@pytest.mark.fast
def test_reward_model_pessimistic_floor():
    steps = steps_from_records(_synthetic_records(500, seed=1), K)
    model = EmpiricalRewardModel().fit(steps)
    worst = min(st.reward for st in steps)
    assert model.floor == worst
    # A (state, action) pair the log never contains scores the floor.
    assert not model.supported(10**6, 0)
    assert model.predict(10**6, 0) == worst
    # Supported pairs score their empirical mean, not the floor.
    st = steps[0]
    assert model.supported(st.state, st.action)
    assert model.predict(st.state, st.action) > worst


@pytest.mark.fast
def test_dr_estimate_covers_true_value_of_held_out_policy():
    """The acceptance bar: DR's estimate of a policy the log never
    served falls within its own bootstrap CI of the true value."""
    recs = _synthetic_records(4000, seed=2)

    def held_out(s):          # disagrees with the behavior greedy arm
        return (s + 2) % K

    ests = evaluate_policy(recs, _candidate(held_out, "held-out"),
                           n_actions=K,
                           cfg=OPEConfig(n_bootstrap=200, seed=0))
    truth = _true_value(held_out)
    dr = ests["dr"]
    assert dr.n == len(recs)
    assert dr.ci_lo <= truth <= dr.ci_hi
    # The point estimate itself lands close (noise is 0.05, n large).
    assert abs(dr.value - truth) < 0.5
    # IPS agrees within its (wider) interval too.
    assert ests["ips"].ci_lo <= truth <= ests["ips"].ci_hi
    # Per-bucket stratification covered both buckets.
    assert set(dr.per_bucket) == {"16", "32"}


@pytest.mark.fast
def test_ips_and_dr_agree_on_incumbent_vs_candidate_ranking():
    recs = _synthetic_records(4000, seed=3)
    incumbent = _candidate(_behavior_action, "incumbent")

    def bad(s):               # anti-optimal arm by construction
        return int(np.argmin(R_TABLE[s]))

    cfg = OPEConfig(n_bootstrap=50, seed=0)
    inc = evaluate_policy(recs, incumbent, n_actions=K, cfg=cfg)
    cand = evaluate_policy(recs, _candidate(bad, "bad"), n_actions=K,
                           cfg=cfg)
    # Ground truth ranking...
    assert _true_value(_behavior_action) > _true_value(bad)
    # ...reproduced by both estimators.
    assert inc["ips"].value > cand["ips"].value
    assert inc["dr"].value > cand["dr"].value


@pytest.mark.fast
def test_ess_and_support_diagnostics():
    recs = _synthetic_records(2000, seed=4)
    inc = evaluate_policy(recs, _candidate(_behavior_action, "inc"),
                          n_actions=K, cfg=OPEConfig(n_bootstrap=0))
    # The incumbent matches most logged actions: weights are dense and
    # DM support is near-total. ESS stays well below n even so — the
    # explore-coincides-with-greedy records carry the conservative
    # exploration propensity (eps/K), and their large weights dominate
    # the Σw² term. That haircut is the documented contract.
    assert inc["dr"].ess > 0.15 * len(recs)
    assert inc["dr"].support > 0.95

    def rare(s):              # only exploration ever logged this arm
        return (s + 3) % K

    off = evaluate_policy(recs, _candidate(rare, "rare"), n_actions=K,
                          cfg=OPEConfig(n_bootstrap=0))
    assert off["dr"].ess < inc["dr"].ess


@pytest.mark.fast
def test_ope_gate_verdicts():
    recs = _synthetic_records(3000, seed=5)
    cfg = OPEConfig(n_bootstrap=100, seed=0)
    incumbent = _candidate(_behavior_action, "incumbent")

    def bad(s):
        return int(np.argmin(R_TABLE[s]))

    # A clearly worse candidate is refused.
    rep = ope_gate(recs, incumbent, _candidate(bad, "bad"), n_actions=K,
                   margin=0.5, min_records=64, cfg=cfg)
    assert not rep.accept and rep.reason == "lcb_below_floor"
    assert rep.floor == pytest.approx(
        evaluate_policy(recs, incumbent, n_actions=K, cfg=cfg,
                        model=EmpiricalRewardModel().fit(
                            steps_from_records(recs, K)))["dr"].value
        - 0.5)
    # The incumbent itself (served as a candidate) clears its own floor.
    rep2 = ope_gate(recs, incumbent,
                    _candidate(_behavior_action, "clone"), n_actions=K,
                    margin=0.5, min_records=64, cfg=cfg)
    assert rep2.accept and rep2.reason == "cleared"
    # Degenerate inputs fail open, with the reason on record.
    rep3 = ope_gate(recs[:10], incumbent, _candidate(bad, "bad"),
                    n_actions=K, min_records=64, cfg=cfg)
    assert rep3.accept and rep3.reason == "insufficient_records"
    rep4 = ope_gate(recs, None, _candidate(bad, "bad"), n_actions=K,
                    cfg=cfg)
    assert rep4.accept and rep4.reason == "no_incumbent"
    # Reports serialize for the decision trail.
    ev = rep.to_event()
    assert ev["accept"] is False
    assert json.dumps(ev)     # JSONL-safe
    assert ev["candidate"]["dr"]["ci"][0] <= ev["candidate"]["dr"]["value"]


@pytest.mark.fast
def test_as_candidate_coercions():
    c = as_candidate(lambda f, s: 0)
    assert c.action_of(np.zeros(1), 3) == 0
    with pytest.raises(TypeError):
        as_candidate(object())
    with pytest.raises(ValueError):
        evaluate_policy(_synthetic_records(5, seed=0),
                        _candidate(_behavior_action, "x"), n_actions=None)


# ---------------------------------------------------------------------------
# Real server-produced segments: replay + snapshot candidates
# ---------------------------------------------------------------------------

def _requests(n, seed, n_range=(12, 28)):
    rng = np.random.default_rng(seed)
    return generate_dense_set(n, rng, n_range, log10_kappa_range=(3, 6))


@pytest.fixture(scope="module")
def reg_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("opereg") / "reg")
    rng = np.random.default_rng(7)
    train = generate_dense_set(8, rng, n_range=(12, 28),
                               log10_kappa_range=(3, 6))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=6))
    return root


def _serve_with_trajlog(reg_root, tmp_path, n=24, seed=5):
    """Serve a seeded stream through a trajectory-logging server;
    returns (server, log path, {request_id: instance})."""
    path = str(tmp_path / "traj.jsonl")
    obs = Observability(registry=MetricsRegistry(), trajectory_path=path)
    srv = AutotuneServer(PolicyRegistry(reg_root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=obs)
    reqs = _requests(n, seed=seed)
    instances = {}
    for system in reqs:
        instances[srv.submit(system)] = system
    srv.drain()
    return srv, path, instances


def test_replay_of_server_segment_is_bit_identical(reg_root, tmp_path):
    srv, path, instances = _serve_with_trajlog(reg_root, tmp_path)
    records = TrajectoryLog.read_complete(path, task=srv.task.name)
    assert len(records) == len(instances)

    # A fresh engine — new process state as far as the solve cache is
    # concerned — re-solves every logged (instance, action) pair.
    task = coerce_task(IR, bucket_step=16, min_bucket=16)
    task.action_space = SPACE
    engine = AutotuneEngine(task, W1, chunk=4, seed=99)
    report = assert_replay_ok(
        replay_records(engine, records, instances),
        min_replayed=len(records))
    assert report.n_replayed == len(records)
    assert report.n_skipped == 0
    assert report.ok
    # The replay went through batched ad-hoc solves, not per-record.
    assert engine.n_solves == len({(id(i), r["action"]) for r, i in
                                   ((rec, instances[int(rec["request_id"])])
                                    for rec in records)})


def test_replay_detects_a_corrupted_record(reg_root, tmp_path):
    srv, path, instances = _serve_with_trajlog(reg_root, tmp_path, n=8,
                                               seed=6)
    records = TrajectoryLog.read_complete(path, task=srv.task.name)
    records[0] = dict(records[0], reward=records[0]["reward"] + 1e-9)
    task = coerce_task(IR, bucket_step=16, min_bucket=16)
    task.action_space = SPACE
    engine = AutotuneEngine(task, W1, chunk=4)
    report = replay_records(engine, records, instances)
    assert not report.ok
    assert any(m.field == "reward" for m in report.mismatches)
    with pytest.raises(AssertionError):
        assert_replay_ok(report)
    # Unmapped records are skipped and counted, not failed.
    report2 = replay_records(engine, records[1:], {})
    assert report2.n_skipped == len(records) - 1
    with pytest.raises(AssertionError):
        assert_replay_ok(report2)       # nothing replayed => not verified


def test_snapshot_candidate_scores_real_log(reg_root, tmp_path):
    """`SnapshotCandidate` closes the loop: the registry's own snapshot
    scored on the server's own log, no synthetic pieces."""
    srv, path, instances = _serve_with_trajlog(reg_root, tmp_path, n=40,
                                               seed=8)
    records = TrajectoryLog.read_complete(path, task=srv.task.name)
    reg = PolicyRegistry(reg_root)
    cand = SnapshotCandidate.from_registry(reg, reg.current_version())
    assert cand.n_actions == SPACE.n_actions
    ests = evaluate_policy(records, cand,
                           cfg=OPEConfig(n_bootstrap=50, seed=0))
    dr = ests["dr"]
    assert dr.n == len(records)
    assert np.isfinite(dr.value)
    assert dr.ci_lo <= dr.value <= dr.ci_hi
    # The serving policy is (mostly) the snapshot's greedy policy, so
    # its logged support is substantial.
    assert dr.support > 0.5


# ---------------------------------------------------------------------------
# The OPE gate inside the rollout controller (e2e)
# ---------------------------------------------------------------------------

def _publish_degraded_with_healthy_meta(reg, telemetry=None):
    """Candidate pinned to the all-bf16 arm — but carrying healthy
    telemetry evidence in its meta, so the *telemetry* gates would see
    nothing wrong with it. Only off-policy evaluation of the Q-table
    itself can refuse it before it takes traffic."""
    pol = reg.load()
    pol.qtable.Q[:] = 0.0
    pol.qtable.Q[:, 0] = 1.0
    return reg.publish(pol, note="degraded with healthy-looking meta",
                       extra_meta=({"telemetry": telemetry}
                                   if telemetry else None))


def _healthy_telemetry(server):
    """Snapshot-meta-shaped telemetry evidence from a live server."""
    tel = server.telemetry
    return {"responses": tel.responses,
            "reward_ewma": tel.reward_ewma.value,
            "converged_frac": tel.converged_frac,
            "latency_s_per_bucket": tel.latency_percentiles_per_bucket()}


def _fork(reg_root, tmp_path):
    import shutil
    dst = str(tmp_path / "reg")
    shutil.copytree(reg_root, dst)
    return PolicyRegistry(dst)


def _ope_shadow(reg, tmp_path, margin, obs=False, min_records=40,
                tag=""):
    cfg = RolloutConfig(canary_frac=0.3, shadow=True,
                        decision_window=10**9, min_samples=10**9,
                        seed=0, ope_gate=True, ope_margin=margin,
                        ope_min_records=min_records, ope_bootstrap=50)
    if obs is False:
        obs = Observability(registry=MetricsRegistry(),
                            trajectory_path=str(tmp_path
                                                / f"traj{tag}.jsonl"))
    return ShadowServer(reg, IR, W1, BCFG, OnlineConfig(),
                        rollout_cfg=cfg, seed=0, obs=obs,
                        decision_log_path=str(tmp_path
                                              / f"decisions{tag}.jsonl"))


def test_ope_gate_refuses_degraded_candidate_before_canary(reg_root,
                                                           tmp_path):
    reg = _fork(reg_root, tmp_path)
    baseline = reg.current_version()
    shadow = _ope_shadow(reg, tmp_path, margin=0.5)
    # Serve enough traffic to populate the primary's trajectory log —
    # the evidence the gate scores candidates on.
    for system in _requests(60, seed=9):
        shadow.submit(system)
    shadow.drain()

    vbad = _publish_degraded_with_healthy_meta(
        reg, telemetry=_healthy_telemetry(shadow.primary))
    assert reg.meta(vbad).get("telemetry")      # telemetry gates green
    with pytest.raises(OPEGateRejected) as ei:
        shadow.start_rollout(vbad)
    report = ei.value.report
    assert not report.accept and report.reason == "lcb_below_floor"
    assert report.candidate["dr"].ci_lo < report.floor

    # Refused means *no traffic*: no promotion, no candidate, idle.
    assert shadow.phase == "idle"
    assert shadow.candidate is None
    assert reg.current_version() == baseline

    # The refusal is on the record everywhere it must be:
    # 1. controller decision history + counters,
    d = shadow.decisions[-1]
    assert d.outcome == "ope_reject" and d.responses == 0
    assert shadow.rollout_state()["decision_counts"]["ope_reject"] == 1
    # 2. repro_rollout_decisions_total{outcome="ope_reject"},
    fam = {k: c.value for k, c in
           shadow.obs.registry.counter(
               "repro_rollout_decisions_total",
               "Canary gate decisions, by outcome.",
               ("task", "outcome"))._children.items()}
    assert any(k[1] == "ope_reject" and v >= 1 for k, v in fam.items())
    # 3. the decision-trail JSONL,
    events = [json.loads(ln)
              for ln in open(str(tmp_path / "decisions.jsonl"))
              if ln.strip()]
    gate = [e for e in events if e.get("event") == "ope_gate"]
    assert gate and gate[-1]["outcome"] == "ope_reject"
    assert gate[-1]["candidate"] == vbad
    assert gate[-1]["reason"] == "lcb_below_floor"
    # 4. the candidate version's registry meta (the audit annotation).
    assert reg.meta(vbad)["ope_gate"]["accept"] is False

    # A healthy copy of the incumbent clears the same gate and starts
    # the canary normally (generous margin: clone == incumbent, the CI
    # halfwidth is the only separation).
    shadow2 = _ope_shadow(reg, tmp_path, margin=25.0, obs=shadow.obs,
                          tag="2")
    for system in _requests(60, seed=9):
        shadow2.submit(system)
    shadow2.drain()
    vgood = reg.publish(reg.load(), note="healthy copy")
    shadow2.start_rollout(vgood)
    assert shadow2.phase == "canary"
    assert reg.current_version() == vgood
    assert shadow2.decisions[-1].outcome == "ope_accept"
    assert reg.meta(vgood)["ope_gate"]["accept"] is True


def test_ope_gate_abstains_without_logged_evidence(reg_root, tmp_path):
    reg = _fork(reg_root, tmp_path)
    shadow = _ope_shadow(reg, tmp_path, margin=0.5)   # empty trajlog
    vbad = _publish_degraded_with_healthy_meta(reg)
    shadow.start_rollout(vbad)           # abstains: fail-open to canary
    assert shadow.phase == "canary"
    d = shadow.decisions[0]
    assert d.outcome == "ope_accept"
    assert d.evidence["reason"] == "insufficient_records"
    # The canary's own telemetry gates remain the rail in this regime —
    # exactly the pre-OPE behavior.
