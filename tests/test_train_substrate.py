"""Train/serve substrate tests: optimizer, quantization, pipeline,
checkpoint, grad sync, serve loop, integration (loss decreases)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.data.tokens import TokenPipeline
from repro.train import (AdamWConfig, LMPrecisionPolicy, QTensor,
                         TrainPrecisionController, TrainState,
                         TrainStepConfig, adamw_init, adamw_update,
                         cosine_with_warmup, dequantize_int8,
                         init_train_state, make_train_step, quantize_int8,
                         sync_leaf)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (1000,)) * 3.0
    q = quantize_int8(x, block=256)
    err = jnp.abs(dequantize_int8(q, block=256) - x)
    # absmax int8: error <= scale/127 per block
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-7
    assert q.codes.dtype == jnp.int8


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 500), st.floats(1e-6, 1e6))
def test_prop_int8_roundtrip(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q = quantize_int8(x, block=64)
    back = dequantize_int8(q, block=64)
    assert back.shape == x.shape
    assert float(jnp.max(jnp.abs(back - x))) <= scale * 0.2 + 1e-6


def test_int8_zero_block():
    x = jnp.zeros((300,))
    back = dequantize_int8(quantize_int8(x), 256)
    np.testing.assert_array_equal(np.asarray(back), 0)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.asarray([0.5])}


@pytest.mark.parametrize("quant", [False, True])
def test_adamw_minimizes_quadratic(quant):
    cfg = AdamWConfig(weight_decay=0.0, quantize_moments=quant,
                      quant_block=4)
    params = _quad_params()
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)  # d/dp p^2
        params, state, _ = adamw_update(params, grads, state, 0.05, cfg)
    total = sum(float(jnp.sum(jnp.abs(p))) for p in
                jax.tree_util.tree_leaves(params))
    assert total < 0.05


def test_adamw_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    params = _quad_params()
    state = adamw_init(params, cfg)
    big = jax.tree_util.tree_map(lambda p: p * 1e6, params)
    p2, _, stats = adamw_update(params, big, state, 0.01, cfg)
    assert float(stats["grad_norm"]) > 1e5
    delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(params)))
    assert delta < 0.1  # clipped step stays small


def test_quantized_moments_are_int8():
    cfg = AdamWConfig(quantize_moments=True, quant_block=4)
    state = adamw_init(_quad_params(), cfg)
    leaves = jax.tree_util.tree_leaves(
        state.m, is_leaf=lambda x: isinstance(x, QTensor))
    assert all(isinstance(q, QTensor) for q in leaves)


def test_cosine_schedule():
    lr0 = float(cosine_with_warmup(0, peak_lr=1.0, warmup=10, total=100))
    lr_peak = float(cosine_with_warmup(10, peak_lr=1.0, warmup=10,
                                       total=100))
    lr_end = float(cosine_with_warmup(100, peak_lr=1.0, warmup=10,
                                      total=100))
    assert lr0 == 0.0 and lr_peak == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, abs=0.01)


# ---------------------------------------------------------------------------
# Token pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    p1 = TokenPipeline(1000, 64, 4, seed=7)
    batches = [p1.next_batch() for _ in range(3)]
    p2 = TokenPipeline(1000, 64, 4, seed=7)
    p2.load_state_dict({"cursor": 2, "seed": 7, "shard": 0, "n_shards": 1})
    np.testing.assert_array_equal(p2.next_batch()["tokens"],
                                  batches[2]["tokens"])


def test_pipeline_shards_disjoint():
    a = TokenPipeline(1000, 32, 2, seed=1, shard=0, n_shards=2).next_batch()
    b = TokenPipeline(1000, 32, 2, seed=1, shard=1, n_shards=2).next_batch()
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_learnable_structure():
    p = TokenPipeline(1000, 64, 8, seed=0)
    t = p.next_batch()["tokens"]
    pos = np.arange(64) % 8 == 0
    pred = (np.roll(t, 1, axis=1)[:, pos] * 7 + 3) % 998 + 2
    np.testing.assert_array_equal(t[:, pos][:, 1:], pred[:, 1:])


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("granite-3-2b")
    tcfg = TrainStepConfig(opt=AdamWConfig(quantize_moments=True,
                                           quant_block=64))
    state = init_train_state(cfg, KEY, tcfg)
    path = save_checkpoint(str(tmp_path), 5, state,
                           {"pipeline": {"cursor": 3}})
    assert latest_step(str(tmp_path)) == 5
    restored, meta = restore_checkpoint(str(tmp_path), state)
    assert meta["step"] == 5 and meta["pipeline"]["cursor"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_pointer_survives_multiple_saves(tmp_path):
    state = {"x": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 2, {"x": jnp.ones((3,)) * 2})
    restored, meta = restore_checkpoint(str(tmp_path), state)
    assert meta["step"] == 2
    assert float(restored["x"][0]) == 2.0


# ---------------------------------------------------------------------------
# Grad sync (cross-pod compression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,tol", [("fp32", 1e-7), ("bf16", 0.02),
                                      ("int8", 0.05)])
def test_sync_leaf_modes(mode, tol):
    devs = jax.local_devices()
    n = min(len(devs), 1) or 1
    # Single-device: emulate a 1-pod mean via shard_map over a size-1 axis.
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    g = jax.random.normal(KEY, (64,))
    if hasattr(jax, "shard_map"):          # newer jax; kwarg name varies
        try:
            f = jax.shard_map(lambda x: sync_leaf(x, mode),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False)
        except TypeError:                  # top-level but pre-rename
            f = jax.shard_map(lambda x: sync_leaf(x, mode),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              check_rep=False)
    else:                                  # jax 0.4.x
        from jax.experimental.shard_map import shard_map
        f = shard_map(lambda x: sync_leaf(x, mode),
                      mesh=mesh, in_specs=P(), out_specs=P(),
                      check_rep=False)
    out = f(g)
    assert float(jnp.max(jnp.abs(out - g))) <= tol * float(
        jnp.max(jnp.abs(g))) + 1e-6


# ---------------------------------------------------------------------------
# Integration: a tiny model trains; controller reacts to divergence
# ---------------------------------------------------------------------------

def test_train_loss_decreases_smoke():
    cfg = get_smoke("granite-3-2b")
    tcfg = TrainStepConfig(peak_lr=3e-3, warmup=5, total_steps=60)
    state = init_train_state(cfg, KEY, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = TokenPipeline(cfg.vocab_size, 64, 8, seed=0)
    losses = []
    for _ in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_precision_controller_learns_to_avoid_divergence():
    ctrl = TrainPrecisionController(total_decisions=200, interval=1,
                                    seed=0)
    rng = np.random.default_rng(0)
    # Synthetic world: e4m3 matmuls diverge, bf16/fp32 fine.
    for _ in range(200):
        feats = ctrl.features(1.0, 1e-3)
        pol = ctrl.act(feats)
        lowest = int(ctrl.space.ladder_idx[ctrl._pending[1]][0])
        if lowest == 0:  # e4m3 compute
            ctrl.observe(2.0, 2.5 + rng.random(), diverged=rng.random() < .5)
        else:
            ctrl.observe(2.0, 1.98)
    feats = ctrl.features(1.0, 1e-3)
    pol = ctrl.act(feats)
    a = ctrl._pending[1]
    assert int(ctrl.space.ladder_idx[a][0]) != 0  # avoids e4m3 compute


def test_lm_policy_emulated_matmul_precision():
    from repro.precision import FORMAT_ID
    pol = LMPrecisionPolicy(jnp.asarray(FORMAT_ID["e4m3"], jnp.int32))
    x = jax.random.normal(KEY, (16, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.float32)
    lo = pol.matmul(x, w, "ffn")
    hi = jnp.dot(x, w)
    rel = float(jnp.max(jnp.abs(lo - hi)) / jnp.max(jnp.abs(hi)))
    assert 1e-3 < rel < 0.5
