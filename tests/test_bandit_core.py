"""Bandit core tests: action space, discretizer, rewards, Q-learning."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Discretizer, QTable, RewardConfig, W1, W2,
                        accuracy_term, epsilon_schedule, full_action_space,
                        is_monotone, penalty_term, precision_term,
                        reduced_action_space, reduced_size, reward)
from repro.precision import FORMAT_ID, FORMATS
from repro.solvers.ir import CONVERGED, FAILED


# ---------------------------------------------------------------------------
# Action space (Eq. 11-12)
# ---------------------------------------------------------------------------

def test_reduced_action_space_count_paper():
    """256 -> 35 (~86% reduction), paper §3.2."""
    space = reduced_action_space()
    assert space.n_actions == 35 == reduced_size(4, 4)
    assert full_action_space().n_actions == 256
    assert 1 - 35 / 256 == pytest.approx(0.863, abs=0.01)


@pytest.mark.parametrize("m,k", [(2, 2), (3, 4), (4, 4), (7, 3)])
def test_reduced_size_formula(m, k):
    from math import comb
    assert reduced_size(m, k) == comb(m + k - 1, k)


def test_actions_monotone_and_ordered():
    space = reduced_action_space()
    for row in space.ladder_idx:
        assert is_monotone(row)
    # significand bits non-decreasing within each action (Eq. 11)
    for a in range(space.n_actions):
        bits = space.significand_bits(a)
        assert list(bits) == sorted(bits)
    # first action = all-lowest, last = all-highest
    assert space.names(0) == ("bf16",) * 4
    assert space.names(space.n_actions - 1) == ("fp64",) * 4


def test_subsample_keeps_extremes():
    space = reduced_action_space(subsample=9, seed=1)
    assert space.n_actions == 9
    assert space.names(0) == ("bf16",) * 4
    assert space.names(space.n_actions - 1) == ("fp64",) * 4


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=5), st.integers(min_value=2,
                                                          max_value=4))
def test_prop_reduced_space_is_exactly_monotone_subset(m, k):
    ladder = ["e5m2", "e4m3", "bf16", "fp16", "tf32"][:m]
    red = reduced_action_space(tuple(ladder), k)
    full = full_action_space(tuple(ladder), k)
    mono = [row for row in full.ladder_idx.tolist() if is_monotone(row)]
    assert sorted(mono) == sorted(red.ladder_idx.tolist())
    assert red.n_actions == reduced_size(m, k)


# ---------------------------------------------------------------------------
# Discretizer (Eq. 19-20)
# ---------------------------------------------------------------------------

def test_discretizer_bins_and_clipping():
    feats = np.array([[0.0, 0.0], [9.0, 4.0]])
    d = Discretizer.fit(feats, (10, 5))
    assert d.n_states == 50
    assert d(np.array([0.0, 0.0])) == 0
    assert d(np.array([9.0, 4.0])) == 49       # max clips into last bin
    assert d(np.array([100.0, 100.0])) == 49   # out-of-range clips
    assert d(np.array([-100.0, -100.0])) == 0
    # Eq. 20 indexing: s = bin1 * n2 + bin2
    assert d(np.array([0.0, 4.0])) == 4
    assert d(np.array([9.0, 0.0])) == 45


def test_discretizer_roundtrip_serialization():
    feats = np.random.default_rng(0).uniform(0, 10, (50, 2))
    d = Discretizer.fit(feats, (10, 10))
    d2 = Discretizer.from_dict(d.to_dict())
    x = np.random.default_rng(1).uniform(-5, 15, (100, 2))
    np.testing.assert_array_equal(d(x), d2(x))


@settings(max_examples=100, deadline=None)
@given(st.floats(-1e6, 1e6), st.floats(-1e6, 1e6))
def test_prop_discretizer_in_bounds(a, b):
    feats = np.array([[0.0, -3.0], [5.0, 7.0]])
    d = Discretizer.fit(feats, (7, 3))
    s = d(np.array([a, b]))
    assert 0 <= s < d.n_states


# ---------------------------------------------------------------------------
# Rewards (Eq. 21-25)
# ---------------------------------------------------------------------------

def test_precision_term_prefers_low_precision_and_damps_with_kappa():
    bf = np.full(4, FORMAT_ID["bf16"])
    f64 = np.full(4, FORMAT_ID["fp64"])
    assert precision_term(bf, 10.0) > precision_term(f64, 10.0)
    assert precision_term(bf, 10.0) > precision_term(bf, 1e8)
    # Eq. 22 exact value: 4 * 53/(8 * (1+1)) at kappa=10
    assert precision_term(bf, 10.0) == pytest.approx(4 * 53 / (8 * 2))
    assert precision_term(f64, 1.0) == pytest.approx(4.0)


def test_accuracy_term_shape():
    cfg = RewardConfig()
    good = accuracy_term(1e-14, 1e-17, cfg)
    bad = accuracy_term(1.0, 1e-3, cfg)
    awful = accuracy_term(1e9, 1e5, cfg)
    assert good > bad > awful
    # theta-capped below (Eq. 24): worst case is -2*C1*theta
    assert awful == pytest.approx(-2 * cfg.C1 * cfg.theta)
    # eps-floored above: best case is -2*C1*log10(eps)
    assert good <= -2 * cfg.C1 * np.log10(cfg.eps) + 1e-9


def test_penalty_term():
    assert penalty_term(1) == 0.0
    assert penalty_term(8) == 3.0
    assert penalty_term(0) == 0.0


def test_reward_composition_and_failure():
    act = np.full(4, FORMAT_ID["fp32"])
    r = reward(1e-10, 1e-12, 4, CONVERGED, act, 100.0, W1)
    expected = (W1.w2 * precision_term(act, 100.0)
                + W1.w1 * accuracy_term(1e-10, 1e-12, W1)
                - W1.w3 * penalty_term(4))
    assert r == pytest.approx(expected)
    assert reward(1e-10, 1e-12, 4, FAILED, act, 100.0, W1) == W1.fail_reward
    # no-penalty ablation (Table 6)
    cfg = RewardConfig(w1=1.0, w2=1.0, use_penalty=False)
    r_np = reward(1e-10, 1e-12, 1024, CONVERGED, act, 100.0, cfg)
    r_p = reward(1e-10, 1e-12, 1024, CONVERGED, act, 100.0, W2)
    assert r_np > r_p


def test_w2_more_aggressive_than_w1():
    """W2 weights precision savings 10x more (paper §5.1)."""
    bf = np.full(4, FORMAT_ID["bf16"])
    f64 = np.full(4, FORMAT_ID["fp64"])
    # A slightly-lossy bf16 run vs a perfect fp64 run at low kappa:
    r_bf_w1 = reward(1e-7, 1e-8, 8, CONVERGED, bf, 10.0, W1)
    r_64_w1 = reward(1e-14, 1e-16, 2, CONVERGED, f64, 10.0, W1)
    r_bf_w2 = reward(1e-7, 1e-8, 8, CONVERGED, bf, 10.0, W2)
    r_64_w2 = reward(1e-14, 1e-16, 2, CONVERGED, f64, 10.0, W2)
    assert r_64_w1 > r_bf_w1          # W1: accuracy wins
    assert (r_bf_w2 - r_64_w2) > (r_bf_w1 - r_64_w1)  # W2 shifts toward low


# ---------------------------------------------------------------------------
# Q-table learning (Eq. 5-6, 13)
# ---------------------------------------------------------------------------

def test_epsilon_schedule():
    assert epsilon_schedule(0, 100, 0.02) == 1.0
    assert epsilon_schedule(50, 100, 0.02) == 0.5
    assert epsilon_schedule(99, 100, 0.02) == pytest.approx(0.02, abs=0.009)
    assert epsilon_schedule(1000, 100, 0.02) == 0.02


def test_q_update_converges_to_mean_reward():
    qt = QTable(1, 1, alpha=None)  # 1/N schedule => running mean
    rng = np.random.default_rng(0)
    rewards = rng.normal(3.0, 1.0, 2000)
    for r in rewards:
        qt.update(0, 0, r)
    assert qt.Q[0, 0] == pytest.approx(np.mean(rewards))
    assert qt.N[0, 0] == 2000


def test_q_update_constant_alpha():
    qt = QTable(2, 3, alpha=0.5)
    rpe = qt.update(1, 2, 10.0)
    assert rpe == 10.0
    assert qt.Q[1, 2] == 5.0
    qt.update(1, 2, 10.0)
    assert qt.Q[1, 2] == 7.5


def test_greedy_ties_break_to_highest_precision():
    qt = QTable(2, 5, alpha=0.5)
    assert qt.greedy(0) == 4          # unvisited row -> last (safest) action
    qt.update(0, 1, 3.0)
    assert qt.greedy(0) == 1
    qt.update(0, 3, 3.0)              # equal Q after one 0.5-step? 1.5 each
    assert qt.Q[0, 1] == qt.Q[0, 3]
    assert qt.greedy(0) == 3          # tie -> higher index


def test_zeroed_qtable_greedy_pins_to_all_fp64_arm():
    """Regression pin for the all-zero-Q tie break on the real reduced
    space: `greedy` resolves full-row ties toward the HIGHEST action
    index, which Eq. 11's ordering makes the all-fp64 (safest) arm —
    never the all-bf16 arm at index 0. Rollout/OPE test fixtures that
    want a *degraded* candidate rely on this being stable: zeroing Q
    alone degrades nothing, so they must pin ``Q[:, 0] = 1``.
    """
    space = reduced_action_space()
    qt = QTable(6, space.n_actions, alpha=0.5, seed=0)
    assert np.all(qt.Q == 0.0)
    for s in range(qt.n_states):
        a = qt.greedy(s)
        assert a == space.n_actions - 1
        assert space.names(a) == ("fp64",) * 4
    assert space.names(0) == ("bf16",) * 4     # the degraded-fixture arm
    # And the tie break is by index order, not by Q magnitude noise:
    # raising any single arm wins that arm exactly.
    qt.Q[2, 7] = 1e-9
    assert qt.greedy(2) == 7


def test_eps_greedy_distribution():
    qt = QTable(1, 4, alpha=0.5, seed=0)
    qt.update(0, 2, 5.0)
    picks = np.array([qt.select(0, 0.5) for _ in range(4000)])
    frac_greedy = np.mean(picks == 2)
    # P(greedy) = 1 - eps + eps/|A| = 0.625
    assert abs(frac_greedy - 0.625) < 0.03


def test_qtable_save_load(tmp_path):
    qt = QTable(4, 7, alpha=0.5, seed=3)
    qt.update(2, 5, 1.5)
    p = str(tmp_path / "q.npz")
    qt.save(p)
    qt2 = QTable.load(p)
    np.testing.assert_array_equal(qt.Q, qt2.Q)
    np.testing.assert_array_equal(qt.N, qt2.N)
    assert qt2.alpha == 0.5


# ---------------------------------------------------------------------------
# fp8-extended action space (SOLVER_LADDER_FP8)
# ---------------------------------------------------------------------------

def test_fp8_reduced_action_space():
    from repro.core import fp8_reduced_action_space
    from repro.precision import SOLVER_LADDER_FP8
    space = fp8_reduced_action_space()
    assert tuple(space.ladder) == tuple(SOLVER_LADDER_FP8)
    assert space.n_actions == reduced_size(6, 4) == 126
    # Eq. 11 ordering holds across the fp8 rungs too.
    for a in range(space.n_actions):
        bits = space.significand_bits(a)
        assert list(bits) == sorted(bits)
    assert space.names(0) == ("e5m2",) * 4            # cheapest extreme
    assert space.names(space.n_actions - 1) == ("fp64",) * 4
    # fp8 ids resolve to the saturating formats (what makes u_f = fp8
    # fail soft on overflow instead of poisoning the LU with infs).
    assert FORMATS["e4m3"].saturate and FORMATS["e5m2"].saturate
    assert space.actions[0][0] == FORMAT_ID["e5m2"]


def test_fp8_subsample_keeps_extremes():
    from repro.core import fp8_reduced_action_space
    space = fp8_reduced_action_space(subsample=40, seed=0)
    assert space.n_actions == 40
    assert space.names(0) == ("e5m2",) * 4
    assert space.names(space.n_actions - 1) == ("fp64",) * 4


def test_fp8_actions_solve_end_to_end():
    """An all-e4m3 factorization action must run through GMRES-IR
    without recompiling or crashing — saturation keeps the factors
    finite, and failure (if any) flows through the status path."""
    import jax.numpy as jnp
    from repro.core import fp8_reduced_action_space
    from repro.data.matrices import randsvd_dense
    from repro.solvers import IRConfig, gmres_ir
    space = fp8_reduced_action_space()
    s = randsvd_dense(12, 10.0, np.random.default_rng(0))
    # action 0 = all-e5m2, plus a mixed arm with fp8 factorization only.
    mixed = np.asarray([FORMAT_ID["e4m3"], FORMAT_ID["fp32"],
                        FORMAT_ID["fp32"], FORMAT_ID["fp64"]], np.int32)
    for act in (space.actions[0], mixed):
        st = gmres_ir(jnp.asarray(s.A), jnp.asarray(s.b),
                      jnp.asarray(s.x_true), jnp.asarray(act, jnp.int32),
                      IRConfig(tau=1e-6, i_max=4, m_max=12))
        assert int(st.status) in (CONVERGED, 1, 2, FAILED)
        assert np.isfinite(float(st.res_norm)) or int(st.status) == FAILED
