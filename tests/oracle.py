"""Exact-arithmetic (Fraction-based) round-to-format oracle for tests.

This is the ground truth for repro.precision.chop and kernels/chop: correct
single-rounding RNE with gradual underflow, independent of any float
arithmetic. (XLA's native f64->bf16 casts double-round through f32 and flush
target subnormals, so they are NOT a valid oracle.)
"""
import math
from fractions import Fraction

import numpy as np


def chop_oracle(v: float, t: int, emin: int, emax: int, xmax: float,
                saturate: bool) -> float:
    if not np.isfinite(v) or v == 0:
        return float(v)
    fx = Fraction(float(v))
    e = math.floor(math.log2(abs(float(v))))
    # log2 can misround at boundaries; fix up exactly.
    while abs(fx) >= Fraction(2) ** (e + 1):
        e += 1
    while abs(fx) < Fraction(2) ** e:
        e -= 1
    q = max(e, emin) - (t - 1)
    scaled = fx / (Fraction(2) ** q)
    fl = math.floor(scaled)
    r = scaled - fl
    if r > Fraction(1, 2):
        n = fl + 1
    elif r < Fraction(1, 2):
        n = fl
    else:  # tie -> even
        n = fl if fl % 2 == 0 else fl + 1
    y = float(Fraction(n) * Fraction(2) ** q)
    if abs(y) > xmax:
        return math.copysign(float(xmax) if saturate else math.inf, v)
    if y == 0.0:
        return math.copysign(0.0, v)
    return y


def chop_oracle_array(x: np.ndarray, fmt) -> np.ndarray:
    """Vectorized oracle for a FloatFormat; returns same dtype as x."""
    out = np.array([chop_oracle(float(v), fmt.t, fmt.emin, fmt.emax,
                                fmt.xmax, fmt.saturate)
                    for v in np.asarray(x, dtype=np.float64).ravel()])
    return out.reshape(np.shape(x)).astype(np.asarray(x).dtype)
