"""Online autotuning service: batcher flush semantics, continual learning,
drift detection, registry versioning, and the end-to-end acceptance path
(warm start -> stream -> online updates -> benchmark report)."""
import json
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Discretizer, GMRESIREnv, QTable, TrainConfig, W1,
                        pad_to_bucket, reduced_action_space)
from repro.core.policy import PrecisionPolicy
from repro.data import generate_dense_set, generate_sparse_set
from repro.data.matrices import randsvd_dense
from repro.service import (AutotuneServer, BatcherConfig, DriftDetector,
                           EpsilonController, MicroBatcher, OnlineConfig,
                           OnlineLearner, PolicyRegistry)
from repro.solvers import IRConfig, gmres_ir

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:          # for `import benchmarks.*`
    sys.path.insert(0, ROOT)

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _systems(n_sys, rng, n_range=(8, 14)):
    return [randsvd_dense(int(rng.integers(*n_range)), 100.0, rng)
            for _ in range(n_sys)]


def _direct_record(system, action_row, bucket_step, min_bucket,
                   ir_cfg=IR):
    A, b, x = pad_to_bucket(system, bucket_step, min_bucket)
    return gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x),
                    jnp.asarray(action_row, jnp.int32), ir_cfg)


def _assert_matches_direct(rec, system, action_row, bucket_step,
                           min_bucket):
    st = _direct_record(system, action_row, bucket_step, min_bucket)
    assert rec.n_outer == int(st.n_outer)
    assert rec.n_gmres == int(st.n_gmres)
    assert rec.status == int(st.status)
    for got, want in ((rec.ferr, float(st.ferr)), (rec.nbe, float(st.nbe))):
        if np.isfinite(want):
            assert got == pytest.approx(want, rel=1e-9, abs=1e-300)
        else:
            assert not np.isfinite(got)


# ---------------------------------------------------------------------------
# Micro-batcher flush semantics
# ---------------------------------------------------------------------------

def test_batcher_flushes_full_batch_without_waiting():
    clock = FakeClock()
    mb = MicroBatcher(IR, BatcherConfig(max_batch=3, max_wait_s=10.0,
                                        bucket_step=16, min_bucket=16),
                      clock)
    rng = np.random.default_rng(0)
    systems = _systems(3, rng)
    ids = [mb.submit(s, SPACE.actions[-1])[0] for s in systems]
    assert mb.pending == 3
    out = mb.pump()                     # zero time elapsed: full batch goes
    assert len(out) == 1
    assert out[0].req_ids == ids
    assert out[0].n_rows == 3           # fixed compiled shape == max_batch
    assert mb.pending == 0
    for rec, s in zip(out[0].records, systems):
        _assert_matches_direct(rec, s, SPACE.actions[-1], 16, 16)


def test_batcher_partial_batch_waits_for_deadline():
    clock = FakeClock()
    mb = MicroBatcher(IR, BatcherConfig(max_batch=4, max_wait_s=0.5,
                                        bucket_step=16, min_bucket=16),
                      clock)
    rng = np.random.default_rng(1)
    systems = _systems(2, rng)
    ids = [mb.submit(s, SPACE.actions[0])[0] for s in systems]
    assert mb.pump() == []              # under max_batch, deadline not hit
    clock.advance(0.49)
    assert mb.pump() == []              # still inside the wait window
    clock.advance(0.02)                 # oldest entry passes max_wait_s
    out = mb.pump()
    assert len(out) == 1 and out[0].req_ids == ids
    assert len(out[0].records) == 2     # pad rows dropped from results
    assert out[0].n_rows == 4           # but the solve ran at full shape
    assert mb.pending == 0


def test_batcher_buckets_are_independent():
    clock = FakeClock()
    mb = MicroBatcher(IR, BatcherConfig(max_batch=2, max_wait_s=5.0,
                                        bucket_step=16, min_bucket=16),
                      clock)
    rng = np.random.default_rng(2)
    small = _systems(2, rng, n_range=(8, 14))       # bucket 16
    big = _systems(1, rng, n_range=(20, 28))        # bucket 32
    for s in small:
        mb.submit(s, SPACE.actions[-1])
    mb.submit(big[0], SPACE.actions[-1])
    out = mb.pump()                     # only the full small bucket flushes
    assert len(out) == 1 and out[0].bucket == 16
    assert mb.pending == 1
    out = mb.flush_all()                # force the straggler
    assert len(out) == 1 and out[0].bucket == 32
    assert mb.pending == 0


# ---------------------------------------------------------------------------
# Online learning: epsilon control + drift
# ---------------------------------------------------------------------------

def test_epsilon_controller_anneals_and_boosts():
    cfg = OnlineConfig(eps0=0.2, eps_min=0.02, eps_boost=0.5,
                       decay_updates=10)
    ec = EpsilonController(cfg)
    assert ec.value == pytest.approx(0.2)
    for _ in range(10):
        ec.step()
    assert ec.value == pytest.approx(0.02)
    ec.boost()
    assert ec.value == pytest.approx(0.5)
    for _ in range(5):
        ec.step()
    assert 0.02 < ec.value < 0.5        # re-annealing from the boost level


def test_online_update_matches_manual_q_update():
    qt = QTable(4, 3, alpha=0.5, seed=0)
    learner = OnlineLearner(qt, OnlineConfig(alpha=0.5))
    upd = learner.update(2, 1, 10.0)
    assert upd.rpe == pytest.approx(10.0)          # Q was 0
    assert qt.Q[2, 1] == pytest.approx(5.0)        # 0 + 0.5 * rpe
    assert qt.N[2, 1] == 1
    upd = learner.update(2, 1, 10.0)
    assert upd.rpe == pytest.approx(5.0)
    assert qt.Q[2, 1] == pytest.approx(7.5)


def test_drift_triggers_reexploration_once_per_regime():
    cfg = OnlineConfig(warmup_updates=5, cooldown_updates=8,
                       eps0=0.05, eps_min=0.02, eps_boost=0.5,
                       decay_updates=1000, alpha=0.5,
                       drift_ratio=2.0, drift_margin=0.25)
    qt = QTable(1, 1, alpha=0.5, seed=0)
    learner = OnlineLearner(qt, cfg)
    # Stable regime: reward 1.0; Q converges, |RPE| -> small.
    drifts = [learner.update(0, 0, 1.0).drift for _ in range(30)]
    assert not any(drifts)
    eps_before = learner.epsilon.value
    # Regime change: reward jumps far from Q's prediction.
    triggered = []
    for _ in range(10):
        triggered.append(learner.update(0, 0, -20.0).drift)
    assert any(triggered), "drift never triggered on a regime change"
    # Exactly one trigger inside the cooldown window.
    assert sum(triggered) == 1
    assert learner.epsilon.value > eps_before
    assert learner.epsilon.value >= 0.4            # boosted toward eps_boost


def test_drift_ignores_exploration_and_first_visits():
    cfg = OnlineConfig(warmup_updates=2, cooldown_updates=2,
                       drift_ratio=2.0, drift_margin=0.25, alpha=0.5)
    qt = QTable(8, 2, alpha=0.5, seed=0)
    learner = OnlineLearner(qt, cfg)
    for i in range(20):
        learner.update(0, 0, 1.0)
    n_before = learner.drift._updates
    # Exploratory updates never feed the detector...
    upd = learner.update(0, 0, -50.0, explore=True)
    assert not upd.drift and learner.drift._updates == n_before
    # ...nor do first visits to a fresh state (RPE vs an empty Q row).
    upd = learner.update(5, 1, -50.0)
    assert not upd.drift and learner.drift._updates == n_before


# ---------------------------------------------------------------------------
# Registry: versioning, atomic promote, rollback
# ---------------------------------------------------------------------------

def _tiny_policy(seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(20, 2))
    disc = Discretizer.fit(feats, (4, 4))
    qt = QTable(disc.n_states, SPACE.n_actions, 0.5, seed)
    qt.Q[:] = rng.normal(size=qt.Q.shape)
    qt.N[:] = rng.integers(0, 3, size=qt.N.shape)
    return PrecisionPolicy(SPACE, disc, qt)


def test_registry_promote_rollback_roundtrip(tmp_path):
    reg = PolicyRegistry(str(tmp_path / "reg"))
    assert reg.current_version() is None
    p1 = _tiny_policy(1)
    v1 = reg.publish(p1, note="first")
    assert reg.current_version() is None           # publish != promote
    reg.promote(v1)
    assert reg.current_version() == v1

    p2 = _tiny_policy(2)
    v2 = reg.publish(p2, note="second")
    reg.promote(v2)
    assert reg.current_version() == v2
    assert reg.versions() == [v1, v2]

    # Round-trip: the promoted snapshot loads back bit-identically.
    loaded = reg.load()
    assert np.array_equal(loaded.qtable.Q, p2.qtable.Q)
    assert np.array_equal(loaded.qtable.N, p2.qtable.N)
    assert np.array_equal(loaded.discretizer.mins, p2.discretizer.mins)

    # Rollback re-promotes v1; a fresh registry handle agrees (disk truth).
    assert reg.rollback() == v1
    assert PolicyRegistry(str(tmp_path / "reg")).current_version() == v1
    assert np.array_equal(reg.load().qtable.Q, p1.qtable.Q)
    assert reg.meta(v1)["note"] == "first"


def test_registry_consecutive_rollbacks_walk_back(tmp_path):
    reg = PolicyRegistry(str(tmp_path / "reg"))
    versions = [reg.publish(_tiny_policy(i)) for i in range(3)]
    for v in versions:
        reg.promote(v)
    v1, v2, v3 = versions
    assert reg.rollback() == v2          # v3 bad -> back to v2
    assert reg.rollback() == v1          # v2 also bad -> back to v1, not v3
    with pytest.raises(RuntimeError):
        reg.rollback()                   # nothing before v1


def test_registry_rollback_with_single_entry_history(tmp_path):
    reg = PolicyRegistry(str(tmp_path / "reg"))
    v1 = reg.publish(_tiny_policy(1))
    reg.promote(v1)
    with pytest.raises(RuntimeError):
        reg.rollback()                   # no prior version exists
    # The failed rollback left the registry untouched.
    assert reg.current_version() == v1
    assert reg.history() == [v1]


def test_registry_promote_unknown_version_is_atomic(tmp_path):
    reg = PolicyRegistry(str(tmp_path / "reg"))
    v1 = reg.publish(_tiny_policy(1))
    reg.promote(v1)
    with pytest.raises(ValueError):
        reg.promote("v9999")
    assert reg.current_version() == v1   # CURRENT did not move
    assert reg.history() == [v1]         # no phantom HISTORY entry


def test_registry_concurrent_publish_and_promote(tmp_path):
    from concurrent.futures import ThreadPoolExecutor

    reg = PolicyRegistry(str(tmp_path / "reg"))
    pols = {i: _tiny_policy(i) for i in range(8)}
    with ThreadPoolExecutor(max_workers=8) as ex:
        out = list(ex.map(
            lambda i: reg.publish(pols[i], note=f"w{i}"), range(8)))
    # Every publisher got a distinct version directory (the atomic mkdir
    # claim), and each snapshot is intact and loadable.
    assert len(set(out)) == 8
    assert reg.versions() == sorted(out)
    for i, v in enumerate(out):
        assert np.array_equal(reg.load(v).qtable.Q, pols[i].qtable.Q)
        assert reg.meta(v)["note"] == f"w{i}"
    # Concurrent promotes: CURRENT ends on one of the contenders and
    # never a torn value (atomic os.replace under the registry lock).
    with ThreadPoolExecutor(max_workers=2) as ex:
        list(ex.map(reg.promote, out[:2]))
    assert reg.current_version() in out[:2]
    assert set(reg.history()) == set(out[:2])


def test_server_bounds_unclaimed_responses(tmp_path):
    from repro.obs import MetricsRegistry, Observability

    rng = np.random.default_rng(3)
    train = generate_dense_set(4, rng, n_range=(16, 16),
                               log10_kappa_range=(1, 4))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    reg, _, _ = PolicyRegistry.warm_start(
        str(tmp_path / "reg"), env, W1, TrainConfig(episodes=1))
    obs = Observability(registry=MetricsRegistry())
    srv = AutotuneServer(
        reg, IR, W1,
        BatcherConfig(max_batch=4, max_wait_s=0.005, bucket_step=16,
                      min_bucket=16),
        OnlineConfig(), max_retained_responses=2, obs=obs)
    reqs = generate_dense_set(6, rng, n_range=(16, 16),
                              log10_kappa_range=(1, 4))
    ids = [srv.submit(s) for s in reqs]       # single bucket: FIFO order
    srv.drain()
    # A consumer that never polls cannot leak: only the newest 2
    # unclaimed responses are retained, the overflow was evicted (and
    # counted), and poll() keeps answering for what is retained.
    assert srv.responses_evicted == 4
    assert all(srv.poll(i) is None for i in ids[:4])
    assert all(srv.poll(i) is not None for i in ids[4:])
    fam = obs.registry.counter("repro_server_responses_evicted_total",
                               "", ("task",))
    assert sum(c.value for _, c in fam.samples()) == 4


def test_qtable_save_load_without_npz_suffix(tmp_path):
    qt = QTable(3, 2, alpha=None, seed=5)
    qt.update(1, 0, 4.0)
    path = str(tmp_path / "qtab")           # no .npz suffix
    qt.save(path)
    back = QTable.load(path)
    assert np.array_equal(back.Q, qt.Q)
    assert np.array_equal(back.N, qt.N)
    assert back.alpha is None


# ---------------------------------------------------------------------------
# End-to-end acceptance: warm start -> stream -> verify -> benchmark
# ---------------------------------------------------------------------------

def test_end_to_end_service(tmp_path):
    rng = np.random.default_rng(42)
    bucket_step = 16
    train = generate_dense_set(12, rng, n_range=(12, 40),
                               log10_kappa_range=(1, 6))
    env = GMRESIREnv(train, SPACE, IR, chunk=8, bucket_step=bucket_step)
    reg, version, snap = PolicyRegistry.warm_start(
        str(tmp_path / "reg"), env, W1, TrainConfig(episodes=4))
    assert version == "v0001" and reg.current_version() == "v0001"
    q0 = snap.qtable.Q.copy()

    srv = AutotuneServer(
        reg, IR, W1,
        BatcherConfig(max_batch=4, max_wait_s=0.005,
                      bucket_step=bucket_step, min_bucket=bucket_step),
        OnlineConfig())
    completed = []
    srv.on_response = completed.append

    # >= 64 mixed-size, mixed-kind requests.
    requests = (generate_dense_set(48, rng, n_range=(12, 40),
                                   log10_kappa_range=(1, 8))
                + generate_sparse_set(16, rng, n_range=(12, 40)))
    rng.shuffle(requests)
    ids = [srv.submit(s) for s in requests]
    srv.drain()
    assert srv.pending == 0
    responses = {i: srv.poll(i) for i in ids}
    assert all(r is not None for r in responses.values())
    assert len(responses) == 64 and len(completed) == 64

    # (a) every response matches a direct gmres_ir solve of the same
    # (padded system, action).
    for i, s in zip(ids, requests):
        r = responses[i]
        _assert_matches_direct(r.record, s, SPACE.actions[r.action],
                               bucket_step, bucket_step)
        assert r.policy_version == "v0001"

    # (b) the served Q-table learned online; the snapshot did not move.
    assert not np.array_equal(srv.live.qtable.Q, q0)
    assert np.array_equal(reg.load("v0001").qtable.Q, q0)

    # Online updates == sequential oracle replay in completion order.
    oracle = QTable(snap.qtable.n_states, snap.qtable.n_actions,
                    OnlineConfig().alpha, seed=123)
    oracle.Q = q0.copy()
    oracle.N = snap.qtable.N.copy()
    for r in completed:
        oracle.update(r.state, r.action, r.reward)
    assert np.array_equal(oracle.Q, srv.live.qtable.Q)
    assert np.array_equal(oracle.N, srv.live.qtable.N)

    # Telemetry saw the whole stream.
    tel = srv.telemetry.snapshot()
    assert tel["responses"] == 64 and tel["updates"] == 64
    assert tel["solver_batches"] >= 64 // 4
    assert tel["latency_s"]["p99"] >= tel["latency_s"]["p50"] >= 0

    # Snapshotting the adapted policy bumps the registry.
    v2 = srv.snapshot()
    assert reg.current_version() == v2 == "v0002"
    assert np.array_equal(reg.load().qtable.Q, srv.live.qtable.Q)


def test_service_bench_emits_json_report(tmp_path, monkeypatch):
    import benchmarks.common as bc
    import benchmarks.service_bench as sb
    monkeypatch.setattr(bc, "RESULTS_DIR", str(tmp_path))
    rows = sb.run(recompute=True, n_requests=10, n_range=(12, 28),
                  batches=(2,), episodes=3, n_train=6, bucket_step=16)
    assert rows and rows[0].startswith("service/b2,")
    report_path = tmp_path / "service_bench.json"
    assert report_path.exists()
    with open(report_path) as f:
        report = json.load(f)
    (setting,) = report["settings"]
    assert setting["max_batch"] == 2
    assert setting["n_requests"] == 10
    assert setting["rps"] > 0
    assert {"p50", "p90", "p99"} <= set(setting["latency_s"])
    assert all(v >= 0 for v in setting["latency_s"].values())
    # Metrics-on vs metrics-off arm (the fail-open layer's overhead).
    ov = report["obs_overhead"]
    assert ov["max_batch"] == 2
    assert ov["rps_on"] > 0 and ov["rps_off"] > 0
    assert ov["overhead_pct"] == pytest.approx(
        100.0 * (1.0 - ov["rps_on"] / ov["rps_off"]))
    # Trajectory-log fsync-price arm (DESIGN.md §11.1).
    ts = report["trajlog_sync"]
    assert set(ts["rps"]) == {"none", "rotate", "always"}
    assert all(v > 0 for v in ts["rps"].values())
    assert ts["fsync_overhead_pct"] == pytest.approx(
        100.0 * (1.0 - ts["rps"]["always"] / ts["rps"]["none"]))
    assert any(r.startswith("service/trajlog_sync_b2,") for r in rows)
    # HTTP front-door arm: the same trace fire-and-polled over the wire.
    hf = report["http_front_door"]
    assert hf["max_batch"] == 2
    assert hf["n_requests"] == 10
    assert hf["rps"] > 0 and hf["rps_inproc"] > 0
    assert {"p50", "p90", "p99"} <= set(hf["latency_s"])
    assert any(r.startswith("service/http_b2,") for r in rows)
