"""Flash attention kernel vs oracle: kinds x shapes x GQA groups."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_op, flash_ref

RNG = np.random.default_rng(5)


def _qkv(b, sq, hq, hkv, d, sk=None):
    sk = sk or sq
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)).astype(np.float32))
    return q, k, v


def _ref(q, k, v, **kw):
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], d)
    o = flash_ref(qf, kf, vf, groups=hq // hkv, **kw)
    return o.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)


CASES = [
    dict(kind="attn"),
    dict(kind="local", window=64),
    dict(kind="local", window=100),
    dict(kind="chunked", chunk=128),
    dict(kind="attn", softcap=50.0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("shape", [(2, 256, 4, 2, 64), (1, 384, 8, 8, 32)])
def test_flash_matches_ref(case, shape):
    b, s, hq, hkv, d = shape
    q, k, v = _qkv(b, s, hq, hkv, d)
    got = np.asarray(flash_attention_op(q, k, v, bq=128, bk=128,
                                        interpret=True, **case))
    want = np.asarray(_ref(q, k, v, **case))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_block_size_sweep():
    q, k, v = _qkv(1, 256, 2, 1, 32)
    want = np.asarray(_ref(q, k, v, kind="attn"))
    for bq, bk in [(64, 64), (128, 64), (256, 128), (64, 256)]:
        got = np.asarray(flash_attention_op(q, k, v, bq=bq, bk=bk,
                                            interpret=True))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention():
    """Cross-check against the model's einsum attention path."""
    from repro.configs import get_smoke
    from repro.models.attention import _sdpa, attn_mask
    cfg = get_smoke("gemma2-9b")
    b, s, d = 2, 128, cfg.head_dim
    q, k, v = _qkv(b, s, cfg.n_heads, cfg.n_kv_heads, d)
    pos = jnp.arange(s)
    mask = attn_mask(pos, pos, "local", cfg.window, 0)[None]
    want = np.asarray(_sdpa(q, k, v, mask, 1.0 / np.sqrt(d),
                            cfg.attn_softcap))
    got = np.asarray(flash_attention_op(
        q, k, v, kind="local", window=cfg.window, softcap=cfg.attn_softcap,
        bq=64, bk=64, interpret=True))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_flash_bf16_io():
    q, k, v = _qkv(1, 128, 2, 2, 64)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = np.asarray(flash_attention_op(qb, kb, vb, interpret=True,
                                        bq=64, bk=64)).astype(np.float32)
    want = np.asarray(_ref(qb.astype(jnp.float32), kb.astype(jnp.float32),
                           vb.astype(jnp.float32), kind="attn"))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
