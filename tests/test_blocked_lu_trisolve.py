"""Blocked factorization/substitution hot path (DESIGN.md §6.2, §6.4).

Covers the blocked-LU + blocked-trisolve subsystem:
  * property: blocked panel-pivoted factors solve the same systems as
    the strict factors (residual-level agreement across all format ids);
  * bit-exactness of the trisolve kernel vs its jnp oracle — padded and
    unpadded, single and batched, lower and upper;
  * bit-exactness of the pinned-contract chopped GEMM
    (`backend.chop_matmul`) across backends, padded and batched;
  * the internal identity padding of `lu_factor_blocked` at sizes that
    are not a block multiple (the old `assert n % block == 0` is gone);
  * the documented double-rounding division semantics of `solve_upper`
    (`chop(chop(y - s) / safe)`), pinned so backends cannot drift;
  * size-threshold dispatch: `lu_factor_auto` / triangular solves take
    the blocked path at `blocking.min_n` and the strict path below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmatmul import qgemm_op, qgemm_ref
from repro.kernels.trisolve import trisolve_op, trisolve_ref
from repro.precision import (FORMAT_ID, FORMAT_LIST, JnpBackend,
                             PallasBackend)
from repro.precision.chop import chop
from repro.solvers import (BlockingPolicy, STRICT_ONLY, lu_factor,
                           lu_factor_auto, lu_factor_blocked, lu_solve,
                           solve_unit_lower, solve_upper)

RNG = np.random.default_rng(77)
FP64 = FORMAT_ID["fp64"]
FP32 = FORMAT_ID["fp32"]
BF16 = FORMAT_ID["bf16"]

ORACLE = JnpBackend(carrier_dtype="float32")
PALLAS = PallasBackend(interpret=True, chop_min_elems=256)

ALL_FMT_IDS = list(range(len(FORMAT_LIST)))


def rand_system(n, kappa=100.0, rng=RNG):
    q1, _ = np.linalg.qr(rng.standard_normal((n, n)))
    q2, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.ones(n)
    s[-1] = 1.0 / kappa
    A = (q1 * s) @ q2.T
    x = rng.standard_normal(n)
    return A, A @ x, x


def tri_factors(n, rng=RNG, scale=4.0):
    """A combined-LU-layout matrix with a well-conditioned triangle."""
    M = rng.standard_normal((n, n))
    M[np.arange(n), np.arange(n)] = scale + rng.uniform(1, 2, n)
    return M


# ---------------------------------------------------------------------------
# Blocked LU: padding, correctness, strict agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(40, 16), (100, 64), (96, 32)])
def test_blocked_lu_pads_non_multiple_sizes(n, block):
    """Regression for the old `assert n % block == 0`: every size takes
    the blocked path via internal identity padding."""
    A, b, x = rand_system(n, kappa=10.0)
    f = lu_factor_blocked(jnp.asarray(A), FP64, block=block)
    assert not bool(f.fail)
    assert f.lu.shape == (n, n) and f.perm.shape == (n,)
    got = np.asarray(lu_solve(f.lu, f.perm, jnp.asarray(b), FP64))
    np.testing.assert_allclose(got, np.linalg.solve(A, b),
                               rtol=0, atol=1e-9)


@pytest.mark.parametrize("fid", ALL_FMT_IDS)
def test_blocked_factors_solve_same_systems(fid):
    """Property: blocked panel-pivoted factors are as good a solver as
    the strict factors, for every format id (residual-level agreement;
    the factorizations themselves legitimately differ bitwise)."""
    A, b, x = rand_system(48, kappa=30.0,
                          rng=np.random.default_rng(100 + fid))
    fs = lu_factor(jnp.asarray(A), fid)
    fb = lu_factor_blocked(jnp.asarray(A), fid, block=16)
    assert bool(fs.fail) == bool(fb.fail)
    if bool(fs.fail):       # fp8 overflow etc.: both paths must agree
        return
    norm = np.abs(A).sum(axis=1).max()

    def resid(f):
        sol = np.asarray(lu_solve(f.lu, f.perm, jnp.asarray(b), fid))
        if not np.all(np.isfinite(sol)):
            return np.inf
        return np.max(np.abs(b - A @ sol)) / (
            norm * np.max(np.abs(sol)) + np.max(np.abs(b)))

    rs, rb = resid(fs), resid(fb)
    # Same error floor up to a modest constant (both are backward-stable
    # eliminations at the same precision).
    assert np.isfinite(rb)
    assert rb <= 50 * rs + 1e-14, (rs, rb)


@pytest.mark.parametrize("n", [17, 64])
def test_lu_factor_auto_dispatch(n):
    """Below min_n: bitwise the strict factorization; above: the blocked
    one. The dispatch is by static shape only."""
    A, _, _ = rand_system(n, kappa=10.0)
    pol = BlockingPolicy(min_n=32, lu_block=16)
    auto = lu_factor_auto(jnp.asarray(A), FP32, blocking=pol)
    if n < 32:
        want = lu_factor(jnp.asarray(A), FP32)
    else:
        want = lu_factor_blocked(jnp.asarray(A), FP32, block=16)
    np.testing.assert_array_equal(np.asarray(auto.lu), np.asarray(want.lu))
    np.testing.assert_array_equal(np.asarray(auto.perm),
                                  np.asarray(want.perm))


def test_blocked_lu_bitexact_across_backends():
    """Shared trace + bit-exact dispatched ops (chop, pinned-contract
    chop_matmul) => identical factor bits on jnp and pallas-interpret."""
    for fid in (FP32, BF16, FORMAT_ID["fp16"]):
        A, _, _ = rand_system(48, kappa=20.0,
                              rng=np.random.default_rng(fid))
        fj = lu_factor_blocked(ORACLE.coerce(jnp.asarray(A)), fid,
                               block=16, backend=ORACLE)
        fp = lu_factor_blocked(PALLAS.coerce(jnp.asarray(A)), fid,
                               block=16, backend=PALLAS)
        np.testing.assert_array_equal(np.asarray(fj.lu), np.asarray(fp.lu),
                                      err_msg=f"fmt {fid}")
        np.testing.assert_array_equal(np.asarray(fj.perm),
                                      np.asarray(fp.perm))
        assert bool(fj.fail) == bool(fp.fail)


# ---------------------------------------------------------------------------
# Trisolve kernel vs jnp oracle: bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lower", [True, False], ids=["lower", "upper"])
@pytest.mark.parametrize("n,block", [(64, 16), (40, 16), (50, 32)],
                         ids=["unpadded", "padded", "padded-wide"])
@pytest.mark.parametrize("fid", [FP32, BF16, FORMAT_ID["e4m3"]])
def test_trisolve_kernel_matches_oracle(fid, n, block, lower):
    rng = np.random.default_rng(10 * n + fid)
    Lu = jnp.asarray(tri_factors(n, rng), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = trisolve_op(Lu, b, fid, lower=lower, block=block)
    want = trisolve_ref(Lu, b, fid, lower=lower, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("lower", [True, False], ids=["lower", "upper"])
def test_trisolve_kernel_matches_oracle_batched(lower):
    rng = np.random.default_rng(5)
    Lus = jnp.asarray(np.stack([tri_factors(40, rng) for _ in range(3)]),
                      jnp.float32)
    bs = jnp.asarray(rng.standard_normal((3, 40)), jnp.float32)
    got = jax.vmap(lambda L, b: trisolve_op(L, b, BF16, lower=lower,
                                            block=16))(Lus, bs)
    want = jax.vmap(lambda L, b: trisolve_ref(L, b, BF16, lower=lower,
                                              block=16))(Lus, bs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ... and batched == single (row-independent solves).
    for i in range(3):
        single = trisolve_op(Lus[i], bs[i], BF16, lower=lower, block=16)
        np.testing.assert_array_equal(np.asarray(single),
                                      np.asarray(got)[i])


def test_trisolve_matches_strict_solution_fp64():
    """Blocked substitution solves the same triangular systems as the
    strict row loop (residual-level; roundings differ by design)."""
    import scipy.linalg as sla
    rng = np.random.default_rng(3)
    n = 96
    Lu = tri_factors(n, rng, scale=8.0)
    b = rng.standard_normal(n)
    y = np.asarray(trisolve_ref(jnp.asarray(Lu), jnp.asarray(b), FP64,
                                lower=True, block=32))
    L = np.tril(Lu, -1) + np.eye(n)
    np.testing.assert_allclose(y, sla.solve_triangular(L, b, lower=True),
                               rtol=1e-12)
    x = np.asarray(trisolve_ref(jnp.asarray(Lu), jnp.asarray(b), FP64,
                                lower=False, block=32))
    np.testing.assert_allclose(x, sla.solve_triangular(np.triu(Lu), b),
                               rtol=1e-9)


def test_triangular_solvers_dispatch_to_blocked():
    """solve_unit_lower / solve_upper route through chop_trisolve at and
    above min_n, and stay strict below (bitwise check on both sides)."""
    rng = np.random.default_rng(8)
    n = 48
    Lu = jnp.asarray(tri_factors(n, rng), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    pol = BlockingPolicy(min_n=48, trisolve_block=16)
    got = solve_unit_lower(Lu, b, BF16, backend=ORACLE, blocking=pol)
    want = trisolve_ref(Lu, b, BF16, lower=True, block=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Below the threshold the strict row loop answers.
    below = BlockingPolicy(min_n=49, trisolve_block=16)
    strict = solve_unit_lower(Lu, b, BF16, backend=ORACLE, blocking=below)
    plain = solve_unit_lower(Lu, b, BF16, backend=ORACLE,
                             blocking=STRICT_ONLY)
    np.testing.assert_array_equal(np.asarray(strict), np.asarray(plain))


# ---------------------------------------------------------------------------
# Pinned-contract chopped GEMM (backend.chop_matmul)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(32, 16, 32), (40, 17, 23), (64, 64, 64)],
                         ids=["small", "ragged", "square"])
@pytest.mark.parametrize("fid", [FP32, BF16, FORMAT_ID["fp16"]])
def test_chop_matmul_bitexact_across_backends(fid, shape):
    M, K, N = shape
    rng = np.random.default_rng(M + K + fid)
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    got = PALLAS.chop_matmul(a, b, fid)
    want = ORACLE.chop_matmul(a, b, fid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # The oracle follows the documented formula: lane-padded K, one
    # carrier dot, output rounding.
    np.testing.assert_array_equal(np.asarray(want),
                                  np.asarray(qgemm_ref(a, b, fid)))


def test_chop_matmul_bitexact_batched():
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((3, 48, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 16, 48)), jnp.float32)
    got = jax.vmap(lambda x, y: qgemm_op(x, y, BF16))(a, b)
    want = jax.vmap(lambda x, y: qgemm_ref(x, y, BF16))(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# solve_upper division semantics: double rounding is intentional
# ---------------------------------------------------------------------------

def test_solve_upper_double_rounding_pinned():
    """The division path stores the numerator (one rounding) before the
    quotient (second rounding): chop(chop(y - s) / safe). Find inputs
    where single and double rounding differ, then pin the solver to the
    double-rounded value on both the strict and blocked paths."""
    rng = np.random.default_rng(17)
    # 1x1 upper systems: solve_upper reduces to the division semantics.
    vals = rng.uniform(1.0, 2.0, 4096)
    divs = rng.uniform(1.0, 2.0, 4096)
    y = jnp.asarray(vals)
    d = jnp.asarray(divs)
    double = chop(chop(y, BF16) / d, BF16)   # b chopped at entry, s = 0
    single = chop(y / d, BF16)
    diff = np.nonzero(np.asarray(double) != np.asarray(single))[0]
    assert diff.size > 0, "need a discriminating case"
    i = int(diff[0])
    Lu = jnp.asarray([[float(divs[i])]])
    got = solve_upper(Lu, jnp.asarray([float(vals[i])]), BF16)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(double[i]).reshape(1))
    # Blocked path: same double rounding inside the diagonal block.
    n = 32
    Lu_n = jnp.asarray(np.diag(divs[:n]) +
                       np.triu(rng.standard_normal((n, n)) * 0.1, 1),
                       jnp.float32)
    b_n = jnp.asarray(vals[:n], jnp.float32)
    blocked = trisolve_ref(Lu_n, b_n, BF16, lower=False, block=16)
    # Last row has no off-diagonal sum: exactly the division semantics.
    want_last = chop(chop(b_n[-1:], BF16) / Lu_n[-1, -1], BF16)
    np.testing.assert_array_equal(np.asarray(blocked)[-1:],
                                  np.asarray(want_last))


# ---------------------------------------------------------------------------
# Blocked-LU panel-width autotune (solvers/block_autotune)
# ---------------------------------------------------------------------------

def test_panel_autotune_picks_measured_candidate():
    from repro.solvers import BlockingPolicy, tuned_blocking
    from repro.solvers.block_autotune import sweep_lu_block
    base = BlockingPolicy(min_n=32, lu_block=16, trisolve_block=16)
    times = sweep_lu_block(64, candidates=(16, 32), trisolve_block=16,
                           repeats=1)
    assert set(times) == {16, 32}
    assert all(t > 0 for t in times.values())
    pol = tuned_blocking(64, base=base, candidates=(16, 32))
    assert pol.lu_block in (16, 32)
    assert pol.min_n == base.min_n and pol.trisolve_block == 16
    # Cached: the second lookup returns the identical committed policy.
    assert tuned_blocking(64, base=base, candidates=(16, 32)) is pol


def test_panel_autotune_skips_below_threshold_and_disabled():
    from repro.solvers import BlockingPolicy, STRICT_ONLY, tuned_blocking
    base = BlockingPolicy(min_n=256)
    assert tuned_blocking(64, base=base) == base        # strict path: no sweep
    assert tuned_blocking(512, base=STRICT_ONLY) == STRICT_ONLY


def test_task_opt_in_tunes_per_bucket():
    from repro.core import reduced_action_space
    from repro.data.matrices import randsvd_dense
    from repro.solvers import BlockingPolicy, IRConfig
    from repro.tasks import GMRESIRTask
    base = BlockingPolicy(min_n=32, lu_block=16, trisolve_block=16)
    cfg = IRConfig(tau=1e-6, i_max=3, m_max=8, blocking=base)
    space = reduced_action_space()
    systems = [randsvd_dense(30, 10.0, np.random.default_rng(3))]
    task = GMRESIRTask(systems, space, cfg, bucket_step=32, min_bucket=32,
                       tune_blocking=True)
    tuned = task.solver_cfg_for(cfg, 32)
    assert tuned.blocking.lu_block in (16, 32)          # <= bucket candidates
    # One tuned config per (cfg type, bucket): the jit key stays stable.
    assert task.solver_cfg_for(cfg, 32) is tuned
    # The tuned config actually drives the solve path.
    recs = task.solve_rows([task.prepare(systems[0])],
                           [space.actions[-1]], 2)
    assert len(recs) == 1 and recs[0].ok
