"""Stochastic-rounding chop: unbiasedness + representability properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.precision import FORMAT_ID, FORMATS, chop, chop_stochastic

KEY = jax.random.PRNGKey(0)
X = jnp.asarray(np.random.default_rng(0).standard_normal(8000)
                .astype(np.float32))


@pytest.mark.parametrize("fmt", ["bf16", "e4m3", "fp16", "tf32"])
def test_sr_outputs_are_representable(fmt):
    y = chop_stochastic(X, FORMAT_ID[fmt], KEY)
    np.testing.assert_array_equal(np.asarray(chop(y, FORMAT_ID[fmt])),
                                  np.asarray(y))


def test_sr_unbiased_vs_rne():
    """Averaged SR reconstructs x ~sqrt(n)x better than a single rounding."""
    fid = FORMAT_ID["bf16"]
    keys = jax.random.split(KEY, 64)
    f = jax.jit(lambda k: chop_stochastic(X, fid, k))
    mean = np.mean([np.asarray(f(k)) for k in keys], axis=0)
    bias_sr = np.abs(mean - np.asarray(X)).mean()
    err_rn = np.abs(np.asarray(chop(X, fid)) - np.asarray(X)).mean()
    assert bias_sr < 0.35 * err_rn


def test_sr_rounds_to_neighbors():
    """SR result is one of the two enclosing representable values."""
    fid = FORMAT_ID["bf16"]
    y = np.asarray(chop_stochastic(X, fid, KEY))
    lo = np.asarray(chop(X - np.abs(X) * 4e-3, fid))
    hi = np.asarray(chop(X + np.abs(X) * 4e-3, fid))
    assert np.all((y >= np.minimum(lo, hi)) & (y <= np.maximum(lo, hi)))


def test_sr_specials_and_exact_passthrough():
    sp = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, 2.0],
                     jnp.float32)
    y = np.asarray(chop_stochastic(sp, FORMAT_ID["e4m3"], KEY))
    assert y[0] == 0 and np.signbit(y[1]) and np.isposinf(y[2])
    assert np.isneginf(y[3]) and np.isnan(y[4])
    assert y[5] == 1.0 and y[6] == 2.0          # exactly representable
