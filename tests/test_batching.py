"""core/batching edge cases: bucket boundaries, non-dividing steps, and
single-row flushes bit-matching the unbatched solver."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bucket_of, pad_to_bucket, reduced_action_space,
                        solve_fixed_batch)
from repro.data.matrices import randsvd_dense
from repro.solvers import IRConfig, gmres_ir
from repro.tasks import stack_fixed

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)


# ---------------------------------------------------------------------------
# bucket_of boundaries
# ---------------------------------------------------------------------------

def test_bucket_exactly_on_boundary():
    # n == k * step must NOT round up to the next bucket.
    assert bucket_of(128, 128) == 128
    assert bucket_of(256, 128) == 256
    assert bucket_of(16, 16, minimum=16) == 16
    assert bucket_of(32, 16, minimum=16) == 32


def test_bucket_step_not_dividing_n():
    assert bucket_of(129, 128) == 256
    assert bucket_of(100, 48, minimum=48) == 144
    assert bucket_of(1, 16, minimum=16) == 16   # floored at minimum
    assert bucket_of(17, 16, minimum=16) == 32


@pytest.mark.parametrize("n,step,minimum", [(7, 16, 16), (16, 16, 16),
                                            (23, 16, 16), (31, 8, 16)])
def test_pad_to_bucket_preserves_solution(n, step, minimum):
    rng = np.random.default_rng(0)
    s = randsvd_dense(n, 50.0, rng)
    A, b, x = pad_to_bucket(s, step, minimum)
    n_pad = bucket_of(n, step, minimum)
    assert A.shape == (n_pad, n_pad) and b.shape == (n_pad,)
    # Identity padding: the padded system has the zero-extended solution.
    np.testing.assert_allclose(A @ x, b, atol=1e-10)
    np.testing.assert_array_equal(x[n:], 0.0)


# ---------------------------------------------------------------------------
# stack_fixed / solve_fixed_batch
# ---------------------------------------------------------------------------

def test_stack_fixed_pads_batch_by_repeating_row0():
    rng = np.random.default_rng(1)
    rows = [pad_to_bucket(randsvd_dense(10, 10.0, rng), 16, 16)
            for _ in range(3)]
    acts = [SPACE.actions[i] for i in range(3)]
    A, b, x, a, k = stack_fixed(rows, acts, chunk=8)
    assert k == 3 and A.shape[0] == 8
    for j in range(3, 8):          # pad rows repeat row 0
        np.testing.assert_array_equal(A[j], A[0])
        np.testing.assert_array_equal(a[j], a[0])
    with pytest.raises(AssertionError):
        stack_fixed(rows, acts, chunk=2)      # more rows than chunk


def test_single_row_flush_bitmatches_unbatched_solver():
    rng = np.random.default_rng(2)
    s = randsvd_dense(13, 1e3, rng)
    A, b, x = pad_to_bucket(s, 16, 16)
    action = SPACE.actions[-1]
    (rec,) = solve_fixed_batch([A], [b], [x], [action], IR, chunk=4)
    st = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x),
                  jnp.asarray(action, jnp.int32), IR)
    assert rec.ferr == float(st.ferr)
    assert rec.nbe == float(st.nbe)
    assert rec.n_outer == int(st.n_outer)
    assert rec.n_gmres == int(st.n_gmres)
    assert rec.status == int(st.status)
    assert rec.res_norm == float(st.res_norm)


def test_partial_chunk_records_match_per_row_solves():
    rng = np.random.default_rng(3)
    systems = [randsvd_dense(n, 100.0, rng) for n in (9, 12, 14)]
    padded = [pad_to_bucket(s, 16, 16) for s in systems]
    actions = [SPACE.actions[-1], SPACE.actions[20], SPACE.actions[-1]]
    recs = solve_fixed_batch([p[0] for p in padded], [p[1] for p in padded],
                             [p[2] for p in padded], actions, IR, chunk=8)
    assert len(recs) == 3          # pad rows dropped from the result
    for (A, b, x), action, rec in zip(padded, actions, recs):
        st = gmres_ir(jnp.asarray(A), jnp.asarray(b), jnp.asarray(x),
                      jnp.asarray(action, jnp.int32), IR)
        assert rec.n_outer == int(st.n_outer)
        assert rec.status == int(st.status)
        assert rec.ferr == pytest.approx(float(st.ferr), rel=1e-9)
