"""Proposition 1 (discretization regret bound) + fault-tolerance restart."""
import os
import subprocess
import sys

import numpy as np

from repro.core import Discretizer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_prop1_discretization_regret_bound():
    """Empirical check of mu(s, a*(s)) - mu(s, a_d*(s_d)) <= 2 L Delta.

    Synthetic Lipschitz reward: mu(s, a) = -L * |s - c_a| (piecewise-linear,
    Lipschitz constant L per action), optimal action = nearest center.
    """
    rng = np.random.default_rng(0)
    L = 3.0
    centers = rng.uniform(0, 10, size=8)          # one per action
    feats = rng.uniform(0, 10, size=(400, 1))
    disc = Discretizer.fit(feats, (12,))
    delta = disc.bin_diameter()

    def mu(s, a):
        return -L * abs(s - centers[a])

    # Discretized policy: best action at the bin's representative point
    # (empirical mean of training points in the bin = a valid omega(s_d)).
    reps = {}
    states = np.asarray(disc(feats))
    for sd in np.unique(states):
        reps[sd] = float(feats[states == sd].mean())

    worst = 0.0
    for s in rng.uniform(0, 10, size=500):
        sd = int(disc(np.array([s])))
        if sd not in reps:
            continue
        a_star = int(np.argmax([mu(s, a) for a in range(8)]))
        a_d = int(np.argmax([mu(reps[sd], a) for a in range(8)]))
        regret = mu(s, a_star) - mu(s, a_d)
        worst = max(worst, regret)
    assert worst <= 2 * L * delta + 1e-9


TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch",
         "granite-3-2b", "--smoke", "--batch", "2", "--seq", "64",
         "--ckpt-every", "3"]


def test_train_restart_resumes_from_checkpoint(tmp_path):
    """Kill-and-relaunch: the launcher resumes params/opt/pipeline cursor."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    ck = str(tmp_path / "ckpt")
    # Phase 1: run 6 steps (checkpoints at 3 and 6).
    out1 = subprocess.run(TRAIN + ["--steps", "6", "--ckpt-dir", ck],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert "done at step 6" in out1.stdout, out1.stdout + out1.stderr[-1500:]
    # Phase 2: "restart after failure" — same dir, higher target.
    out2 = subprocess.run(TRAIN + ["--steps", "9", "--ckpt-dir", ck],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert "resumed from step 6" in out2.stdout, \
        out2.stdout + out2.stderr[-1500:]
    assert "done at step 9" in out2.stdout
