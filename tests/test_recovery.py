"""Crash-safe learner state (DESIGN.md §11.1).

Registry layer: checksummed snapshots, corrupt/torn-publish detection,
`load_last_good` fallback, and publish under injected registry I/O
faults. Log layer: the fsync knob and torn-tail tolerance of the
trajectory log. Recovery layer: WAL-tail replay restores bit-identical
Q/N/epsilon state, heals a corrupt CURRENT, and (with `verify_with`)
refuses a tampered log.

Acceptance e2e: a serving subprocess is SIGKILLed mid-stream; restarting
against the same registry + log recovers learner state bit-identical to
an independent deterministic replay of the full durable log.
"""
import json
import os
import shutil
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro import faults
from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.data import generate_dense_set
from repro.faults import FaultSpec
from repro.obs import MetricsRegistry, Observability
from repro.obs.trajlog import TrajectoryLog
from repro.service import (AutotuneServer, BatcherConfig, PolicyRegistry,
                           SnapshotCorrupted, recover_server,
                           replay_wal_tail)
from repro.solvers import IRConfig

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
BCFG = BatcherConfig(max_batch=2, max_wait_s=0.0, bucket_step=16,
                     min_bucket=16)


@pytest.fixture(scope="module")
def recovery_template(tmp_path_factory):
    """Warm-started registry template; tests copy it so mutations
    (publishes, deliberate corruption) stay isolated."""
    root = str(tmp_path_factory.mktemp("recov") / "reg")
    train = generate_dense_set(6, np.random.default_rng(1),
                               n_range=(12, 12), log10_kappa_range=(1, 3))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=2))
    return root, train


@pytest.fixture()
def reg_copy(recovery_template, tmp_path):
    root, train = recovery_template
    dst = str(tmp_path / "reg")
    shutil.copytree(root, dst)
    return PolicyRegistry(dst), train


def _corrupt(reg, version, fname="qtable.npz"):
    with open(os.path.join(reg.root, "versions", version, fname), "wb") as f:
        f.write(b"garbage")


# ---------------------------------------------------------------------------
# Registry: checksums, fallback, faulted publish
# ---------------------------------------------------------------------------

def test_verify_catches_checksum_mismatch(reg_copy):
    reg, _ = reg_copy
    assert reg.verify("v0001")["version"] == "v0001"
    _corrupt(reg, "v0001")
    with pytest.raises(SnapshotCorrupted):
        reg.verify("v0001")
    with pytest.raises(SnapshotCorrupted):
        reg.load("v0001")                   # load verifies by default


def test_load_last_good_skips_corrupt_and_torn_snapshots(reg_copy):
    reg, _ = reg_copy
    good = reg.load()
    reg.publish(good, note="published, never promoted")   # v0002
    # Torn publish: a version directory without the meta.json commit
    # record (the crash window before the atomic meta write).
    torn = os.path.join(reg.root, "versions", "v0003")
    os.makedirs(torn)
    with open(os.path.join(torn, "qtable.npz"), "wb") as f:
        f.write(b"partial")
    _corrupt(reg, "v0001")           # CURRENT itself is now corrupt
    policy, version, corrupt = reg.load_last_good()
    # Search order: CURRENT (corrupt) -> promoted history (same) ->
    # unpromoted versions newest-first (v0003 torn, v0002 intact).
    assert version == "v0002"
    assert "v0001" in corrupt and "v0003" in corrupt
    assert policy.qtable.Q.shape == good.qtable.Q.shape


def test_publish_under_io_fault_leaves_registry_loadable(reg_copy):
    reg, _ = reg_copy
    before = reg.current_version()
    policy = reg.load()              # load outside the faulted window
    with faults.injected(FaultSpec("registry.io", "io_error")):
        with pytest.raises(OSError):
            reg.publish(policy, note="doomed")
    # Whatever the fault tore, fallback still restores a good snapshot
    # and CURRENT was not moved (meta is the last write).
    assert reg.current_version() == before
    _, version, _ = reg.load_last_good()
    assert version == before


# ---------------------------------------------------------------------------
# Trajectory log: fsync knob + torn tail
# ---------------------------------------------------------------------------

def test_trajlog_sync_levels_roundtrip(tmp_path):
    rec = {"request_id": 1, "task": "t", "reward": -1.5, "seq": 1}
    for sync in ("none", "rotate", "always"):
        path = str(tmp_path / f"log_{sync}.jsonl")
        log = TrajectoryLog(path, sync=sync)
        log.append(rec)
        log.close()
        assert [r["seq"] for r in TrajectoryLog.read(path)] == [1]
    with pytest.raises(ValueError, match="sync"):
        TrajectoryLog(str(tmp_path / "bad.jsonl"), sync="sometimes")


def test_trajlog_read_skips_torn_tail(tmp_path):
    path = str(tmp_path / "torn.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"seq": 1, "reward": 0.5}) + "\n")
        f.write(json.dumps({"seq": 2, "reward": 0.25}) + "\n")
        f.write('{"seq": 3, "rew')        # crash mid-append
    assert [r["seq"] for r in TrajectoryLog.read(path)] == [1, 2]


# ---------------------------------------------------------------------------
# Recovery: WAL-tail replay, healing, verified restore
# ---------------------------------------------------------------------------

def _serve(reg, log_path, n_requests, snapshot_at, train, seed=7):
    obs = Observability(registry=MetricsRegistry(), trajectory_path=log_path,
                        trajectory_sync="always")
    srv = AutotuneServer(reg, reward_cfg=W1, batcher_cfg=BCFG, obs=obs,
                        seed=seed)
    rid2inst = {}
    for i in range(n_requests):
        inst = train[i % len(train)]
        rid2inst[srv.submit(inst)] = inst
        srv.drain()
        if i == snapshot_at:
            srv.snapshot("mid-stream")
    return srv, rid2inst


def test_recover_restores_bit_exact_state_heals_and_verifies(
        reg_copy, tmp_path):
    reg, train = reg_copy
    log = str(tmp_path / "traj.jsonl")
    srv, rid2inst = _serve(reg, log, n_requests=30, snapshot_at=10,
                           train=train)
    q_live = srv.live.qtable.Q.copy()
    n_live = srv.live.qtable.N.copy()
    eps_live = srv.learner.epsilon.value
    srv.obs.trajlog.close()          # crash: the server is abandoned

    # 1. Plain recovery, with the tail re-solved and checked through
    #    eval.replay before it is applied.
    obs2 = Observability(registry=MetricsRegistry())
    rec = recover_server(reg, log, reward_cfg=W1, batcher_cfg=BCFG,
                         obs=obs2, seed=7, verify_with=rid2inst)
    assert np.array_equal(rec.live.qtable.Q, q_live)
    assert np.array_equal(rec.live.qtable.N, n_live)
    assert rec.update_seq == srv.update_seq == 30
    assert abs(rec.learner.epsilon.value - eps_live) < 1e-15
    lr = rec.last_recovery
    assert lr["version"] == "v0002" and not lr["healed_current"]
    assert lr["snapshot_seq"] == 11          # snapshot after request 11
    assert lr["skipped_stale"] == 11
    assert lr["replayed"] + lr["skipped_quarantined"] == 19
    assert "repro_recovery_total" in {f.name for f in
                                      obs2.registry.collect()}

    # 2. CURRENT points at a corrupt snapshot: recovery heals it and
    #    replays the full log from the older watermark — same state.
    cur = reg.current_version()
    _corrupt(reg, cur)
    rec2 = recover_server(reg, log, reward_cfg=W1, batcher_cfg=BCFG,
                          obs=Observability(registry=MetricsRegistry()),
                          seed=7)
    lr2 = rec2.last_recovery
    assert lr2["healed_current"] and cur in lr2["corrupt_versions"]
    assert reg.current_version() != cur
    assert lr2["snapshot_seq"] == 0          # v0001 predates the WAL
    assert np.array_equal(rec2.live.qtable.Q, q_live)
    assert np.array_equal(rec2.live.qtable.N, n_live)

    # 3. A tampered log fails verified recovery (and counts it).
    tampered = str(tmp_path / "tampered.jsonl")
    lines = [json.loads(ln) for ln in open(log) if ln.strip()]
    lines[-1]["reward"] = float(lines[-1]["reward"]) + 1.0
    with open(tampered, "w") as f:
        for ln in lines:
            f.write(json.dumps(ln) + "\n")
    obs3 = Observability(registry=MetricsRegistry())
    with pytest.raises(AssertionError):
        recover_server(reg, tampered, reward_cfg=W1, batcher_cfg=BCFG,
                       obs=obs3, seed=7, verify_with=rid2inst)
    fam = {f.name: f for f in obs3.registry.collect()}
    assert "repro_recovery_total" in fam


# ---------------------------------------------------------------------------
# Acceptance e2e: SIGKILL mid-stream, recover, diff against full replay
# ---------------------------------------------------------------------------

_CHILD = """\
import sys
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.data import generate_dense_set
from repro.obs import MetricsRegistry, Observability
from repro.service import AutotuneServer, BatcherConfig, PolicyRegistry
from repro.solvers import IRConfig

root, log = sys.argv[1], sys.argv[2]
train = generate_dense_set(6, np.random.default_rng(1), n_range=(12, 12),
                           log10_kappa_range=(1, 3))
env = GMRESIREnv(train, reduced_action_space(), IRConfig(tau=1e-6),
                 chunk=4, bucket_step=16)
reg, _, _ = PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=2))
obs = Observability(registry=MetricsRegistry(), trajectory_path=log,
                    trajectory_sync="always")
bc = BatcherConfig(max_batch=2, max_wait_s=0.0, bucket_step=16,
                   min_bucket=16)
srv = AutotuneServer(reg, reward_cfg=W1, batcher_cfg=bc, obs=obs, seed=7)
for i in range(10000):           # runs until the parent SIGKILLs it
    srv.submit(train[i % len(train)])
    srv.drain()
    if i == 10:
        srv.snapshot("mid-stream")
    print(f"DONE {i}", flush=True)
"""


def test_sigkill_mid_stream_then_recover_matches_full_replay(tmp_path):
    root = str(tmp_path / "reg")
    log = str(tmp_path / "traj.jsonl")
    child = tmp_path / "child.py"
    child.write_text(_CHILD)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)

    proc = subprocess.Popen([sys.executable, str(child), root, log],
                            stdout=subprocess.PIPE, text=True, env=env)
    watchdog = threading.Timer(570.0, proc.kill)
    watchdog.start()
    last = -1
    try:
        for line in proc.stdout:
            if line.startswith("DONE"):
                last = int(line.split()[1])
                if last >= 30:
                    proc.kill()              # SIGKILL: no atexit, no flush
                    break
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait()
        watchdog.cancel()
    assert last >= 30, "child died before reaching the kill point"

    # Recover from the mid-stream snapshot (v0002, WAL seq 11) + tail.
    reg = PolicyRegistry(root)
    rec = recover_server(reg, log, reward_cfg=W1, batcher_cfg=BCFG,
                         obs=Observability(registry=MetricsRegistry()),
                         seed=7)
    lr = rec.last_recovery
    assert lr["version"] == "v0002" and lr["snapshot_seq"] == 11
    assert not lr["healed_current"] and lr["corrupt_versions"] == []
    assert lr["final_seq"] >= 31             # everything durable replayed

    # Independent check: replay the ENTIRE durable log from the
    # warm-start snapshot (v0001, before any online update). sync
    # "always" means every completion the child announced is on disk,
    # so both paths must land on bit-identical Q/N.
    base = AutotuneServer(reg.load("v0001"), reward_cfg=W1,
                          batcher_cfg=BCFG, obs=False, seed=7)
    replay_wal_tail(base, log, snapshot_seq=0)
    assert base.update_seq == rec.update_seq == lr["final_seq"]
    assert np.array_equal(rec.live.qtable.Q, base.live.qtable.Q)
    assert np.array_equal(rec.live.qtable.N, base.live.qtable.N)
