"""Feature extraction + problem generation tests."""
import numpy as np
import pytest

from repro.core.features import (condest_hager, diag_dominance,
                                 feature_vector, inf_norm, sparsity,
                                 system_features)
from repro.data import (generate_dense_set, generate_sparse_set, pad_batch,
                        randsvd_dense, sparse_spd)

RNG = np.random.default_rng(3)


def test_condest_tracks_true_condition():
    """Hager-Higham 1-norm estimate within the usual n-factor of kappa_2."""
    for kappa in [1e2, 1e5, 1e8]:
        s = randsvd_dense(80, kappa, RNG)
        true = np.linalg.cond(s.A, 1)
        est = condest_hager(s.A)
        assert est <= true * 1.01          # estimator is a lower bound
        assert est >= true / 100           # but a good one
        # and log10 of the estimate lands within ~1 decade of target kappa
        assert abs(np.log10(est) - np.log10(kappa)) < 1.5


def test_inf_norm_and_sparsity():
    A = np.array([[1.0, -2.0], [0.0, 3.0]])
    assert inf_norm(A) == 3.0
    assert sparsity(A) == 0.25
    assert diag_dominance(A) == pytest.approx(min(1.0 / 2.0, 3.0 / 0.0
                                                  if False else 10.0))


def test_feature_vector_order():
    s = randsvd_dense(50, 1e4, RNG)
    v = feature_vector(s.features)
    assert v.shape == (2,)
    assert abs(v[0] - np.log10(s.features["kappa_est"])) < 1e-9


def test_randsvd_mode2_spectrum():
    s = randsvd_dense(60, 1e6, RNG)
    sv = np.linalg.svd(s.A, compute_uv=False)
    assert np.isclose(sv[0], 1.0, rtol=1e-8)
    assert np.isclose(sv[-2], 1.0, rtol=1e-8)      # n-1 equal singular values
    assert np.isclose(sv[-1], 1e-6, rtol=1e-6)
    assert np.isclose(sv[0] / sv[-1], 1e6, rtol=1e-6)
    np.testing.assert_allclose(s.b, s.A @ s.x_true)


def test_sparse_spd_properties():
    s = sparse_spd(120, 0.01, RNG, kappa_target=1e8)
    assert np.allclose(s.A, s.A.T)
    ev = np.linalg.eigvalsh(s.A)
    assert ev.min() > 0                    # SPD
    assert 1e6 < s.kappa < 1e11            # lands in the paper's band
    assert np.all(np.diag(s.A) != 0)


def test_generate_sets_diversity():
    dense = generate_dense_set(8, RNG, n_range=(40, 80),
                               log10_kappa_range=(1, 9))
    ns = {s.n for s in dense}
    ks = [s.kappa for s in dense]
    assert len(ns) > 1
    assert max(ks) / min(ks) > 1e2
    sparse = generate_sparse_set(3, RNG, n_range=(40, 80))
    assert all(s.kind == "sparse" for s in sparse)


def test_pad_batch_solution_preserving():
    systems = generate_dense_set(3, RNG, n_range=(30, 50),
                                 log10_kappa_range=(1, 3))
    A, b, x = pad_batch(systems, n_pad=64)
    assert A.shape == (3, 64, 64)
    for i, s in enumerate(systems):
        np.testing.assert_allclose(A[i] @ x[i], b[i], atol=1e-12)
        got = np.linalg.solve(A[i], b[i])
        np.testing.assert_allclose(got, x[i], atol=1e-6)
        assert np.all(got[s.n:] == 0)
