"""Solver-agnostic TunableTask API: engine/task equivalence with the
legacy env, CG-IR as a second instantiation (train + serve through the
same code paths), solver-import hygiene, n_solves accounting, and the
degenerate-discretizer fix."""
import os

import numpy as np
import pytest

from repro.core import (AutotuneEngine, Discretizer, GMRESIREnv, Outcome,
                        TrainConfig, W1, coerce_task, evaluate_fixed_action,
                        evaluate_policy, is_tunable_task,
                        reduced_action_space, train_policy)
from repro.data import generate_dense_set, generate_sparse_set
from repro.service import (AutotuneServer, BatcherConfig, MicroBatcher,
                           OnlineConfig, PolicyRegistry)
from repro.solvers import CGConfig, IRConfig
from repro.tasks import CGIRTask, GMRESIRTask, adapt_legacy

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
CG = CGConfig(tau=1e-6)
SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _dense(n_sys, seed=0, n_range=(12, 30)):
    rng = np.random.default_rng(seed)
    return generate_dense_set(n_sys, rng, n_range=n_range,
                              log10_kappa_range=(1, 6))


def _spd(n_sys, seed=0, n_range=(12, 30)):
    rng = np.random.default_rng(seed)
    return generate_sparse_set(n_sys, rng, n_range=n_range)


# ---------------------------------------------------------------------------
# Engine <-> legacy env equivalence
# ---------------------------------------------------------------------------

def test_task_training_matches_legacy_env_bitwise():
    systems = _dense(6)
    env = GMRESIREnv(systems, SPACE, IR, chunk=4, bucket_step=16)
    p_env, h_env = train_policy(env, W1, TrainConfig(episodes=3))
    task = GMRESIRTask(systems, SPACE, IR, bucket_step=16, min_bucket=16)
    p_task, h_task = train_policy(task, W1, TrainConfig(episodes=3))
    assert np.array_equal(p_env.qtable.Q, p_task.qtable.Q)
    assert np.array_equal(p_env.qtable.N, p_task.qtable.N)
    assert h_env.episode_reward == h_task.episode_reward


def test_legacy_env_record_exposes_solverecord_fields():
    systems = _dense(2)
    env = GMRESIREnv(systems, SPACE, IR, chunk=2, bucket_step=16)
    rec = env.record(0, SPACE.n_actions - 1)
    assert isinstance(rec, Outcome)
    # SolveRecord-era attribute access flows through Outcome.metrics.
    for field in ("ferr", "nbe", "n_outer", "n_gmres", "res_norm"):
        getattr(rec, field)
    assert rec.ok
    with pytest.raises(AttributeError):
        rec.not_a_metric


def test_outcome_survives_pickle_and_copy():
    import copy
    import pickle
    out = Outcome(status=0, cost=4.0, metrics={"ferr": 1e-9, "nbe": 1e-12})
    back = pickle.loads(pickle.dumps(out))
    assert back.ferr == out.ferr and back.status == 0
    dup = copy.deepcopy(out)
    assert dup.metrics == out.metrics
    assert copy.copy(out).cost == 4.0


def test_server_rejects_mismatched_task_action_space(tmp_path):
    from repro.core import full_action_space
    task = GMRESIRTask(_dense(4), SPACE, IR, bucket_step=16, min_bucket=16)
    reg, _, _ = PolicyRegistry.warm_start(str(tmp_path / "reg"), task, W1,
                                          TrainConfig(episodes=1))
    bad_task = GMRESIRTask(action_space=full_action_space(), ir_cfg=IR,
                           bucket_step=16, min_bucket=16)
    with pytest.raises(ValueError, match="action space"):
        AutotuneServer(reg, bad_task, W1,
                       BatcherConfig(bucket_step=16, min_bucket=16))


def test_coerce_task_and_adapters():
    assert isinstance(coerce_task(IR), GMRESIRTask)
    assert isinstance(coerce_task(CG), CGIRTask)
    assert isinstance(coerce_task(None), GMRESIRTask)
    task = GMRESIRTask((), SPACE, IR)
    assert coerce_task(task) is task
    assert is_tunable_task(task)
    assert not is_tunable_task(IR)
    with pytest.raises(TypeError):
        adapt_legacy(object())
    adapted = coerce_task(IR, bucket_step=32, min_bucket=32)
    assert adapted.bucket_step == 32 and adapted.min_bucket == 32


# ---------------------------------------------------------------------------
# Satellite: n_solves accounting (real rows vs chunk padding)
# ---------------------------------------------------------------------------

def test_engine_counts_real_and_pad_solves_separately():
    systems = _dense(3)
    env = GMRESIREnv(systems, SPACE, IR, chunk=8, bucket_step=16)
    env.solve_pairs([(i, SPACE.n_actions - 1) for i in range(3)])
    # 3 real rows in one chunk-of-8 call: 3 real + 5 padding.
    assert env.n_solves == 3
    assert env.n_pad_solves == 5
    summary = env.summarize()
    assert summary["n_solves"] == 3
    assert summary["n_pad_solves"] == 5
    assert summary["cache_size"] == 3
    # A second, cached lookup does no new solver work.
    env.solve_pairs([(0, SPACE.n_actions - 1)])
    assert env.n_solves == 3 and env.n_pad_solves == 5


def test_train_history_surfaces_solver_work():
    task = GMRESIRTask(_dense(3), SPACE, IR, bucket_step=16, min_bucket=16)
    _, hist = train_policy(task, W1, TrainConfig(episodes=2))
    assert hist.n_solves > 0
    assert hist.n_solves + hist.n_pad_solves >= hist.n_solves
    assert hist.n_solves == hist.unique_solves[-1]  # cache == real rows here


# ---------------------------------------------------------------------------
# Satellite: degenerate discretizer fit
# ---------------------------------------------------------------------------

def test_discretizer_single_instance_single_bin():
    d = Discretizer.fit(np.array([[2.0, 5.0]]), (10, 10))
    # All queries — at, below, above the fit point — land in one state.
    for q in ([2.0, 5.0], [2.3, 5.9], [-100.0, 100.0], [2.0001, 5.0]):
        assert d(np.array(q)) == 0


def test_discretizer_constant_column_is_single_bin():
    feats = np.array([[0.0, 7.0], [9.0, 7.0], [4.5, 7.0]])
    d = Discretizer.fit(feats, (10, 5))
    # Column 1 is constant: its bin index is always 0, whatever the query
    # (previously an off-point query landed in an arbitrary bin).
    idx = d.bin_indices(np.array([[4.5, 7.3], [4.5, 6.1], [4.5, 7.0]]))
    assert np.array_equal(idx[:, 1], [0, 0, 0])
    # Non-degenerate column 0 still bins normally.
    assert d.bin_indices(np.array([9.0, 7.0]))[0, 0] == 9
    states = d(np.array([[4.5, 7.3], [4.5, 6.1]]))
    assert states[0] == states[1]


# ---------------------------------------------------------------------------
# CG-IR: the API-generalization proof
# ---------------------------------------------------------------------------

def test_cg_task_trains_and_evaluates_via_shared_paths():
    systems = _spd(6)
    task = CGIRTask(systems, SPACE, CG, bucket_step=16, min_bucket=16)
    policy, hist = train_policy(task, W1, TrainConfig(episodes=3))
    assert len(hist.episode_reward) == 3
    ev = evaluate_policy(policy, CGIRTask(systems, SPACE, CG, bucket_step=16,
                                          min_bucket=16), tau_base=1e-6)
    assert ev["table"]           # sparse SPD set lands in the high ranges
    assert np.all(ev["n_inner"] >= 0)
    bl = evaluate_fixed_action(
        CGIRTask(systems, SPACE, CG, bucket_step=16, min_bucket=16),
        SPACE.n_actions - 1, 1e-6)
    # The all-FP64 baseline solves SPD systems accurately through CG-IR.
    assert np.all(bl["ferr"] < 1e-6)


def test_cg_task_serves_through_the_same_server(tmp_path):
    systems = _spd(6, seed=1)
    train_task = CGIRTask(systems, SPACE, CG, bucket_step=16, min_bucket=16)
    reg, version, snap = PolicyRegistry.warm_start(
        str(tmp_path / "reg"), train_task, W1, TrainConfig(episodes=2))
    serve_task = CGIRTask(action_space=SPACE, cg_cfg=CG, bucket_step=16,
                          min_bucket=16)
    srv = AutotuneServer(
        reg, serve_task, W1,
        BatcherConfig(max_batch=4, max_wait_s=0.005, bucket_step=16,
                      min_bucket=16), OnlineConfig())
    requests = _spd(8, seed=2)
    ids = [srv.submit(s) for s in requests]
    srv.drain()
    responses = [srv.poll(i) for i in ids]
    assert all(r is not None for r in responses)
    assert all("n_cg" in r.record.metrics for r in responses)
    tel = srv.telemetry.snapshot()
    assert tel["responses"] == 8 and tel["updates"] == 8
    assert tel["n_solves"] + tel["n_pad_solves"] == tel["solver_rows"]
    v2 = srv.snapshot()
    assert reg.meta(v2)["task"] == "cg_ir"


def test_microbatcher_hosts_cg_task():
    task = CGIRTask(action_space=SPACE, cg_cfg=CG, bucket_step=16,
                    min_bucket=16)
    mb = MicroBatcher(task, BatcherConfig(max_batch=2, max_wait_s=10.0,
                                          bucket_step=16, min_bucket=16))
    for s in _spd(2, seed=3, n_range=(12, 14)):   # one shared bucket (16)
        mb.submit(s, SPACE.actions[-1])
    out = mb.pump()
    assert len(out) == 1 and len(out[0].records) == 2
    for rec in out[0].records:
        assert rec.ferr < 1e-6 and rec.ok  # fp64 CG-IR solves SPD exactly


# ---------------------------------------------------------------------------
# Import hygiene: the engine and server really are solver-agnostic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rel", ["core/engine.py", "core/task.py",
                                 "service/server.py", "service/batcher.py"])
def test_no_solver_imports_in_agnostic_layers(rel):
    with open(os.path.join(SRC, rel)) as f:
        src = f.read()
    for banned in ("repro.solvers.ir", "repro.solvers.cg", "gmres",
                   "repro.tasks.gmres", "repro.tasks.cg"):
        assert banned not in src, f"{rel} mentions {banned}"
