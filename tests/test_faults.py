"""Fault injection (DESIGN.md §11.3) + graceful degradation (§11.2).

Unit layer: the injector's deterministic schedules (seeded streams,
`p`/`after`/`max_fires`/`match` semantics, REPRO_FAULTS parsing) and
the per-bucket circuit-breaker FSM in isolation.

Integration layer (acceptance): a stream with injected NaN outcomes on
every non-safe arm trips the breaker; while open, pinned responses are
bit-identical to what a healthy all-fp64 server returns for the same
instances; no poisoned reward ever reaches the Q-table; probes close
the breaker once the fault exhausts and learning resumes. Plus a
REPRO_FAULTS-style chaos stream the server must survive.
"""
import math
import os

import numpy as np
import pytest

from repro import faults
from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.core.task import FAILED, Outcome
from repro.data import generate_dense_set
from repro.faults import FaultInjected, FaultInjector, FaultSpec
from repro.obs import MetricsRegistry, Observability
from repro.service import (AutotuneServer, BatcherConfig, BreakerConfig,
                           OnlineConfig, PolicyRegistry)
from repro.service.breaker import (CLOSED, HALF_OPEN, OPEN,
                                   CircuitBreakers)
from repro.solvers import IRConfig

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
BCFG = BatcherConfig(max_batch=1, max_wait_s=0.0, bucket_step=16,
                     min_bucket=16)
# eps=0 everywhere in this module: selection must be deterministic so
# the degraded-vs-healthy comparison is exact.
OCFG = OnlineConfig(eps0=0.0, eps_min=0.0)


# ---------------------------------------------------------------------------
# Injector units
# ---------------------------------------------------------------------------

def test_spec_validation_rejects_unknown_site_and_kind():
    with pytest.raises(ValueError, match="site"):
        FaultSpec("nope.where", "nan")
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("batcher.flush", "explode")


def test_deterministic_schedule_per_seed():
    def schedule(seed):
        inj = FaultInjector([FaultSpec("batcher.flush", "raise", p=0.3)],
                            seed=seed)
        return [inj.fire("batcher.flush") is not None for _ in range(200)]

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)
    assert any(schedule(7)) and not all(schedule(7))


def test_after_and_max_fires_window():
    inj = FaultInjector([FaultSpec("trajlog.write", "io_error",
                                   after=2, max_fires=2)])
    fired = [inj.fire("trajlog.write") is not None for _ in range(6)]
    # Hits 1-2 skipped by `after`, 3-4 fire, 5-6 exhausted.
    assert fired == [False, False, True, True, False, False]
    assert inj.counts()[("trajlog.write", "io_error")] == (6, 2)


def test_match_predicate_filters_and_fails_closed():
    inj = FaultInjector([FaultSpec("solver.outcome", "nan",
                                   match=lambda ctx: ctx["bucket"] == 16)])
    assert inj.fire("solver.outcome", bucket=32) is None
    assert inj.fire("solver.outcome", bucket=16) is not None
    # A raising predicate must not take the site down: the spec just
    # doesn't fire.
    broken = FaultInjector([FaultSpec("solver.outcome", "nan",
                                      match=lambda ctx: ctx["missing"])])
    assert broken.fire("solver.outcome", bucket=16) is None


def test_first_matching_spec_wins_and_sites_are_independent():
    inj = FaultInjector([FaultSpec("registry.io", "io_error", max_fires=1),
                         FaultSpec("registry.io", "raise"),
                         FaultSpec("clock", "clock_skew")])
    assert inj.fire("registry.io").kind == "io_error"
    assert inj.fire("registry.io").kind == "raise"    # first one exhausted
    assert inj.fire("clock").kind == "clock_skew"
    assert inj.fire("http.request") is None


def test_from_env_parses_the_documented_grammar():
    inj = faults.from_env("solver.outcome:divergence:p=0.15;"
                          " trajlog.write:io_error:max=3:after=1;"
                          "clock:clock_skew:value=2.5", seed=3)
    assert [s.site for s in inj.specs] == ["solver.outcome",
                                          "trajlog.write", "clock"]
    assert inj.specs[0].p == 0.15
    assert inj.specs[1].max_fires == 3 and inj.specs[1].after == 1
    assert inj.specs[2].value == 2.5
    with pytest.raises(ValueError, match="site:kind"):
        faults.from_env("justasite")
    with pytest.raises(ValueError, match="unknown fault option"):
        faults.from_env("batcher.flush:raise:frequency=9")


def test_env_plan_activates_lazily(monkeypatch):
    monkeypatch.setenv(faults.ENV_PLAN, "batcher.flush:raise:p=0.5")
    monkeypatch.setenv(faults.ENV_SEED, "11")
    faults.uninstall()            # re-arm env discovery
    try:
        inj = faults.active()
        assert inj is not None and inj.seed == 11
        assert inj.specs[0].site == "batcher.flush"
        assert faults.active() is inj          # parsed once, cached
    finally:
        monkeypatch.delenv(faults.ENV_PLAN)
        monkeypatch.delenv(faults.ENV_SEED)
        faults.uninstall()


def test_injected_restores_previous_injector(monkeypatch):
    monkeypatch.delenv(faults.ENV_PLAN, raising=False)
    faults.uninstall()
    assert faults.active() is None
    with faults.injected(FaultSpec("http.request", "raise")) as outer:
        assert faults.active() is outer
        with faults.injected(FaultSpec("clock", "clock_skew")) as inner:
            assert faults.active() is inner
        assert faults.active() is outer
    assert faults.active() is None


def test_maybe_raise_kinds():
    faults.maybe_raise("http.request")      # no injector: no-op
    with faults.injected(FaultSpec("http.request", "raise")):
        with pytest.raises(FaultInjected):
            faults.maybe_raise("http.request")
    with faults.injected(FaultSpec("registry.io", "io_error")):
        with pytest.raises(OSError):
            faults.maybe_raise("registry.io")
    with faults.injected(FaultSpec("batcher.flush", "delay", value=0.0)):
        faults.maybe_raise("batcher.flush")  # returns after the sleep


def test_corrupt_outcome_nan_and_divergence():
    out = Outcome(status=0, cost=12.5, metrics={"ferr": 1e-9, "n_gmres": 8})
    assert faults.corrupt_outcome("solver.outcome", out) is out  # inert

    with faults.injected(FaultSpec("solver.outcome", "nan")):
        bad = faults.corrupt_outcome("solver.outcome", out)
    assert bad.status == 0                   # healthy-looking status
    assert math.isnan(bad.cost)
    assert all(math.isnan(v) for v in bad.metrics.values())

    with faults.injected(FaultSpec("solver.outcome", "divergence")):
        div = faults.corrupt_outcome("solver.outcome", out)
    assert div.status == FAILED
    assert all(math.isinf(v) for v in div.metrics.values())


def test_wrap_clock_accumulates_skew():
    base = [100.0]
    clock = faults.wrap_clock(lambda: base[0])
    assert clock() == 100.0                  # transparent with no injector
    with faults.injected(FaultSpec("clock", "clock_skew", value=2.0,
                                   max_fires=3)):
        reads = [clock() for _ in range(5)]
    assert reads == [102.0, 104.0, 106.0, 106.0, 106.0]
    assert clock() == 106.0                  # skew persists, stops growing


def test_fires_are_counted_on_the_default_registry():
    from repro.obs.metrics import default_registry
    with faults.injected(FaultSpec("executor.dispatch", "delay",
                                   value=0.0)):
        faults.maybe_raise("executor.dispatch")
    fams = {f.name: f for f in default_registry().collect()}
    assert "repro_faults_injected_total" in fams


# ---------------------------------------------------------------------------
# Breaker FSM units
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(window=8, min_samples=4, failure_threshold=0.5,
                probe_interval=3, probe_successes=2)
    base.update(kw)
    return BreakerConfig(**base)


def test_breaker_trips_after_min_samples_and_pins_with_probe_cadence():
    log = []
    br = CircuitBreakers(_cfg(), on_transition=lambda b, o, n:
                         log.append((b, o, n)))
    for _ in range(3):
        assert br.on_outcome(16, healthy=False) == CLOSED  # below min
    assert br.on_outcome(16, healthy=False) == OPEN
    assert log == [(16, CLOSED, OPEN)]
    assert br.open_buckets() == [16]
    assert br.state(32) == CLOSED            # per-bucket isolation
    # Every probe_interval-th selection probes; the rest are pinned.
    routes = [br.on_select(16) for _ in range(6)]
    assert routes == ["pinned", "pinned", "probe",
                      "pinned", "pinned", "probe"]
    assert br.state(16) == HALF_OPEN         # first probe half-opens
    assert br.describe()["16"]["times_opened"] == 1


def test_breaker_closes_on_probe_streak_and_reopens_on_probe_failure():
    br = CircuitBreakers(_cfg())
    for _ in range(4):
        br.on_outcome(16, healthy=False)
    assert br.state(16) == OPEN
    [br.on_select(16) for _ in range(3)]     # reach the first probe
    assert br.on_outcome(16, healthy=True, probe=True) == HALF_OPEN
    # A failed probe resets the streak and falls back to open.
    assert br.on_outcome(16, healthy=False, probe=True) == OPEN
    [br.on_select(16) for _ in range(3)]
    assert br.on_outcome(16, healthy=True, probe=True) == HALF_OPEN
    assert br.on_outcome(16, healthy=True, probe=True) == CLOSED
    assert br.open_buckets() == []
    # Pinned traffic never feeds the window: these carry no evidence.
    br2 = CircuitBreakers(_cfg())
    for _ in range(4):
        br2.on_outcome(16, healthy=False)
    for _ in range(50):
        br2.on_outcome(16, healthy=True, probe=False)   # pinned outcomes
    assert br2.state(16) != CLOSED


def test_breaker_disabled_is_transparent():
    br = CircuitBreakers(BreakerConfig(enabled=False))
    for _ in range(64):
        br.on_outcome(16, healthy=False)
    assert br.state(16) == CLOSED
    assert br.on_select(16) == "normal"
    assert br.open_buckets() == []


# ---------------------------------------------------------------------------
# Integration: NaN poisoning -> breaker -> safe arm (acceptance e2e)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fault_reg(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("faultreg") / "reg")
    train = generate_dense_set(6, np.random.default_rng(1),
                               n_range=(12, 12), log10_kappa_range=(1, 3))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=2))
    return root, train


def _serve_one(srv, inst):
    rid = srv.submit(inst)
    resp = srv.poll(rid)
    if resp is None:
        srv.drain()
        resp = srv.poll(rid)
    assert resp is not None
    return resp


def test_injected_nan_trips_breaker_pins_safe_arm_then_recovers(fault_reg):
    root, train = fault_reg
    stream = [train[i % len(train)] for i in range(26)]

    # Healthy reference: a zeroed Q-row tie-breaks to the highest index
    # = the all-fp64 safe arm (pinned by test_qtable), so this server
    # answers every request with the safe arm.
    ref_srv = AutotuneServer(PolicyRegistry(root), reward_cfg=W1,
                             batcher_cfg=BCFG, online_cfg=OCFG, seed=0,
                             obs=False)
    refs = []
    for inst in stream:
        ref_srv.live.qtable.Q[:] = 0.0       # learning must not unpin
        refs.append(_serve_one(ref_srv, inst))
    assert all(r.action == ref_srv.safe_action for r in refs)

    # Degraded server: greedy pinned to arm 0 (a reduced-precision
    # arm), and every solve on a non-safe arm returns NaN metrics.
    srv = AutotuneServer(
        PolicyRegistry(root), reward_cfg=W1, batcher_cfg=BCFG,
        online_cfg=OCFG, seed=0, obs=True,
        breaker_cfg=BreakerConfig(window=8, min_samples=4,
                                  failure_threshold=0.5, probe_interval=2,
                                  probe_successes=2))
    srv.live.qtable.Q[:] = 0.0
    srv.live.qtable.Q[:, 0] = 1.0
    safe_row = srv.action_space.actions[srv.safe_action]
    n_before = srv.live.qtable.N.sum()

    def not_safe_arm(ctx):
        return not bool((np.asarray(ctx["action_row"]) == safe_row).all())

    with faults.injected(FaultSpec("solver.outcome", "nan",
                                   match=not_safe_arm, max_fires=10)):
        resps = [_serve_one(srv, inst) for inst in stream]

    bucket = resps[0].bucket
    desc = srv.breakers.describe()[str(bucket)]
    assert desc["times_opened"] == 1, desc

    # While poisoned+closed: NaN rewards are quarantined, never trained.
    poisoned = [r for r in resps if math.isnan(r.reward)]
    assert poisoned and all(r.quarantined for r in poisoned)
    assert np.isfinite(srv.live.qtable.Q).all()
    assert np.isfinite(srv.live.qtable.N).all()

    # Acceptance: every pinned response is bit-identical to the healthy
    # all-fp64 server's answer for the same instance.
    pinned = [(i, r) for i, r in enumerate(resps) if r.pinned]
    assert pinned, "breaker never pinned traffic"
    for i, r in pinned:
        ref = refs[i]
        assert r.action == srv.safe_action == ref.action
        assert r.quarantined and not r.probe
        assert r.record.status == ref.record.status
        assert r.reward == ref.reward        # bit-identical float64
        assert r.record.cost == ref.record.cost
        for k, v in ref.record.metrics.items():
            assert r.record.metrics[k] == v, (i, k)

    # The fault exhausted (max_fires) -> healthy probes closed the
    # breaker -> learning resumed on post-recovery traffic.
    assert srv.breakers.state(bucket) == CLOSED
    assert not resps[-1].quarantined and not resps[-1].pinned
    assert srv.live.qtable.N.sum() > n_before
    assert srv.update_seq == len(stream)     # every completion sequenced

    deg = srv.degradation_state()
    assert deg["degraded"] is False
    assert deg["quarantined_updates"] == sum(r.quarantined for r in resps)


@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_stream_server_survives(fault_reg, tmp_path, seed):
    """REPRO_FAULTS-style chaos plan: mixed NaN/divergence/raise/io/skew
    faults; the server must answer every admitted request eventually,
    keep the Q-table finite, and end with nothing stuck in the queue."""
    root, train = fault_reg
    obs = Observability(registry=MetricsRegistry(),
                        trajectory_path=str(tmp_path / f"chaos{seed}.jsonl"))
    srv = AutotuneServer(PolicyRegistry(root), reward_cfg=W1,
                         batcher_cfg=BCFG, online_cfg=OCFG, seed=seed,
                         obs=obs,
                         breaker_cfg=BreakerConfig(window=8, min_samples=4,
                                                   failure_threshold=0.5,
                                                   probe_interval=2,
                                                   probe_successes=2))
    # The CI chaos job varies REPRO_FAULTS_SEED (and may override the
    # plan) so every matrix entry sees a different deterministic
    # schedule; locally the built-in plan and seeds run.
    plan = os.environ.get(faults.ENV_PLAN) or (
        "solver.outcome:nan:p=0.2;solver.outcome:divergence:p=0.1;"
        "batcher.flush:raise:p=0.15;trajlog.write:io_error:p=0.2;"
        "clock:clock_skew:p=0.1:value=0.5")
    seed = seed ^ int(os.environ.get(faults.ENV_SEED, "0") or 0)
    inj = faults.from_env(plan, seed=seed)
    faults.install(inj)
    rids = []
    try:
        for i in range(24):
            try:
                rids.append(srv.submit(train[i % len(train)]))
            except FaultInjected:
                continue          # flush raised through auto_step: retry
            for _ in range(20):   # injected flush failures are retryable
                try:
                    srv.drain()
                    break
                except FaultInjected:
                    pass
    finally:
        faults.uninstall()
    srv.drain()                   # fault-free final drain
    resps = [srv.poll(rid) for rid in rids]
    assert all(r is not None for r in resps), "an admitted request was lost"
    assert srv.pending == 0
    assert np.isfinite(srv.live.qtable.Q).all()
    # Non-finite rewards never train; divergence (finite fail_reward)
    # legitimately may.
    for r in resps:
        if not math.isfinite(r.reward):
            assert r.quarantined
    # The injector saw traffic: solver.outcome is hit on every flush.
    assert sum(h for h, _ in inj.counts().values()) > 0
