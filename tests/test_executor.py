"""Execution layer (DESIGN.md §7): executor contract + sharded parity.

In-process tests cover the pure-Python contract (chunk rounding,
registry/selection, engine/batcher integration) and the degenerate
1-device mesh, which must bit-match the local path anywhere.

The heavy parity suite runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
fixed at jax import, same pattern as tests/test_distributed.py):
`ShardedExecutor` SolveRecords must bit-match `LocalExecutor` for all 7
format ids, single and batched rows, strict and blocked factorization
paths, end-to-end through the `AutotuneEngine` and the serving stack,
with one executable per bucket across a full precision-action sweep.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LocalExecutor, ShardedExecutor, available_executors,
                        pad_to_bucket, reduced_action_space, resolve_executor,
                        set_default_executor, solve_fixed_batch)
from repro.core.engine import AutotuneEngine
from repro.data.matrices import randsvd_dense
from repro.service import AutotuneServer, BatcherConfig, MicroBatcher
from repro.solvers import IRConfig
from repro.tasks import GMRESIRTask

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
SPACE = reduced_action_space()
IR = IRConfig(tau=1e-5, i_max=4, m_max=12)


# ---------------------------------------------------------------------------
# Contract: chunk rounding, registry, selection
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_preferred_chunk_rounding():
    assert LocalExecutor().preferred_chunk(9) == 9
    ex = ShardedExecutor(data=4)
    assert ex.preferred_chunk(1) == 4    # at least one row per device
    assert ex.preferred_chunk(3) == 4
    assert ex.preferred_chunk(4) == 4
    assert ex.preferred_chunk(8) == 8
    assert ex.preferred_chunk(9) == 12   # round UP, never down
    # Rounding depends only on the request, never on queue occupancy:
    # that is what keeps the compiled shape stable per bucket.
    assert ex.preferred_chunk(8, bucket=128) == 8


@pytest.mark.fast
def test_registry_and_selection(monkeypatch):
    from repro.core import executor as E
    assert "local" in available_executors()
    assert "sharded" in available_executors()
    assert resolve_executor(None).name == "local"
    assert resolve_executor("local") == LocalExecutor()
    inst = ShardedExecutor(data=1)
    assert resolve_executor(inst) is inst
    with pytest.raises(KeyError):
        resolve_executor("nope")
    monkeypatch.setenv(E.ENV_VAR, "sharded")
    assert resolve_executor(None).name == "sharded"
    prev = set_default_executor("local")
    try:
        assert resolve_executor(None).name == "local"   # beats env var
    finally:
        set_default_executor(prev)


@pytest.mark.fast
def test_executors_hash_by_value():
    """Equal-valued executors must share memoized dispatch wrappers
    (and therefore compiled executables)."""
    assert LocalExecutor() == LocalExecutor()
    assert hash(ShardedExecutor(data=2)) == hash(ShardedExecutor(data=2))
    assert ShardedExecutor(data=2) != ShardedExecutor(data=4)


@pytest.mark.fast
def test_mesh_larger_than_host_raises():
    import jax
    ndev = jax.device_count()
    with pytest.raises(ValueError, match="devices"):
        ShardedExecutor(data=ndev * 64).mesh()


# ---------------------------------------------------------------------------
# Degenerate 1-device mesh == local, bitwise
# ---------------------------------------------------------------------------

def test_one_device_mesh_bitmatches_local():
    rng = np.random.default_rng(2)
    rows = [pad_to_bucket(randsvd_dense(int(n), 1e3, rng), 16, 16)
            for n in (13, 10, 12)]
    acts = [SPACE.actions[i] for i in (0, 20, SPACE.n_actions - 1)]
    loc = solve_fixed_batch([r[0] for r in rows], [r[1] for r in rows],
                            [r[2] for r in rows], acts, IR, chunk=4)
    sh = solve_fixed_batch([r[0] for r in rows], [r[1] for r in rows],
                           [r[2] for r in rows], acts, IR, chunk=4,
                           executor=ShardedExecutor(data=1))
    for a, b in zip(loc, sh):
        assert a.__dict__ == b.__dict__


# ---------------------------------------------------------------------------
# Engine + batcher integration via a stub executor (no extra devices)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FourRounder(LocalExecutor):
    """Local dispatch with a mesh-like granularity of 4 — exercises the
    chunk-rounding plumbing without needing multiple devices."""
    name: str = dataclasses.field(default="four", init=False)

    def preferred_chunk(self, chunk: int, bucket: int = 0) -> int:
        return max(4, -(-int(chunk) // 4) * 4)

    def device_count(self) -> int:
        return 4


def _systems(k, seed=0, lo=9, hi=14):
    rng = np.random.default_rng(seed)
    return [randsvd_dense(int(n), 100.0, rng)
            for n in rng.integers(lo, hi, size=k)]


def test_engine_rounds_chunk_and_accounts_padding():
    task = GMRESIRTask(_systems(3, seed=1), SPACE, IR, bucket_step=16,
                       min_bucket=16, executor=FourRounder())
    eng = AutotuneEngine(task, chunk=2)          # rounds up to 4
    assert eng.executor == FourRounder()         # picked up from the task
    eng.solve_pairs([(i, 0) for i in range(3)])
    assert eng.n_solves == 3
    assert eng.n_pad_solves == 1                 # 4-row chunk, 3 live rows
    summ = eng.summarize()
    assert summ["n_devices"] == 4
    assert summ["rows_per_device"] == 1
    assert summ["n_solves_per_device"] == pytest.approx(3 / 4)


def test_batcher_flush_targets_executor_chunk():
    task = GMRESIRTask((), SPACE, IR, bucket_step=16, min_bucket=16,
                       executor=FourRounder())
    mb = MicroBatcher(task, BatcherConfig(max_batch=3, max_wait_s=1e9,
                                          bucket_step=16, min_bucket=16))
    assert mb.flush_target(16) == 4              # max_batch rounded up
    for s in _systems(3, seed=2, lo=9, hi=14):
        mb.submit(s, SPACE.actions[-1])
    assert mb.pump() == []                       # 3 < flush target of 4
    mb.submit(_systems(1, seed=3)[0], SPACE.actions[-1])
    out = mb.pump()
    assert len(out) == 1
    assert out[0].n_rows == 4                    # rows solved == target
    assert len(out[0].records) == 4


def test_server_threads_executor_to_task_and_telemetry():
    from repro.core import QTable, Discretizer, W1
    from repro.core.policy import PrecisionPolicy
    feats = np.array([[1.0, 10.0], [5.0, 1e4]])
    disc = Discretizer.fit(feats, (2, 2))
    snap = PrecisionPolicy(SPACE, disc, QTable(disc.n_states,
                                               SPACE.n_actions))
    srv = AutotuneServer(snap, IR,
                         batcher_cfg=BatcherConfig(max_batch=2,
                                                   max_wait_s=1e9,
                                                   bucket_step=16,
                                                   min_bucket=16),
                         executor=FourRounder())
    assert srv.executor == FourRounder()
    assert srv.task.executor == FourRounder()    # legacy cfg adapted with it
    assert srv.batcher.flush_target(16) == 4
    for s in _systems(4, seed=4):
        srv.submit(s)
    srv.drain()
    tel = srv.telemetry.snapshot()
    # Pad accounting reflects the executor's 4-row granularity.
    assert tel["solver_rows"] % 4 == 0
    assert tel["n_solves"] == 4
    assert tel["n_solves"] + tel["n_pad_solves"] == tel["solver_rows"]


def test_records_from_stats_single_host_transfer(monkeypatch):
    """The whole SolveStats tuple must come to host in ONE device_get."""
    import jax
    from repro.core import batching
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(x)
        return real(x)

    monkeypatch.setattr(batching.jax, "device_get", counting)
    rng = np.random.default_rng(5)
    A, b, x = pad_to_bucket(randsvd_dense(11, 10.0, rng), 16, 16)
    (rec,) = solve_fixed_batch([A], [b], [x], [SPACE.actions[-1]], IR,
                               chunk=2)
    assert len(calls) == 1
    assert rec.status in (0, 1, 2, 3)


# ---------------------------------------------------------------------------
# 8-device host mesh: the full parity + accounting suite (subprocess)
# ---------------------------------------------------------------------------

PARITY_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import (LocalExecutor, ShardedExecutor, pad_to_bucket,
                        reduced_action_space, solve_fixed_batch)
from repro.core import executor as EX
from repro.core.engine import AutotuneEngine
from repro.data.matrices import randsvd_dense, sparse_spd
from repro.solvers import BlockingPolicy, CGConfig, IRConfig
from repro.tasks import CGIRTask, GMRESIRTask

assert jax.device_count() == 8, jax.device_count()
SPACE = reduced_action_space()
IR = IRConfig(tau=1e-5, i_max=4, m_max=12)
CG = CGConfig(tau=1e-5, i_max=4, m_max=12)
# Threshold-lowered blocking so the small parity systems exercise the
# blocked LU + trisolve path end to end (DESIGN.md §6.4).
IRB = IRConfig(tau=1e-5, i_max=4, m_max=12,
               blocking=BlockingPolicy(min_n=16, lu_block=16,
                                       trisolve_block=16))

# --- solve_fixed_batch parity: all 7 format ids, single + batched ---------
for fid in range(7):
    A, b, x = pad_to_bucket(
        randsvd_dense(13, 10.0 ** (fid % 5), np.random.default_rng(fid)),
        16, 16)
    act = np.asarray([fid] * 4, np.int32)
    for cfg in (IR, IRB):
        loc = solve_fixed_batch([A], [b], [x], [act], cfg, chunk=8)
        sh = solve_fixed_batch([A], [b], [x], [act], cfg, chunk=8,
                               executor=ShardedExecutor(data=8))
        assert loc[0].__dict__ == sh[0].__dict__, (fid, cfg, loc, sh)
rows = [pad_to_bucket(randsvd_dense(int(n), 10.0 ** k,
                                    np.random.default_rng(k)), 16, 16)
        for k, n in enumerate((10, 13, 12, 14, 11, 9, 15, 10))]
acts = [SPACE.actions[i % SPACE.n_actions] for i in range(8)]
for d in (2, 4, 8):
    loc = solve_fixed_batch([r[0] for r in rows], [r[1] for r in rows],
                            [r[2] for r in rows], acts, IR, chunk=8)
    sh = solve_fixed_batch([r[0] for r in rows], [r[1] for r in rows],
                           [r[2] for r in rows], acts, IR, chunk=8,
                           executor=ShardedExecutor(data=d))
    for a, b_ in zip(loc, sh):
        assert a.__dict__ == b_.__dict__, d
print("PARITY_BATCH_OK")

# --- engine e2e (both tasks, full action space) + accounting --------------
def engine(cls, systems, cfg, kw, ex, chunk=4):
    t = cls(systems, SPACE, bucket_step=16, min_bucket=16, executor=ex,
            **{kw: cfg})
    e = AutotuneEngine(t, chunk=chunk)
    e.prefill_all()
    return e

dsys = [randsvd_dense(int(n), 10.0 ** (i + 1), np.random.default_rng(i))
        for i, n in enumerate((9, 11, 13, 10))]
ssys = [sparse_spd(int(n), 0.2, np.random.default_rng(i), 1e4)
        for i, n in enumerate((9, 11, 13, 10))]
for cls, systems, cfg, kw in ((GMRESIRTask, dsys, IR, "ir_cfg"),
                              (CGIRTask, ssys, CG, "cg_cfg")):
    el = engine(cls, systems, cfg, kw, None)
    es = engine(cls, systems, cfg, kw, ShardedExecutor(data=8))
    for i in range(len(systems)):
        for a in range(SPACE.n_actions):
            got, want = es.outcome(i, a), el.outcome(i, a)
            assert got.status == want.status, (cls.__name__, i, a)
            assert got.metrics == want.metrics, (cls.__name__, i, a)
    # Chunk rounded 4 -> 8: pad rows are counted, per-device view honest.
    s = es.summarize()
    assert s["n_devices"] == 8
    assert es.n_solves == len(systems) * SPACE.n_actions
    total = es.n_solves + es.n_pad_solves
    assert total % 8 == 0 and s["rows_per_device"] == total // 8
print("PARITY_ENGINE_OK")

# --- recompile accounting: one executable per bucket ----------------------
from repro.core.executor import batch_callable
from repro.solvers import gmres_ir_batch_lowerable
wrapped = batch_callable(ShardedExecutor(data=8), None,
                         gmres_ir_batch_lowerable(IR))
# One bucket, full action sweep already ran through this wrapper above:
# exactly one AOT-compiled executable in the per-shape cache.
assert len(wrapped.executables) == 1, sorted(wrapped.executables)
# An equal-valued executor + equal-valued lowerable reuse the same
# wrapper (computation_key collapses them — no new compile).
assert batch_callable(ShardedExecutor(data=8), None,
                      gmres_ir_batch_lowerable(IR)) is wrapped
print("PARITY_COMPILE_OK")

# --- service e2e through the sharded path ---------------------------------
import tempfile
from repro.core import TrainConfig, W1
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)

def serve(ex, root):
    train = [randsvd_dense(int(n), 50.0, np.random.default_rng(40 + i))
             for i, n in enumerate((10, 12, 14, 11))]
    task = GMRESIRTask(train, SPACE, IR, bucket_step=16, min_bucket=16,
                       executor=ex)
    reg, _, _ = PolicyRegistry.warm_start(root, task, W1,
                                          TrainConfig(episodes=2))
    serve_task = GMRESIRTask((), SPACE, IR, bucket_step=16, min_bucket=16,
                             executor=ex)
    srv = AutotuneServer(reg, serve_task, W1,
                         BatcherConfig(max_batch=4, max_wait_s=0.001,
                                       bucket_step=16, min_bucket=16),
                         OnlineConfig(eps0=0.0, eps_min=0.0), seed=0)
    reqs = [randsvd_dense(int(n), 100.0, np.random.default_rng(100 + i))
            for i, n in enumerate((10, 13, 12, 14, 11, 9))]
    ids = [srv.submit(s) for s in reqs]
    srv.drain()
    return srv, [srv.poll(i) for i in ids]

with tempfile.TemporaryDirectory() as tmp:
    srv_s, resp_s = serve(ShardedExecutor(data=8), tmp + "/s")
    srv_l, resp_l = serve(None, tmp + "/l")
# Flush size tracks mesh width: max_batch 4 -> 8-row flushes.
assert srv_s.batcher.flush_target(16) == 8
assert srv_l.batcher.flush_target(16) == 4
for rs, rl in zip(resp_s, resp_l):
    assert rs.action == rl.action
    assert rs.record.status == rl.record.status
    assert rs.record.metrics == rl.record.metrics
    assert rs.reward == rl.reward
tel = srv_s.telemetry.snapshot()
assert tel["solver_rows"] % 8 == 0
print("PARITY_SERVICE_OK")
"""


def test_sharded_parity_8_devices():
    """Full executor parity suite on a forced 8-device host mesh."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    out = subprocess.run([sys.executable, "-c", PARITY_8DEV], env=env,
                         capture_output=True, text=True, timeout=900)
    for marker in ("PARITY_BATCH_OK", "PARITY_ENGINE_OK",
                   "PARITY_COMPILE_OK", "PARITY_SERVICE_OK"):
        assert marker in out.stdout, (marker, out.stdout[-2000:],
                                      out.stderr[-3000:])
