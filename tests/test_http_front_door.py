"""Async HTTP front door: validation, async/sync solve round-trips,
exactly-once result retrieval, policy endpoint, explicit backpressure
under an overload burst, and graceful drain on shutdown."""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import GMRESIREnv, TrainConfig, W1, reduced_action_space
from repro.data import generate_dense_set
from repro.service import (AutotuneServer, BatcherConfig, OnlineConfig,
                           PolicyRegistry)
from repro.service.http import HttpConfig, serve_http
from repro.solvers import IRConfig

SPACE = reduced_action_space()
IR = IRConfig(tau=1e-6)
BCFG = BatcherConfig(max_batch=4, max_wait_s=0.002, bucket_step=16,
                     min_bucket=16)


def _http(method, url, payload=None, raw=None, timeout=60):
    if raw is not None:
        data = raw
    else:
        data = (json.dumps(payload).encode("utf-8")
                if payload is not None else None)
    req = urllib.request.Request(
        url, data=data, method=method,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read().decode("utf-8")), r.headers
    except urllib.error.HTTPError as e:
        body = e.read().decode("utf-8")
        return e.code, (json.loads(body) if body else {}), e.headers


def _payload(system, request_id=None, x_true=True):
    out = {"A": system.A.tolist(), "b": system.b.tolist()}
    if x_true:
        out["x_true"] = system.x_true.tolist()
    if request_id is not None:
        out["request_id"] = request_id
    return out


def _await_result(url, rid, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        code, body, _ = _http("GET", f"{url}/v1/result/{rid}")
        if code == 200:
            return body
        assert code == 202, body
        time.sleep(0.01)
    raise AssertionError(f"request {rid} never completed")


@pytest.fixture(scope="module")
def http_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("httpreg") / "reg")
    rng = np.random.default_rng(5)
    train = generate_dense_set(4, rng, n_range=(12, 20),
                               log10_kappa_range=(1, 4))
    env = GMRESIREnv(train, SPACE, IR, chunk=4, bucket_step=16)
    PolicyRegistry.warm_start(root, env, W1, TrainConfig(episodes=1))
    return root


@pytest.fixture()
def front_door(http_root):
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=False)
    fd = serve_http(srv, cfg=HttpConfig(max_n=64, flush_interval_s=0.002))
    yield fd
    fd.close()


def _systems(n, seed=11, n_range=(12, 20)):
    rng = np.random.default_rng(seed)
    return generate_dense_set(n, rng, n_range, log10_kappa_range=(1, 4))


# ---------------------------------------------------------------------------
# Validation + routing
# ---------------------------------------------------------------------------

def test_validation_rejects_bad_payloads(front_door):
    url = front_door.url
    sys0 = _systems(1)[0]

    code, body, _ = _http("POST", url + "/v1/solve", raw=b"not json")
    assert code == 400 and "JSON" in body["error"]

    bad = [
        {"A": sys0.A[:, :-1].tolist(), "b": sys0.b.tolist()},   # not square
        {"A": sys0.A.tolist(), "b": sys0.b[:-1].tolist()},      # b mismatch
        {"A": (sys0.A * np.nan).tolist(), "b": sys0.b.tolist()},
        {"A": sys0.A.tolist(), "b": sys0.b.tolist(), "oops": 1},
        {"A": sys0.A.tolist(), "b": sys0.b.tolist(),
         "x_true": sys0.x_true[:-1].tolist()},                  # len mismatch
        {"A": sys0.A.tolist(), "b": sys0.b.tolist(),
         "request_id": 17},                                     # non-string
        {"b": sys0.b.tolist()},                                 # A missing
        [1, 2, 3],                                              # not an object
    ]
    for payload in bad:
        code, body, _ = _http("POST", url + "/v1/solve", payload)
        assert code == 400, (payload, body)
        assert "error" in body

    big = np.eye(128)
    code, body, _ = _http("POST", url + "/v1/solve",
                          {"A": big.tolist(), "b": big[0].tolist()})
    assert code == 400 and "exceeds" in body["error"]


def test_unknown_routes_and_methods(front_door):
    url = front_door.url
    code, body, _ = _http("GET", url + "/nope")
    assert code == 404
    code, body, _ = _http("GET", url + "/v1/solve")
    assert code == 405
    code, body, _ = _http("POST", url + "/v1/policy")
    assert code == 405
    code, body, _ = _http("GET", url + "/v1/result/abc")
    assert code == 400


# ---------------------------------------------------------------------------
# Solve round-trips
# ---------------------------------------------------------------------------

def test_async_solve_roundtrip_exactly_once(front_door):
    url = front_door.url
    sys0 = _systems(1)[0]
    code, body, headers = _http("POST", url + "/v1/solve",
                                _payload(sys0, request_id="req-abc-1"))
    assert code == 202, body
    assert body["status"] == "queued"
    assert body["client_request_id"] == "req-abc-1"
    assert headers["X-Request-Id"] == "req-abc-1"
    rid = body["request_id"]
    assert isinstance(rid, int) and body["bucket"] in (16, 32)

    result = _await_result(url, rid)
    assert result["status"] == "done"
    assert result["request_id"] == rid
    assert result["client_request_id"] == "req-abc-1"
    assert result["policy_version"] == "v0001"
    assert isinstance(result["action_names"], list)
    assert result["outcome"]["status"] in (0, 1, 2, 3)
    assert result["has_x_true"] is True
    # Retrieval evicts: the id is gone afterwards.
    code, body, _ = _http("GET", f"{url}/v1/result/{rid}")
    assert code == 404


def test_sync_solve_and_missing_x_true(front_door):
    url = front_door.url
    sys0 = _systems(2, seed=12)[0]
    code, body, _ = _http("POST", url + "/v1/solve:sync", _payload(sys0))
    assert code == 200, body
    assert body["status"] == "done"
    assert "reward" in body and "eps" in body and "latency_s" in body

    code, body, _ = _http("POST", url + "/v1/solve:sync",
                          _payload(sys0, x_true=False))
    assert code == 200, body
    assert body["has_x_true"] is False


def test_sync_timeout_result_stays_retrievable(http_root):
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=False)
    fd = serve_http(srv, cfg=HttpConfig(max_n=64, sync_timeout_s=0.001,
                                        flush_interval_s=0.002))
    try:
        sys0 = _systems(1, seed=13)[0]
        code, body, _ = _http("POST", fd.url + "/v1/solve:sync",
                              _payload(sys0))
        assert code == 504, body
        assert body["status"] == "pending"
        result = _await_result(fd.url, body["request_id"])
        assert result["status"] == "done"
    finally:
        fd.close()


def test_policy_endpoint(front_door):
    code, body, _ = _http("GET", front_door.url + "/v1/policy")
    assert code == 200
    assert body["current"] == "v0001"
    assert body["policy_version"] == "v0001"
    assert body["versions"] == ["v0001"]
    assert body["history"] == ["v0001"]
    assert "rollout" not in body          # plain AutotuneServer


# ---------------------------------------------------------------------------
# Backpressure (acceptance): bounded queue, 429s, exactly-once answers
# ---------------------------------------------------------------------------

def test_backpressure_burst_bounded_and_exactly_once(http_root):
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=False)
    cfg = HttpConfig(max_n=64, max_queue_depth=3, flush_interval_s=0.05,
                     retry_after_s=2.0)
    fd = serve_http(srv, cfg=cfg)
    try:
        url = fd.url
        # Warm the bucket (first solve pays the XLA compile).
        warm = _systems(1, seed=14, n_range=(16, 16))[0]
        code, _, _ = _http("POST", url + "/v1/solve:sync", _payload(warm))
        assert code == 200

        burst = _systems(18, seed=15, n_range=(16, 16))
        out, lock = [], threading.Lock()

        def fire(system):
            code, body, headers = _http("POST", url + "/v1/solve",
                                        _payload(system))
            with lock:
                out.append((code, body, headers))

        threads = [threading.Thread(target=fire, args=(s,)) for s in burst]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        codes = [c for c, _, _ in out]
        assert len(out) == len(burst)
        assert set(codes) <= {202, 429}
        accepted = [b["request_id"] for c, b, _ in out if c == 202]
        rejected = [(b, h) for c, b, h in out if c == 429]
        assert rejected, "overload burst produced no 429s"
        assert len(accepted) + len(rejected) == len(burst)
        # Admission is bounded per bucket: never more in flight than the
        # cap plus what the pump already answered into the done store.
        assert len(set(accepted)) == len(accepted)
        for _, headers in rejected:
            assert int(headers["Retry-After"]) >= 1

        # No accepted request is lost, none is answered twice.
        for rid in accepted:
            result = _await_result(url, rid)
            assert result["request_id"] == rid
            code, _, _ = _http("GET", f"{url}/v1/result/{rid}")
            assert code == 404
        assert fd.queue_depth(16) == 0
    finally:
        fd.close()


# ---------------------------------------------------------------------------
# Drain + shutdown
# ---------------------------------------------------------------------------

def test_graceful_drain_answers_admitted_requests(http_root):
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, BCFG,
                         OnlineConfig(), seed=0, obs=False)
    fd = serve_http(srv, cfg=HttpConfig(max_n=64, flush_interval_s=10.0))
    rids = []
    for system in _systems(4, seed=16):
        code, body, _ = _http("POST", fd.url + "/v1/solve",
                              _payload(system))
        assert code == 202
        rids.append(body["request_id"])
    # The flush tick is far away: close() itself must drain and answer.
    fd.close()
    assert srv.pending == 0
    assert not fd._pending
    for rid in rids:
        assert fd._done[rid]["status"] == "done"


def test_drain_deadline_expiry_fails_pending_terminally(http_root):
    """A wedged solver cannot hold shutdown hostage: whatever is still
    unanswered when `drain_timeout_s` passes gets a terminal failure
    response — sync callers see 503, fire-and-poll callers find the
    failure in the done store — and nothing stays pending forever."""
    from repro import faults
    from repro.faults import FaultSpec

    # Nothing may flush on its own (huge max_wait, roomy max_batch):
    # the wedged drain must be the only way out.
    stuck = BatcherConfig(max_batch=64, max_wait_s=100.0, bucket_step=16,
                          min_bucket=16)
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, stuck,
                         OnlineConfig(), seed=0, obs=False)
    fd = serve_http(srv, cfg=HttpConfig(max_n=64, flush_interval_s=10.0,
                                        drain_timeout_s=0.3,
                                        sync_timeout_s=30.0))
    systems = _systems(3, seed=18)
    rids = []
    for system in systems[:2]:
        code, body, _ = _http("POST", fd.url + "/v1/solve",
                              _payload(system))
        assert code == 202
        rids.append(body["request_id"])

    sync_out = {}

    def sync_call():
        code, body, _ = _http("POST", fd.url + "/v1/solve:sync",
                              _payload(systems[2]))
        sync_out["code"], sync_out["body"] = code, body

    t = threading.Thread(target=sync_call)
    t.start()
    deadline = time.monotonic() + 10.0
    while len(fd._pending) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)          # wait for the sync request to admit
    assert len(fd._pending) == 3

    # Every flush attempt during the drain raises: the deadline, not a
    # successful drain, ends the shutdown.
    with faults.injected(FaultSpec("batcher.flush", "raise")):
        fd.close()
    t.join(timeout=10.0)
    assert not t.is_alive()

    assert not fd._pending
    for rid in rids:
        payload = fd._done[rid]
        assert payload["status"] == "failed"
        assert "error" in payload
    assert sync_out["code"] == 503, sync_out
    assert sync_out["body"]["status"] == "failed"


def test_flush_loop_supervisor_restarts_after_crash(http_root):
    """An exception escaping the background flush loop is counted and
    the loop restarted — requests admitted around the crash still get
    answered."""
    from repro import faults
    from repro.faults import FaultSpec

    # max_wait keeps the flush out of submit()'s auto-step (which would
    # turn the injected raise into a 500): only the background loop,
    # whose supervisor is under test, ever flushes.
    lazy = BatcherConfig(max_batch=4, max_wait_s=0.05, bucket_step=16,
                         min_bucket=16)
    srv = AutotuneServer(PolicyRegistry(http_root), IR, W1, lazy,
                         OnlineConfig(), seed=0, obs=False)
    fd = serve_http(srv, cfg=HttpConfig(max_n=64, flush_interval_s=0.005))
    try:
        with faults.injected(FaultSpec("batcher.flush", "raise",
                                       max_fires=2)):
            sys0 = _systems(1, seed=19)[0]
            code, body, _ = _http("POST", fd.url + "/v1/solve",
                                  _payload(sys0))
            assert code == 202
            result = _await_result(fd.url, body["request_id"])
        assert result["status"] == "done"
        assert fd.flush_restarts >= 1
    finally:
        fd.close()


def test_draining_rejects_new_work(front_door):
    sys0 = _systems(1, seed=17)[0]
    front_door._draining = True
    try:
        code, body, _ = _http("POST", front_door.url + "/v1/solve",
                              _payload(sys0))
        assert code == 503
    finally:
        front_door._draining = False
    code, _, _ = _http("POST", front_door.url + "/v1/solve:sync",
                       _payload(sys0))
    assert code == 200
