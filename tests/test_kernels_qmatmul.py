"""Shape/format sweeps: fused qmatmul kernel vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmatmul import qmatmul_op, qmatmul_ref, qmatmul_ref_blocked
from repro.precision import FORMAT_ID, FORMATS

RNG = np.random.default_rng(11)

SHAPES = [(32, 128, 128), (64, 256, 128), (100, 130, 70), (8, 512, 256),
          (256, 512, 256)]
FMTS = ["e5m2", "e4m3", "bf16", "fp16", "tf32", "fp32"]


def _mats(M, K, N):
    a = jnp.asarray(RNG.standard_normal((M, K)).astype(np.float32))
    b = jnp.asarray(RNG.standard_normal((K, N)).astype(np.float32))
    return a, b


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_qmatmul_vs_blocked_ref(shape, fmt):
    """Bit-exact for coarse output formats; for fine formats, XLA's gemm
    reduction order varies with tile shape, so the bound is the f32
    accumulation noise plus one output ulp."""
    M, K, N = shape
    bk = 128
    a, b = _mats(M, K, N)
    got = np.asarray(qmatmul_op(a, b, FORMAT_ID[fmt], bm=32, bn=128, bk=bk))
    Kp = -(-K // bk) * bk
    ap = jnp.pad(a, ((0, 0), (0, Kp - K)))
    bp = jnp.pad(b, ((0, Kp - K), (0, 0)))
    want = np.asarray(qmatmul_ref_blocked(ap, bp, FORMAT_ID[fmt], bk))
    f = FORMATS[fmt]
    if f.t <= 8:
        np.testing.assert_array_equal(got, want)
    else:
        scale = np.abs(want) + np.sqrt(K)
        tol = 4 * f.unit_roundoff + 8 * np.sqrt(K) * np.finfo(np.float32).eps
        assert np.max(np.abs(got - want) / scale) <= tol


@pytest.mark.parametrize("fmt", ["bf16", "fp32"])
def test_qmatmul_close_to_mathematical_ref(fmt):
    """Accumulation-order differences stay within ~1 output ulp."""
    a, b = _mats(128, 512, 128)
    got = np.asarray(qmatmul_op(a, b, FORMAT_ID[fmt], bm=64, bn=128, bk=128))
    want = np.asarray(qmatmul_ref(a, b, FORMAT_ID[fmt]))
    u = FORMATS[fmt].unit_roundoff
    scale = np.abs(want) + np.sqrt(512)
    tol = 4 * u + 8 * np.sqrt(512) * np.finfo(np.float32).eps
    assert np.max(np.abs(got - want) / scale) <= tol


def test_qmatmul_emulates_precision_loss():
    a, b = _mats(64, 128, 64)
    exact = np.asarray(a @ b)
    lo = np.asarray(qmatmul_op(a, b, FORMAT_ID["e4m3"], bm=32, bn=128,
                               bk=128))
    hi = np.asarray(qmatmul_op(a, b, FORMAT_ID["fp32"], bm=32, bn=128,
                               bk=128))
    err_lo = np.abs(lo - exact).mean()
    err_hi = np.abs(hi - exact).mean()
    assert err_lo > 10 * err_hi


def test_qmatmul_chop_out_flag():
    a, b = _mats(32, 128, 128)
    with_chop = np.asarray(qmatmul_op(a, b, FORMAT_ID["bf16"],
                                      chop_out=True, bm=32, bn=128, bk=128))
    no_chop = np.asarray(qmatmul_op(a, b, FORMAT_ID["bf16"],
                                    chop_out=False, bm=32, bn=128, bk=128))
    # Unchopped accumulator has values not representable in bf16.
    from repro.precision import chop_static
    assert np.array_equal(
        np.asarray(chop_static(jnp.asarray(no_chop), "bf16")), with_chop)
    assert not np.array_equal(with_chop, no_chop)
