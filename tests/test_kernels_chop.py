"""Shape/dtype/format sweeps: Pallas chop kernel vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chop import chop_op, chop_ref
from repro.precision import FORMAT_ID, FORMAT_LIST

RNG = np.random.default_rng(7)

SHAPES = [(8, 128), (256, 128), (1000,), (3, 5, 7), (1, 1), (4096,),
          (17, 129), (2, 384, 64)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", [f.name for f in FORMAT_LIST])
def test_chop_kernel_matches_ref(shape, fmt):
    x = (RNG.standard_normal(shape) *
         np.exp(RNG.uniform(-40, 40, shape))).astype(np.float32)
    fid = FORMAT_ID[fmt]
    got = np.asarray(chop_op(jnp.asarray(x), fid, interpret=True))
    want = np.asarray(chop_ref(jnp.asarray(x), fid))
    np.testing.assert_array_equal(got, want)


def test_chop_kernel_specials():
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 1e38, -1e-40],
                    jnp.float32)
    for f in FORMAT_LIST:
        got = np.asarray(chop_op(x, FORMAT_ID[f.name], interpret=True))
        want = np.asarray(chop_ref(x, FORMAT_ID[f.name]))
        np.testing.assert_array_equal(got, want)


def test_chop_kernel_block_rows_sweep():
    x = jnp.asarray(RNG.standard_normal(5000).astype(np.float32))
    want = np.asarray(chop_ref(x, FORMAT_ID["bf16"]))
    for br in (8, 64, 256):
        got = np.asarray(chop_op(x, FORMAT_ID["bf16"], block_rows=br,
                                 interpret=True))
        np.testing.assert_array_equal(got, want)


def test_chop_kernel_runtime_format_single_program():
    """All format ids through one jitted call signature."""
    x = jnp.asarray(RNG.standard_normal((64, 128)).astype(np.float32))
    for f in FORMAT_LIST:
        got = np.asarray(chop_op(x, jnp.int32(FORMAT_ID[f.name]),
                                 interpret=True))
        want = np.asarray(chop_ref(x, FORMAT_ID[f.name]))
        np.testing.assert_array_equal(got, want)


def test_chop_kernel_rejects_f64():
    with pytest.raises(TypeError):
        chop_op(jnp.zeros((8,), jnp.float64), 0, interpret=True)
