"""Docs integrity gate (run by the CI docs job).

Fails on:
  * broken intra-repo markdown links (``[text](relative/path)``),
  * source citations of markdown files that do not exist in the repo,
  * ``DESIGN.md §x.y`` citations whose section is missing from
    docs/DESIGN.md.

Pure-stdlib static checks — no jax import, safe to run anywhere.
"""
import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SOURCE_DIRS = ("src", "tests", "benchmarks", "examples", "docs")
SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", "results", "artifacts",
             ".github", ".claude"}

# Markdown files whose names may legitimately appear in prose without
# existing in-repo (e.g. generic mentions inside strings).
ALLOWED_MISSING = set()


def _walk(exts):
    # Repo root: top-level files only (no recursion — a stray .venv or
    # node_modules must not feed the gate).
    for f in sorted(os.listdir(REPO)):
        path = os.path.join(REPO, f)
        if os.path.isfile(path) and f.endswith(exts):
            yield path
    for d in SOURCE_DIRS:
        base = os.path.join(REPO, d)
        if not os.path.isdir(base):
            continue
        for root, dirs, files in os.walk(base):
            dirs[:] = [x for x in dirs if x not in SKIP_DIRS]
            for f in files:
                if f.endswith(exts):
                    yield os.path.join(root, f)


def _md_files():
    top = [os.path.join(REPO, f) for f in os.listdir(REPO)
           if f.endswith(".md")]
    docs = [os.path.join(r, f)
            for r, ds, fs in os.walk(os.path.join(REPO, "docs"))
            for f in fs if f.endswith(".md")]
    return top + docs


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_markdown_relative_links_resolve():
    broken = []
    for path in _md_files():
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target_path = target.split("#", 1)[0]
            if not target_path:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target_path))
            if not os.path.exists(resolved):
                broken.append(f"{os.path.relpath(path, REPO)} -> {target}")
    assert not broken, "broken intra-repo markdown links:\n" + "\n".join(broken)


# Citations of markdown files from source: "DESIGN.md", "PAPER_MAP.md", ...
_MD_CITE = re.compile(r"\b([A-Za-z][A-Za-z0-9_]*\.md)\b")


def _repo_md_basenames():
    names = {}
    for path in _md_files():
        names.setdefault(os.path.basename(path), path)
    return names


def test_source_md_citations_exist():
    known = _repo_md_basenames()
    missing = []
    for path in _walk((".py",)):
        rel = os.path.relpath(path, REPO)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for name in set(_MD_CITE.findall(text)):
            if name in ALLOWED_MISSING:
                continue
            if name not in known:
                missing.append(f"{rel} cites {name}")
    assert not missing, ("source cites non-existent markdown files:\n"
                         + "\n".join(sorted(set(missing))))


# "DESIGN.md §3.4", "DESIGN §3", "(DESIGN §4)" — all normalize to a
# section number that must exist as a DESIGN.md heading.
_DESIGN_CITE = re.compile(r"DESIGN(?:\.md)?\s*§\s*([0-9]+(?:\.[0-9]+)*)")
_HEADING = re.compile(r"^#{1,6}\s+([0-9]+(?:\.[0-9]+)*)\b", re.MULTILINE)


def _design_sections():
    path = os.path.join(REPO, "docs", "DESIGN.md")
    assert os.path.exists(path), "docs/DESIGN.md is missing"
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    sections = set(_HEADING.findall(text))
    # §3.4 implies §3 exists as a chapter even if only subsections are
    # numbered; keep the check strict the other way round only.
    return sections


def test_design_section_citations_resolve():
    sections = _design_sections()
    unresolved = []
    for path in _walk((".py", ".md")):
        rel = os.path.relpath(path, REPO)
        if rel == os.path.join("docs", "DESIGN.md"):
            continue
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for sec in _DESIGN_CITE.findall(text):
            if sec not in sections:
                unresolved.append(f"{rel} cites DESIGN.md §{sec}")
    assert not unresolved, ("DESIGN.md citations of missing sections:\n"
                            + "\n".join(sorted(set(unresolved))))


def test_design_covers_advertised_sections():
    """The sections the issue/code contract names must stay present."""
    sections = _design_sections()
    for sec in ("3.3", "3.4", "3.5", "4", "5", "6", "6.2", "6.3"):
        assert sec in sections, f"DESIGN.md lost §{sec}"


def test_paper_map_module_paths_exist():
    path = os.path.join(REPO, "docs", "PAPER_MAP.md")
    assert os.path.exists(path), "docs/PAPER_MAP.md is missing"
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    missing = []
    for ref in re.findall(r"`((?:src|tests|benchmarks|examples)/[^`]*)`",
                          text):
        target = ref.split("::", 1)[0]
        if not os.path.exists(os.path.join(REPO, target)):
            missing.append(ref)
    assert not missing, ("PAPER_MAP.md references missing paths:\n"
                         + "\n".join(missing))
