"""Serving-loop tests: prefill+decode equivalence, KV-format knob."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import forward, init_params
from repro.precision import FORMAT_ID
from repro.serve import ServeConfig, generate

KEY = jax.random.PRNGKey(0)


def test_generate_greedy_matches_argmax_forward():
    """Greedy generation must equal repeated argmax over full forwards."""
    cfg = get_smoke("granite-3-2b")
    params = init_params(cfg, KEY, jnp.float32)
    prompts = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    new = 5
    got = np.asarray(generate(params, prompts, cfg,
                              ServeConfig(max_new_tokens=new,
                                          compute_dtype=jnp.float32), KEY))
    # reference: autoregressive full forward
    seq = prompts
    ref = []
    for _ in range(new):
        logits = forward(params, seq, cfg, jnp.float32)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(got, np.stack(ref, axis=1))


def test_generate_with_reduced_kv_cache_stays_reasonable():
    cfg = get_smoke("gemma-2b")
    params = init_params(cfg, KEY, jnp.float32)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    full = np.asarray(generate(params, prompts, cfg,
                               ServeConfig(max_new_tokens=8,
                                           compute_dtype=jnp.float32), KEY))
    bf16 = np.asarray(generate(
        params, prompts, cfg,
        ServeConfig(max_new_tokens=8, compute_dtype=jnp.float32,
                    cache_fmt=FORMAT_ID["bf16"]), KEY))
    # bf16 KV cache: most tokens agree with the fp32-cache reference
    assert np.mean(full == bf16) > 0.5


def test_sampled_generation_shape_and_range():
    cfg = get_smoke("musicgen-large")
    params = init_params(cfg, KEY, jnp.float32)
    prompts = jax.random.randint(KEY, (3, 4), 0, cfg.vocab_size)
    toks = np.asarray(generate(params, prompts, cfg,
                               ServeConfig(max_new_tokens=6, temperature=1.0,
                                           compute_dtype=jnp.float32), KEY))
    assert toks.shape == (3, 6)
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
