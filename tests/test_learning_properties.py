"""Property-based tests of the core learning math (Discretizer binning,
reward shape, online epsilon control).

Uses `hypothesis` when installed; otherwise `tests/_hypothesis_stub.py`
(registered by conftest) provides a deterministic boundary-inclusive
sweep over the same strategy API, so these properties are exercised in
every environment.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Discretizer, RewardConfig, W1, accuracy_term,
                        penalty_term, precision_term, reward)
from repro.precision import FORMAT_ID
from repro.service import OnlineConfig
from repro.service.online import EpsilonController
from repro.solvers.ir import CONVERGED, FAILED

pytestmark = pytest.mark.fast

FEATS = np.array([[0.0, -3.0], [2.5, 1.0], [10.0, 7.0]])
DISC = Discretizer.fit(FEATS, (7, 4))


# ---------------------------------------------------------------------------
# Discretizer (Eq. 19-20)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.floats(-1e9, 1e9), st.floats(-1e9, 1e9),
       st.floats(0.0, 5.0), st.floats(0.0, 5.0))
def test_prop_bin_mapping_is_componentwise_monotone(a, b, da, db):
    """Growing any feature never decreases its bin index (the bins tile
    an interval; clipping at the edges preserves monotonicity)."""
    lo = DISC.bin_indices(np.array([a, b]))[0]
    hi = DISC.bin_indices(np.array([a + da, b + db]))[0]
    assert lo[0] <= hi[0] and lo[1] <= hi[1]


@settings(max_examples=60, deadline=None)
@given(st.floats(allow_nan=False), st.floats(allow_nan=False))
def test_prop_no_out_of_range_bins(a, b):
    """Any finite (even astronomically out-of-range) feature vector maps
    to a valid flat state — Eq. 19's clipping, with no exceptions."""
    s = int(DISC(np.array([a, b])))
    assert 0 <= s < DISC.n_states
    idx = DISC.bin_indices(np.array([a, b]))[0]
    assert all(0 <= idx[j] < DISC.n_bins[j] for j in range(DISC.d))


@settings(max_examples=30, deadline=None)
@given(st.floats(-1e6, 1e6), st.floats(-1e6, 1e6))
def test_prop_degenerate_single_bin_features(x, q):
    """A constant feature column (single training instance, or a
    feature that never varies) maps every query to bin 0 instead of an
    arbitrary floor() artifact."""
    d = Discretizer.fit(np.array([[x, 0.0], [x, 4.0]]), (5, 2))
    idx = d.bin_indices(np.array([q, 0.0]))[0]
    assert idx[0] == 0                       # degenerate axis pins to 0
    assert d.n_states == 10                  # state space is unchanged
    s = int(d(np.array([q, 4.0])))
    assert 0 <= s < d.n_states


def test_single_bin_everywhere_is_one_state():
    d = Discretizer.fit(FEATS, (1, 1))
    assert d.n_states == 1
    for v in ([-1e30, 1e30], [0.0, 0.0], [5.0, -5.0]):
        assert int(d(np.array(v))) == 0


# ---------------------------------------------------------------------------
# Reward shape (Eq. 21-25)
# ---------------------------------------------------------------------------

ACT = np.full(4, FORMAT_ID["fp32"])


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 10**6), st.integers(0, 10**5),
       st.floats(1.0, 1e12))
def test_prop_reward_monotone_nonincreasing_in_cost(iters, extra, kappa):
    """More solver iterations never pays more (penalty_term is
    non-decreasing in cost; every other term is cost-independent)."""
    cfg = RewardConfig()        # use_penalty=True
    r_cheap = reward(1e-10, 1e-12, iters, CONVERGED, ACT, kappa, cfg)
    r_dear = reward(1e-10, 1e-12, iters + extra, CONVERGED, ACT, kappa,
                    cfg)
    assert r_dear <= r_cheap
    assert penalty_term(iters + extra) >= penalty_term(iters)


@settings(max_examples=60, deadline=None)
@given(st.floats(1e-20, 1e10), st.floats(1e-20, 1e10),
       st.integers(1, 10**4), st.floats(1.0, 1e12))
def test_prop_reward_bounded_and_finite(ferr, nbe, iters, kappa):
    """Converged rewards are finite and bounded by the per-term caps:
    accuracy is theta-capped / eps-floored (Eq. 24), precision is at
    most 4 * 53/8 (all-fp64 numerator at kappa -> 1), penalty >= 0."""
    cfg = W1
    r = reward(ferr, nbe, iters, CONVERGED, ACT, kappa, cfg)
    assert np.isfinite(r)
    acc_hi = -2.0 * cfg.C1 * np.log10(cfg.eps)
    acc_lo = -2.0 * cfg.C1 * cfg.theta
    prec_hi = 4 * 53.0 / 8.0
    assert r <= cfg.w1 * acc_hi + cfg.w2 * prec_hi + 1e-9
    assert r >= cfg.w1 * acc_lo - cfg.w3 * penalty_term(iters) - 1e-9
    # Failure short-circuits every term to the flat fail reward.
    assert reward(ferr, nbe, iters, FAILED, ACT, kappa, cfg) \
        == cfg.fail_reward


@settings(max_examples=40, deadline=None)
@given(st.floats(1.0, 1e15), st.floats(1.0, 1e15))
def test_prop_precision_term_damps_with_kappa(k1, k2):
    lo, hi = sorted((k1, k2))
    bf = np.full(4, FORMAT_ID["bf16"])
    assert precision_term(bf, hi) <= precision_term(bf, lo) + 1e-12
    assert precision_term(bf, lo) > 0.0


@settings(max_examples=40, deadline=None)
@given(st.floats(1e-18, 1e6), st.floats(1e-18, 1e6),
       st.floats(1e-18, 1e6))
def test_prop_accuracy_term_monotone_in_error(e1, e2, nbe):
    lo, hi = sorted((e1, e2))
    cfg = RewardConfig()
    assert accuracy_term(hi, nbe, cfg) <= accuracy_term(lo, nbe, cfg) \
        + 1e-9


# ---------------------------------------------------------------------------
# Online epsilon control (service.online)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2000), st.integers(1, 800))
def test_prop_epsilon_decays_monotonically_to_floor(steps, decay):
    cfg = OnlineConfig(eps0=0.10, eps_min=0.02, decay_updates=decay)
    eps = EpsilonController(cfg)
    prev = eps.value
    assert prev == cfg.eps0
    for _ in range(steps):
        eps.step()
        cur = eps.value
        assert cur <= prev + 1e-12           # never re-opens on its own
        assert cfg.eps_min <= cur <= cfg.eps0
        prev = cur
    if steps >= decay:
        # Floor reached (up to anneal-arithmetic rounding), stays there.
        assert eps.value == pytest.approx(cfg.eps_min, abs=1e-12)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_prop_epsilon_boost_reopens_then_reanneals(steps):
    cfg = OnlineConfig(eps0=0.10, eps_min=0.02, eps_boost=0.5,
                       decay_updates=100)
    eps = EpsilonController(cfg)
    for _ in range(steps):
        eps.step()
    eps.boost()
    assert eps.value == cfg.eps_boost        # drift re-opens exploration
    for _ in range(cfg.decay_updates):
        eps.step()
    # Re-anneals to the floor (up to anneal-arithmetic rounding).
    assert eps.value == pytest.approx(cfg.eps_min, abs=1e-12)
