"""Unit + property tests for the precision substrate (formats + chop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from oracle import chop_oracle_array
from repro.precision import (FORMAT_ID, FORMAT_LIST, FORMATS, SOLVER_LADDER,
                             chop, chop_matmul, chop_static, chop_tree,
                             format_id, get_format, rounding_unit)

RNG = np.random.default_rng(1234)


def wide_randoms(n, lo=-300, hi=300, dtype=np.float64):
    x = RNG.standard_normal(n) * np.exp(RNG.uniform(lo, hi, n))
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Format descriptors: paper Table 1
# ---------------------------------------------------------------------------

# NOTE: the paper's Table 1 row for TF32 is internally inconsistent: it lists
# t=11 but u=9.77e-4 (=2^-10; with the u=2^-t convention used by every other
# row, t=11 gives 4.88e-4), and xmax=1.70e38 (=2^127; the t=11/emax=127
# format max is 3.40e38, matching NVIDIA's TF32). We implement the standard
# convention (u=2^-t) and assert the paper's values for the other four rows.
@pytest.mark.parametrize("name,u,xmin,xmax,t,emin,emax", [
    ("bf16", 3.91e-3, 1.18e-38, 3.39e38, 8, -126, 127),
    ("fp16", 4.88e-4, 6.10e-5, 6.55e4, 11, -14, 15),
    ("tf32", 4.88e-4, 1.18e-38, 3.40e38, 11, -126, 127),
    ("fp32", 5.96e-8, 1.18e-38, 3.40e38, 24, -126, 127),
    ("fp64", 1.11e-16, 2.23e-308, 1.797e308, 53, -1022, 1023),
])
def test_table1_parameters(name, u, xmin, xmax, t, emin, emax):
    f = FORMATS[name]
    assert f.t == t and f.emin == emin and f.emax == emax
    assert np.isclose(f.unit_roundoff, u, rtol=0.01)
    assert np.isclose(f.xmin, xmin, rtol=0.05)
    assert np.isclose(f.xmax, xmax, rtol=0.06)


def test_solver_ladder_ordering():
    """Eq. 11's ordering: increasing significand bits along the ladder."""
    ts = [FORMATS[n].t for n in SOLVER_LADDER]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)
    ids = [format_id(n) for n in SOLVER_LADDER]
    assert ids == sorted(ids)


def test_format_lookup():
    assert get_format("bf16") is FORMATS["bf16"]
    assert get_format(FORMAT_ID["tf32"]).name == "tf32"
    assert format_id(FORMATS["fp32"]) == FORMAT_ID["fp32"]


# ---------------------------------------------------------------------------
# chop vs exact Fraction oracle (the definitive correctness test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [f.name for f in FORMAT_LIST])
@pytest.mark.parametrize("carrier", [np.float32, np.float64])
def test_chop_matches_oracle(name, carrier):
    f = FORMATS[name]
    if carrier == np.float32 and f.name in ("fp32", "fp64"):
        pytest.skip("identity on this carrier")
    x = wide_randoms(500).astype(carrier)
    # Add boundary values: around xmax, xmin, subnormal min, exact powers.
    extra = np.array([f.xmax, f.xmax * (1 + 1e-3), f.xmin, f.xmin / 2,
                      f.xmin_sub, f.xmin_sub / 3, 1.0, -1.0, 2.0 ** 20,
                      1 + f.unit_roundoff, 1 + 2 * f.unit_roundoff],
                     dtype=carrier)
    x = np.concatenate([x, extra, -extra])
    got = np.asarray(chop_static(jnp.asarray(x), name))
    want = chop_oracle_array(x.astype(np.float64), f).astype(carrier)
    if f.saturate:  # oracle saturates finite; ours keeps inf->inf
        pass
    np.testing.assert_array_equal(got[np.isfinite(x)], want[np.isfinite(x)])


def test_chop_specials():
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan], jnp.float64)
    for name in FORMATS:
        y = np.asarray(chop_static(x, name))
        assert y[0] == 0 and np.signbit(y[1]) and np.isposinf(y[2])
        assert np.isneginf(y[3]) and np.isnan(y[4])


def test_chop_native_cast_bitexact_f32_carrier():
    """On an f32 carrier, chop == XLA native casts for normal-range values."""
    x = jnp.asarray(wide_randoms(20000, -80, 80, np.float32))
    for name, dt in [("bf16", jnp.bfloat16), ("fp16", jnp.float16)]:
        ours = np.asarray(chop_static(x, name))
        nat = np.asarray(x.astype(dt).astype(jnp.float32))
        keep = np.abs(np.asarray(x)) >= FORMATS[name].xmin  # XLA casts FTZ
        np.testing.assert_array_equal(ours[keep], nat[keep])


def test_chop_runtime_id_equals_static():
    x = jnp.asarray(wide_randoms(5000))
    for name, fid in FORMAT_ID.items():
        np.testing.assert_array_equal(np.asarray(chop(x, fid)),
                                      np.asarray(chop_static(x, name)))


def test_chop_traced_format_id_jit():
    """A single compiled program must serve all format ids (DESIGN §3.4)."""
    f = jax.jit(lambda x, i: chop(x, i))
    x = jnp.asarray(wide_randoms(1000))
    n_compiles = 0
    for name, fid in FORMAT_ID.items():
        y = f(x, jnp.int32(fid))
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(chop_static(x, name)))
    assert f._cache_size() == 1


def test_chop_vmappable_over_formats():
    x = jnp.asarray(wide_randoms(100))
    ids = jnp.arange(len(FORMAT_LIST), dtype=jnp.int32)
    ys = jax.vmap(lambda i: chop(x, i))(ids)
    for k, f in enumerate(FORMAT_LIST):
        np.testing.assert_array_equal(np.asarray(ys[k]),
                                      np.asarray(chop_static(x, f.name)))


def test_fp64_identity_on_f64():
    x = jnp.asarray(wide_randoms(1000))
    np.testing.assert_array_equal(np.asarray(chop(x, FORMAT_ID["fp64"])),
                                  np.asarray(x))


def test_chop_tree():
    tree = {"a": jnp.ones((3,), jnp.float64) * (1 + 2.0 ** -20),
            "b": (jnp.arange(3), jnp.float64(2.5e-5))}
    out = chop_tree(tree, FORMAT_ID["bf16"])
    assert np.all(np.asarray(out["a"]) == 1.0)          # rounded
    assert out["b"][0].dtype == jnp.arange(3).dtype      # ints untouched


def test_rounding_unit():
    for name, f in FORMATS.items():
        assert float(rounding_unit(FORMAT_ID[name], jnp.float64)) == f.unit_roundoff


def test_chop_matmul_emulates_low_precision():
    a = jnp.asarray(RNG.standard_normal((64, 64)))
    b = jnp.asarray(RNG.standard_normal((64, 64)))
    exact = a @ b
    lo = chop_matmul(a, b, FORMAT_ID["bf16"])
    hi = chop_matmul(a, b, FORMAT_ID["fp64"])
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(exact))
    err = np.abs(np.asarray(lo - exact)) / np.abs(np.asarray(exact))
    u = FORMATS["bf16"].unit_roundoff
    assert np.median(err) > 1e-6            # genuinely lossy
    assert np.median(err) < 64 * u          # but bounded by ~n*u


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

FMT_NAMES = [f.name for f in FORMAT_LIST]


@settings(max_examples=200, deadline=None)
@given(st.floats(allow_nan=False, allow_infinity=False, width=64),
       st.sampled_from(FMT_NAMES))
def test_prop_idempotent(v, name):
    x = jnp.asarray([v], jnp.float64)
    once = chop_static(x, name)
    twice = chop_static(once, name)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=1e-30, max_value=1e30), st.sampled_from(FMT_NAMES))
def test_prop_relative_error_bounded(v, name):
    """|chop(x) - x| <= u |x| for x in the format's normal range."""
    f = FORMATS[name]
    if not (f.xmin <= v <= f.xmax):
        return
    y = float(chop_static(jnp.asarray([v], jnp.float64), name)[0])
    assert abs(y - v) <= f.unit_roundoff * abs(v) * (1 + 1e-12)


@settings(max_examples=200, deadline=None)
@given(st.floats(allow_nan=False, width=64),
       st.floats(allow_nan=False, width=64),
       st.sampled_from(FMT_NAMES))
def test_prop_monotone(a, b, name):
    lo, hi = (a, b) if a <= b else (b, a)
    x = jnp.asarray([lo, hi], jnp.float64)
    y = np.asarray(chop_static(x, name))
    assert y[0] <= y[1] or (np.isnan(y[0]) or np.isnan(y[1]))


@settings(max_examples=200, deadline=None)
@given(st.floats(allow_nan=False, allow_infinity=False, width=64),
       st.sampled_from(FMT_NAMES))
def test_prop_odd_symmetry(v, name):
    x = jnp.asarray([v, -v], jnp.float64)
    y = np.asarray(chop_static(x, name))
    assert y[0] == -y[1] or (np.isnan(y[0]) and np.isnan(y[1]))


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=-126, max_value=127), st.sampled_from(FMT_NAMES))
def test_prop_powers_of_two_fixed(e, name):
    """Every in-range power of two is exactly representable in every format."""
    f = FORMATS[name]
    if not (f.emin <= e <= f.emax):
        return
    v = float(2.0 ** e)
    y = float(chop_static(jnp.asarray([v], jnp.float64), name)[0])
    assert y == v
