"""AOT bucket-grid warmup + persistent compile cache (DESIGN.md §12).

In-process tests cover the pure planning layer (traffic-priority bucket
order, grid enumeration), warm-equals-cold bit-identity, executable
dedupe across tasks, warmup no-ops on already-warm engines, and the two
server warmup modes: ``warmup="sync"`` must make the first live request
compile-free, ``warmup="background"`` must flip the `/readyz` warm gate
per bucket in priority order while traffic is already flowing.

The persistent-cache contract — a restarted server rebuilds its grid
from ``REPRO_COMPILE_CACHE_DIR`` with ZERO fresh XLA compiles — runs as
two subprocess boots sharing one cache directory, asserted on the
jax compilation-cache hit/miss counters (never on wall time).
"""
import json
import os
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import (Discretizer, LocalExecutor, QTable, computation_key,
                        reduced_action_space)
from repro.core import aot
from repro.core import executor as EX
from repro.core.engine import AutotuneEngine
from repro.core.executor import batch_callable
from repro.core.features import PAPER_FEATURES
from repro.core.policy import PrecisionPolicy
from repro.data import generate_dense_set
from repro.data.matrices import randsvd_dense
from repro.obs import Observability
from repro.service import AutotuneServer, BatcherConfig
from repro.solvers import IRConfig, gmres_ir_batch_lowerable
from repro.tasks import GMRESIRTask
from repro.tasks.base import stack_fixed

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
SPACE = reduced_action_space()
BCFG = BatcherConfig(max_batch=2, max_wait_s=0.001, bucket_step=16,
                     min_bucket=16)

# Every compiling test uses its own tau so its grid cells are genuinely
# cold — the per-shape executable caches are process-global.


def _ir(tau):
    return IRConfig(tau=tau, i_max=4, m_max=12)


def _policy():
    nf = len(PAPER_FEATURES)
    feats = np.random.default_rng(0).normal(size=(8, nf))
    disc = Discretizer.fit(feats, [2] * nf)
    return PrecisionPolicy(SPACE, disc,
                           QTable(disc.n_states, SPACE.n_actions))


def _systems(k, seed=0):
    return generate_dense_set(k, np.random.default_rng(seed),
                              n_range=(12, 14),
                              log10_kappa_range=(3, 4))


def _readyz(url):
    try:
        with urllib.request.urlopen(url + "/readyz", timeout=10) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# ---------------------------------------------------------------------------
# Planning layer: traffic priority + grid enumeration (pure, no jax)
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_order_buckets_traffic_priority(tmp_path):
    # No traffic: smallest first (fastest compiles flip /readyz first).
    assert aot.order_buckets([48, 16, 32]) == [16, 32, 48]
    # Most-seen first; size breaks ties.
    assert aot.order_buckets([16, 32, 48],
                             traffic={32: 5, 48: 5}) == [32, 48, 16]
    # Trajectory-log counts add onto explicit traffic.
    p = tmp_path / "traj.jsonl"
    rows = [{"bucket": 48}] * 3 + [{"bucket": 16}, {"other": 1}]
    p.write_text("\n".join(json.dumps(r) for r in rows) + "\nnot json\n")
    assert aot.order_buckets([16, 32, 48],
                             trajectory_path=str(p)) == [48, 16, 32]
    # Fail-open: unreadable path reads as no traffic.
    assert aot.bucket_traffic(str(tmp_path / "missing.jsonl")) == {}
    assert aot.bucket_traffic(None) == {}


@pytest.mark.fast
def test_plan_enumerates_tasks_per_bucket_in_priority_order():
    t1, t2 = object(), object()
    entries = aot.plan([t1, t2], [32, 16], chunk=4, traffic={32: 9})
    assert [(e.task, e.bucket, e.chunk) for e in entries] == [
        (t1, 32, 4), (t2, 32, 4), (t1, 16, 4), (t2, 16, 4)]
    labels = entries[0].labels()
    assert set(labels) == {"task", "bucket", "backend", "executor"}
    assert labels["bucket"] == 32


@pytest.mark.fast
def test_enable_persistent_cache_noop_without_dir(monkeypatch):
    monkeypatch.delenv(aot.ENV_CACHE_DIR, raising=False)
    # No kwarg, no env: nothing changes (returns whatever is in force).
    assert aot.enable_persistent_cache() == aot.cache_stats()["dir"]


# ---------------------------------------------------------------------------
# Warm == cold bit-identity, dedupe, warm-engine no-op
# ---------------------------------------------------------------------------

def test_aot_executable_bitmatches_plain_dispatch():
    """The dispatcher's AOT-compiled route must be bit-identical to the
    plain jitted call — same entry point, same coercion, same shapes."""
    import jax
    cfg = _ir(2.5e-6)
    low = gmres_ir_batch_lowerable(cfg)
    rng = np.random.default_rng(3)
    from repro.core import pad_to_bucket
    row = pad_to_bucket(randsvd_dense(13, 1e3, rng), 16, 16)
    act = np.asarray(SPACE.actions[5], np.int32)
    A, b, x, acts, _ = stack_fixed([row, row], [act, act], 2)
    ref = low(A, b, x, acts)                       # plain jit dispatch
    got = LocalExecutor().dispatch(low, (A, b, x, acts), 16)  # AOT cache
    for rl, gl in zip(jax.tree_util.tree_leaves(ref),
                      jax.tree_util.tree_leaves(got)):
        assert np.asarray(rl).tobytes() == np.asarray(gl).tobytes()


def test_cross_task_precompile_shares_one_executable():
    """Two tasks over the same (config, backend, executor) collapse onto
    one dispatcher and one executable per shape (DESIGN.md §12)."""
    cfg = _ir(3.5e-6)
    t1 = GMRESIRTask(_systems(1, seed=1), SPACE, cfg, bucket_step=16,
                     min_bucket=16)
    t2 = GMRESIRTask(_systems(1, seed=2), SPACE, cfg, bucket_step=16,
                     min_bucket=16)
    assert computation_key(t1.lowerable_for(16)) == \
        computation_key(t2.lowerable_for(16))
    c0 = EX.executor_compile_count()
    assert t1.precompile_bucket(16, 2)
    assert EX.executor_compile_count() == c0 + 1
    assert t2.precompile_bucket(16, 2)            # dedupe: no new build
    assert EX.executor_compile_count() == c0 + 1
    wrapped = batch_callable(LocalExecutor(), None, t1.lowerable_for(16))
    assert len(wrapped.executables) == 1
    assert batch_callable(LocalExecutor(), None,
                          t2.lowerable_for(16)) is wrapped


def test_engine_precompile_noop_when_already_warm():
    """Warming an engine that already solved its buckets builds nothing:
    the live path and the warmup path share the per-shape cache."""
    cfg = _ir(4.5e-6)
    task = GMRESIRTask(_systems(2, seed=3), SPACE, cfg, bucket_step=16,
                       min_bucket=16)
    eng = AutotuneEngine(task, chunk=2)
    eng.solve_pairs([(0, 0), (1, 0)])
    c0 = EX.executor_compile_count()
    out = eng.precompile()
    assert out == [(16, True)]
    assert EX.executor_compile_count() == c0      # nothing new to build


# ---------------------------------------------------------------------------
# Server warmup modes
# ---------------------------------------------------------------------------

def test_sync_warmup_first_request_hits_warm_executable():
    """``warmup="sync"``: ready pre-traffic, and the first live request
    records zero compiles and zero wrap builds — the cliff is gone."""
    srv = AutotuneServer(_policy(), _ir(5.5e-6), batcher_cfg=BCFG,
                         obs=False, seed=0, warmup="sync",
                         warmup_buckets=[12, 28])
    assert sorted(srv._warmup_expected) == [16, 32]   # sizes -> buckets
    assert srv.ready                                  # before any traffic
    state = srv.warmup_state()
    assert state["mode"] == "sync" and state["done"]
    assert state["warmed_buckets"] == [16, 32]
    c0, w0 = EX.executor_compile_count(), len(EX._WRAPPED)
    for s in _systems(2, seed=4):
        srv.submit(s)
    srv.drain()
    assert EX.executor_compile_count() == c0          # zero compiles
    assert len(EX._WRAPPED) == w0                     # zero wrap builds
    assert srv.telemetry.snapshot()["n_solves"] == 2


def test_background_warmup_flips_readyz_per_bucket_in_priority_order(
        tmp_path):
    """``warmup="background"``: /readyz starts 503 with the grid
    pending, flips warm per bucket in trajectory-traffic order, and
    goes 200 exactly when the expected grid is warm."""
    traj = tmp_path / "traj.jsonl"
    traj.write_text("".join(json.dumps({"bucket": b}) + "\n"
                            for b in (32, 32, 32, 16)))
    gate = threading.Semaphore(0)
    srv = AutotuneServer(_policy(), _ir(6.5e-6), batcher_cfg=BCFG, seed=0,
                         obs=Observability(trajectory_path=str(traj)),
                         warmup="background", warmup_buckets=[16, 32],
                         warmup_pace=lambda e: gate.acquire())
    http = srv.serve_obs()
    try:
        code, body = _readyz(http.url)
        assert code == 503
        assert body["warmup"]["pending_buckets"] == [16, 32]
        assert not srv.ready
        gate.release()                       # let bucket #1 compile
        while len(srv.warm_order) < 1:
            time.sleep(0.05)
        code, body = _readyz(http.url)
        assert code == 503                   # 32 warm, 16 still pending
        assert body["warmup"]["warmed_buckets"] == [32]
        gate.release()                       # let bucket #2 compile
        assert srv.warmup.wait(120).done
        code, body = _readyz(http.url)
        assert code == 200
        assert body["warmup"]["done"]
        assert srv.warm_order == [32, 16]    # trajlog priority held
        assert srv.ready
    finally:
        http.close()


# ---------------------------------------------------------------------------
# Warm restart: disk cache serves the whole grid (subprocess x2)
# ---------------------------------------------------------------------------

WARM_BOOT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import json, time, urllib.error, urllib.request
import numpy as np
from repro.core import (Discretizer, QTable, reduced_action_space)
from repro.core import aot, executor as EX
from repro.core.features import PAPER_FEATURES
from repro.core.policy import PrecisionPolicy
from repro.data import generate_dense_set
from repro.obs import Observability
from repro.service import AutotuneServer, BatcherConfig
from repro.solvers import IRConfig

SPACE = reduced_action_space()
nf = len(PAPER_FEATURES)
feats = np.random.default_rng(0).normal(size=(8, nf))
disc = Discretizer.fit(feats, [2] * nf)
pol = PrecisionPolicy(SPACE, disc, QTable(disc.n_states, SPACE.n_actions))
srv = AutotuneServer(pol, IRConfig(tau=8.5e-6, i_max=4, m_max=12),
                     batcher_cfg=BatcherConfig(max_batch=2,
                                               max_wait_s=0.001,
                                               bucket_step=16,
                                               min_bucket=16),
                     obs=Observability(), seed=0, warmup="background",
                     warmup_buckets=[16])   # cache dir via env
http = srv.serve_obs()
deadline, ready = time.time() + 300, None
while time.time() < deadline:          # wait for the warm gate
    try:
        with urllib.request.urlopen(http.url + "/readyz",
                                    timeout=10) as r:
            ready = r.status
            break
    except urllib.error.HTTPError:     # 503: grid still compiling
        time.sleep(0.2)
assert srv.warmup.wait(300).done
s = generate_dense_set(1, np.random.default_rng(7), n_range=(12, 14),
                       log10_kappa_range=(3, 4))
rid = srv.submit(s[0])
srv.drain()
resp = srv.poll(rid)
http.close()
print("RESULT " + json.dumps({
    "ready": ready,
    "compiles": EX.executor_compile_count(),
    "cache": aot.cache_stats(),
    "digest": {"action": int(resp.action),
               "status": int(resp.record.status),
               "metrics": {k: repr(v)
                           for k, v in sorted(
                               resp.record.metrics.items())}}}))
"""


def test_warm_restart_zero_fresh_xla_compiles(tmp_path):
    """Two boots sharing one REPRO_COMPILE_CACHE_DIR: the restart must
    rebuild its grid purely from disk — zero compile-cache misses,
    asserted on counters, never timing — and solve bit-identically."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    env["REPRO_COMPILE_CACHE_DIR"] = str(tmp_path / "xla-cache")
    runs = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", WARM_BOOT], env=env,
                             capture_output=True, text=True, timeout=600)
        lines = [ln for ln in out.stdout.splitlines()
                 if ln.startswith("RESULT ")]
        assert lines, (out.stdout[-2000:], out.stderr[-3000:])
        runs.append(json.loads(lines[-1][len("RESULT "):]))
    first, second = runs
    assert first["ready"] == 200 and second["ready"] == 200
    assert first["cache"]["dir"] == str(tmp_path / "xla-cache")
    assert second["cache"]["dir"] == first["cache"]["dir"]
    # Cold boot really compiled; warm restart did zero fresh XLA work.
    assert first["cache"]["misses"] > 0, first
    assert second["cache"]["misses"] == 0, second
    assert second["cache"]["hits"] > 0, second
    # Same number of in-process executable builds either way (the cache
    # serves the XLA work, not the dispatcher bookkeeping)...
    assert second["compiles"] == first["compiles"]
    # ...and the restart is bit-stable end to end.
    assert second["digest"] == first["digest"]
